//! Second-layer observability record-path cost — the price ISSUE 10
//! adds to the serving hot path: one analytic workload estimate plus
//! eight counter adds per dispatch, one regret fold per online cost
//! observation, one shard-imbalance update per fan-out batch, one SLO
//! window update per delivered reply, and the exposition render that now
//! carries the workload, regret and SLO sections. Feeds DESIGN.md
//! §Observability (recording convention in BENCHMARKS.md; supports
//! `--json <path>` self-recording).

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::coordinator::metrics::Metrics;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::{registry, KernelKind, SparseOp};
use ge_spmm::obs::expo;
use ge_spmm::obs::workload;
use ge_spmm::obs::{SloMonitor, SloSpec};
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::json::{num, obj};
use ge_spmm::util::prng::Xoshiro256;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Duration;

/// Record-path ops per timed closure call: single calls are too small
/// for the wallclock harness, so every case batches and reports per-op.
const BATCH: usize = 10_000;

fn per_op(median_s: f64, ops: usize) -> f64 {
    median_s / ops as f64 * 1e9
}

fn main() {
    println!("== workload-accounting record-path cost (this machine) ==");
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("workload_overhead")
                .with_config(obj(vec![("batch", num(BATCH as f64))])),
        )
    });
    let mut cases: Vec<(String, f64)> = Vec::new();
    let mut run = |name: &str, ops: usize, f: &mut dyn FnMut()| {
        let s = bench_fn(name, f);
        let ns = per_op(s.median_s(), ops);
        println!("{}  ({ns:.1} ns/op)", s.line());
        cases.push((name.to_string(), ns));
        s
    };

    let entry = registry().canonical(SparseOp::Spmm, KernelKind::SrRs);

    // the analytic model alone: what every dispatch computes
    run("workload estimate x10k", BATCH, &mut || {
        for i in 0..BATCH {
            black_box(workload::estimate(&entry.variant, 4096, 65_536 + i, 32));
        }
    });

    // estimate + the eight counter adds the metrics hub pays per dispatch
    let metrics = Metrics::default();
    let latency = Duration::from_micros(40);
    run("workload record x10k", BATCH, &mut || {
        for i in 0..BATCH {
            let est = workload::estimate(&entry.variant, 4096, 65_536 + i, 32);
            metrics.record_workload(entry.id, &est, latency);
        }
    });

    // one regret fold per online cost observation
    run("regret fold x10k", BATCH, &mut || {
        for i in 0..BATCH {
            let cost = 1e-11 + (i % 7) as f64 * 1e-12;
            metrics.regret().fold(SparseOp::Spmm, i % 12, entry.id, cost, 1e-11);
        }
    });

    // one shard-imbalance update per fan-out batch
    run("shard imbalance record x10k", BATCH, &mut || {
        for i in 0..BATCH as u64 {
            metrics.record_shard_imbalance(600 + i % 64, 2000, 4);
        }
    });

    // one SLO window update per delivered reply
    let monitor = Arc::new(SloMonitor::new(SloSpec::parse("p99=2ms,queue=128").unwrap()));
    metrics.install_slo(monitor.clone());
    run("slo observe x10k", BATCH, &mut || {
        for i in 0..BATCH {
            monitor.observe(Duration::from_micros(50 + (i % 100) as u64), i % 32);
        }
    });
    black_box(monitor.report().healthy());

    // denominator: a full instrumented request with workload accounting
    // live (trace, audit, latency histogram, workload banks)
    let mut rng = Xoshiro256::seeded(11);
    let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(256, 256, 0.03, &mut rng));
    let engine = SpmmEngine::native();
    let h = engine.register(csr).unwrap();
    let x = DenseMatrix::random(256, 8, 1.0, &mut rng);
    run("spmm end-to-end accounted", 1, &mut || {
        black_box(engine.spmm(h, &x).unwrap());
    });

    // what `serve --stats-every` pays now that the snapshot carries the
    // workload, regret and SLO sections
    engine.metrics.install_slo(monitor.clone());
    run("prometheus render (full)", 1, &mut || {
        black_box(expo::prometheus_text(&engine.metrics).len());
    });

    if let Some((_, rec)) = record.as_mut() {
        for (name, ns) in &cases {
            rec.push_value(name, *ns, "ns/op");
        }
    }
    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
