//! L3 dispatch latency: how much the coordinator adds around the PJRT
//! execution (selection, routing, packing-cache hit, unpacking), plus
//! batcher throughput. Feeds DESIGN.md §Perf (recording convention in
//! BENCHMARKS.md).

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::coordinator::batcher::Batcher;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::path::Path;

fn main() {
    println!("== coordinator dispatch & batching latency ==");
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = SpmmEngine::new(Path::new("artifacts")).unwrap();
    let mut rng = Xoshiro256::seeded(11);
    let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(400, 400, 0.01, &mut rng));
    let h = engine.register(a.clone()).unwrap();

    for n in [1usize, 4, 32] {
        let x = DenseMatrix::random(400, n, 1.0, &mut rng);
        // prime compile + packing caches
        engine.spmm(h, &x).unwrap();
        let s = bench_fn(&format!("spmm dispatch n={n} (warm)"), || {
            let _ = engine.spmm(h, &x).unwrap();
        });
        println!("{}", s.line());
    }

    // batcher: 4 single-column requests coalesced into one n=4 execution
    let xs: Vec<DenseMatrix> = (0..4)
        .map(|_| DenseMatrix::random(400, 1, 1.0, &mut rng))
        .collect();
    let s = bench_fn("batcher 4×(n=1) → one n=4 call", || {
        let mut b = Batcher::new(&engine, 4);
        for (i, x) in xs.iter().enumerate() {
            let _ = b.submit(h, x.clone(), i as u64).unwrap();
        }
    });
    println!("{}", s.line());
    println!("\n{}", engine.metrics.summary());
}
