//! Dynamic-graph delta updates: in-place patch latency vs full
//! re-preparation, across churn rates. Quantifies the payoff of
//! `SpmmEngine::apply_delta`'s patch path (value-only batches routed
//! through `SpmmBackend::prepare_delta`) against the structural path
//! (snapshot + rebuild + full prepare) and a from-scratch
//! `prepare` baseline. Feeds DESIGN.md §Dynamic updates (recording
//! convention in BENCHMARKS.md; supports `--json <path>`
//! self-recording).

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::sparse::{CsrMatrix, EdgeDelta};
use ge_spmm::util::json::{num, obj, Json};
use ge_spmm::util::prng::Xoshiro256;

/// Value-only batch touching `k` existing edges, strided across the
/// stream order so updates spread over the whole matrix.
fn value_delta(csr: &CsrMatrix, k: usize, rng: &mut Xoshiro256) -> EdgeDelta {
    let nnz = csr.nnz();
    let step = (nnz / k).max(1);
    let mut delta = EdgeDelta::new();
    let mut p = 0usize;
    while p < nnz && delta.len() < k {
        let r = csr.indptr.partition_point(|&e| (e as usize) <= p) - 1;
        delta.insert(r, csr.indices[p] as usize, rng.next_f32());
        p += step;
    }
    delta
}

fn main() {
    println!("== dynamic-graph delta updates: patch vs re-prepare ==");
    let scales = [10u32, 13];
    let update_fracs = [0.001f64, 0.01, 0.1];
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("delta_updates").with_config(obj(vec![
                (
                    "scales",
                    Json::Arr(scales.iter().map(|&s| num(s as f64)).collect()),
                ),
                (
                    "update_fracs",
                    Json::Arr(update_fracs.iter().map(|&f| num(f)).collect()),
                ),
            ])),
        )
    });

    for scale in scales {
        let base = RmatConfig::new(scale, 8.0);
        let mut rng = Xoshiro256::seeded(42);
        let csr = CsrMatrix::from_coo(&base.generate(&mut rng));
        let label = format!("rmat_s{scale}");
        println!(
            "\n--- {label} ({}x{}, nnz {}) ---",
            csr.rows,
            csr.cols,
            csr.nnz()
        );

        // From-scratch preparation: the cost every batch would pay
        // without delta support.
        let backend = NativeBackend::default();
        let prepare = bench_fn(&format!("{label} full prepare"), || {
            backend.prepare(&csr).unwrap();
        });
        println!("{}", prepare.line());
        if let Some((_, rec)) = record.as_mut() {
            rec.push_latency(&prepare);
        }

        // Patch path: value-only churn at increasing update fractions.
        let engine = SpmmEngine::native().with_prepared_cache(256 << 20);
        let h = engine.register(csr.clone()).unwrap();
        for frac in update_fracs {
            let k = ((csr.nnz() as f64 * frac).ceil() as usize).max(1);
            let delta = value_delta(&csr, k, &mut rng);
            let s = bench_fn(&format!("{label} patch f={frac}"), || {
                let out = engine.apply_delta(h, &delta).unwrap();
                assert!(out.patched);
            });
            println!(
                "{}  ({:.1}x vs prepare)",
                s.line(),
                prepare.median_s() / s.median_s()
            );
            if let Some((_, rec)) = record.as_mut() {
                rec.push_latency(&s);
                rec.push_value(
                    &format!("{} speedup", s.name),
                    prepare.median_s() / s.median_s(),
                    "x vs full prepare",
                );
            }
        }

        // Structural path: alternate two batches that move one edge
        // back and forth between a present and an absent coordinate, so
        // every iteration changes the sparsity pattern (a delete + an
        // insert at the SAME coordinate would compose to a value-only
        // update) and takes the snapshot + rebuild + re-prepare route.
        let (r1, c1) = {
            let r = (0..csr.rows).find(|&r| csr.row_nnz(r) > 0).unwrap();
            (r, csr.row(r).0[0] as usize)
        };
        let (r2, c2) = {
            let r = (0..csr.rows).find(|&r| csr.row_nnz(r) < csr.cols).unwrap();
            let row = csr.row(r).0;
            let c = (0..csr.cols as u32).find(|c| row.binary_search(c).is_err());
            (r, c.unwrap() as usize)
        };
        let mut fwd = EdgeDelta::new();
        fwd.delete(r1, c1).insert(r2, c2, 0.5);
        let mut bwd = EdgeDelta::new();
        bwd.delete(r2, c2).insert(r1, c1, 0.25);
        let mut flip = false;
        let s = bench_fn(&format!("{label} structural re-prepare"), || {
            let d = if flip { &bwd } else { &fwd };
            flip = !flip;
            let out = engine.apply_delta(h, d).unwrap();
            assert!(out.report.structural);
            assert!(!out.patched);
        });
        println!(
            "{}  ({:.1}x vs prepare)",
            s.line(),
            prepare.median_s() / s.median_s()
        );
        if let Some((_, rec)) = record.as_mut() {
            rec.push_latency(&s);
        }
    }

    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
