//! §2.1.1 VSR ablation: at N=1, on what fraction of the collection does
//! VSR (PR-WB) beat all three alternatives — the plain baseline (SR-RS),
//! balancing alone (SR-WB) and parallel reduction alone (PR-RS)?
//!
//! Paper: VSR wins on 40.8% of SuiteSparse (RTX3090 model).

use ge_spmm::bench::figures::{load_bench_matrices, sim_suite};
use ge_spmm::bench::Table;
use ge_spmm::sim::{GpuConfig, SimKernel};

fn main() {
    println!("== §2.1.1 ablation: VSR vs the other three designs at N=1 ==");
    let gpu = GpuConfig::rtx3090();
    eprintln!("building collection …");
    let matrices = load_bench_matrices();
    let sr_rs = sim_suite(&matrices, SimKernel::SrRs, 1, &gpu);
    let sr_wb = sim_suite(&matrices, SimKernel::SrWb, 1, &gpu);
    let pr_rs = sim_suite(&matrices, SimKernel::PrRs, 1, &gpu);
    let pr_wb = sim_suite(&matrices, SimKernel::PrWb, 1, &gpu);

    let mut wins = 0usize;
    let mut per_winner = [0usize; 4];
    let mut t = Table::new(&["matrix", "sr_rs", "sr_wb", "pr_rs", "vsr(pr_wb)", "winner"]);
    for i in 0..matrices.len() {
        let times = [sr_rs[i], sr_wb[i], pr_rs[i], pr_wb[i]];
        let best = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        per_winner[best] += 1;
        if best == 3 {
            wins += 1;
        }
        t.row(vec![
            matrices[i].name.clone(),
            format!("{:.1}µs", sr_rs[i] * 1e6),
            format!("{:.1}µs", sr_wb[i] * 1e6),
            format!("{:.1}µs", pr_rs[i] * 1e6),
            format!("{:.1}µs", pr_wb[i] * 1e6),
            ["sr_rs", "sr_wb", "pr_rs", "VSR"][best].to_string(),
        ]);
    }
    t.print();
    println!(
        "\nVSR wins on {}/{} = {:.1}% of matrices (paper: 40.8%)",
        wins,
        matrices.len(),
        100.0 * wins as f64 / matrices.len() as f64
    );
    println!(
        "winner split: sr_rs {} | sr_wb {} | pr_rs {} | vsr {}",
        per_winner[0], per_winner[1], per_winner[2], per_winner[3]
    );
}
