//! Observability record-path cost on this machine — the price of the
//! instrumentation ISSUE 7 threads through the serving hot path: one
//! lock-free histogram update per latency, one TLS read per span site
//! when no trace is attached, span materialization when one is, one
//! audit-log push per selector decision, and the exposition render that
//! `--stats-every` pays once per interval. Feeds DESIGN.md
//! §Observability (recording convention in BENCHMARKS.md; supports
//! `--json <path>` self-recording).

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::coordinator::metrics::Metrics;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::kernels::{KernelKind, SparseOp};
use ge_spmm::obs::expo;
use ge_spmm::obs::hist::AtomicHistogram;
use ge_spmm::obs::trace::{self, Trace, TraceHandle};
use ge_spmm::obs::{AuditEntry, AuditLog};
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::json::{num, obj};
use ge_spmm::util::prng::Xoshiro256;
use std::hint::black_box;
use std::time::Duration;

/// Record-path ops per timed closure call: single calls are too small
/// for the wallclock harness, so every case batches and reports per-op.
const BATCH: usize = 10_000;
/// Spans per on-trace closure call (each call owns a fresh trace, so
/// this also bounds the span vector the trace accumulates).
const SPANS: usize = 1_000;

fn per_op(median_s: f64, ops: usize) -> f64 {
    median_s / ops as f64 * 1e9
}

fn main() {
    println!("== observability record-path cost (this machine) ==");
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("metrics_overhead").with_config(obj(vec![
                ("batch", num(BATCH as f64)),
                ("spans", num(SPANS as f64)),
            ])),
        )
    });
    // pseudo-latencies spanning the histogram's range, fixed across runs
    let vals: Vec<u64> = (0..BATCH as u64).map(|i| 500 + (i * 7919) % 1_000_000).collect();
    let mut cases: Vec<(String, f64)> = Vec::new();
    let mut run = |name: &str, ops: usize, f: &mut dyn FnMut()| {
        let s = bench_fn(name, f);
        let ns = per_op(s.median_s(), ops);
        println!("{}  ({ns:.1} ns/op)", s.line());
        cases.push((name.to_string(), ns));
        s
    };

    let hist = AtomicHistogram::new();
    run("histogram record x10k", BATCH, &mut || {
        for &v in &vals {
            hist.record(v);
        }
    });
    black_box(hist.snapshot());

    let metrics = Metrics::default();
    run("metrics record request x10k", BATCH, &mut || {
        for &v in &vals {
            metrics.record(KernelKind::SrRs, Duration::from_nanos(v));
        }
    });
    run("metrics record shard x10k", BATCH, &mut || {
        for &v in &vals {
            metrics.record_shard(KernelKind::PrWb, Duration::from_nanos(v));
        }
    });

    // span site with no trace attached: the cost every uninstrumented
    // request pays at every span site — a thread-local read and an
    // inert guard
    run("span off-trace x10k", BATCH, &mut || {
        for i in 0..BATCH {
            let mut g = trace::span("bench");
            g.set_attr("i", i);
        }
    });
    // span site inside an attached trace: materializes the record
    run("span on-trace x1k", SPANS, &mut || {
        let t = Trace::begin("bench");
        let scope = trace::attach(&TraceHandle::of(&t));
        for i in 0..SPANS {
            let mut g = trace::span("bench");
            g.set_attr("i", i);
        }
        drop(scope);
        black_box(t.span_count());
    });

    // one selector decision audited, ring at steady state
    let mut rng = Xoshiro256::seeded(11);
    let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(256, 256, 0.03, &mut rng));
    let features = MatrixFeatures::of(&csr);
    let decision = AdaptiveSelector::default().decide(&features, 8);
    let proto = AuditEntry {
        seq: 0,
        op: SparseOp::Spmm,
        grain: "request",
        shard: None,
        selector: "adaptive",
        matrix: Some(0),
        features,
        n: 8,
        thresholds: decision.thresholds,
        rule: decision.rule,
        kernel: decision.kernel,
        explored: false,
        realized_cost: None,
    };
    let log = AuditLog::default();
    run("audit push x1k", SPANS, &mut || {
        for _ in 0..SPANS {
            log.push(proto.clone());
        }
    });

    // denominator: a full instrumented request (trace committed to the
    // ring, decision audited, latency recorded) on a small matrix
    let engine = SpmmEngine::native();
    let h = engine.register(csr).unwrap();
    let x = DenseMatrix::random(256, 8, 1.0, &mut rng);
    run("spmm end-to-end traced", 1, &mut || {
        black_box(engine.spmm(h, &x).unwrap());
    });

    // what `serve --stats-every` pays per interval
    run("prometheus render", 1, &mut || {
        black_box(expo::prometheus_text(&engine.metrics).len());
    });

    if let Some((_, rec)) = record.as_mut() {
        for (name, ns) in &cases {
            rec.push_value(name, *ns, "ns/op");
        }
    }
    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
