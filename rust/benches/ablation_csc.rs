//! §2.1.3 CSC ablation: coalesced sparse-row caching vs the pure
//! sequential-reduction SpMM at N=128, on the R-MAT micro benchmark.
//!
//! Paper: CSC = 1.20× average (RTX3090 model).

use ge_spmm::bench::figures::{geomean_speedup, load_matrices};
use ge_spmm::bench::Table;
use ge_spmm::gen::Collection;
use ge_spmm::sim::{simulate, GpuConfig, SimKernel};

fn main() {
    println!("== §2.1.3 ablation: CSC vs pure sequential SpMM at N=128 ==");
    let gpu = GpuConfig::rtx3090();
    eprintln!("building R-MAT micro benchmark …");
    let specs: Vec<_> = Collection::suite()
        .into_iter()
        .filter(|s| s.name.starts_with("rmat_s1"))
        .take(27)
        .collect();
    let matrices = load_matrices(specs);

    let mut with = Vec::new();
    let mut without = Vec::new();
    let mut t = Table::new(&["matrix", "CSC", "no CSC", "speedup"]);
    for m in &matrices {
        let a = simulate(SimKernel::SrRs, &m.sim, 128, &gpu).seconds;
        let b = simulate(SimKernel::SrRsNoCsc, &m.sim, 128, &gpu).seconds;
        t.row(vec![
            m.name.clone(),
            format!("{:.0}µs", a * 1e6),
            format!("{:.0}µs", b * 1e6),
            format!("{:.2}×", b / a),
        ]);
        with.push(a);
        without.push(b);
    }
    t.print();
    println!(
        "\ngeomean CSC speedup: {:.2}× (paper: 1.20×)",
        geomean_speedup(&without, &with)
    );
}
