//! Fig. 6, SpMV row: ours (best-of-4 and rule-based) vs cuSPARSE on the
//! three GPU models over the benchmark collection at N=1.
//!
//! Paper: ours/cuSPARSE = 1.14× (V100), 1.07× (RTX2080), 1.11× (RTX3090).

use ge_spmm::bench::figures::{
    geomean_speedup, load_bench_matrices, sim_ours_best, sim_ours_rules, sim_suite,
};
use ge_spmm::bench::Table;
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::sim::{GpuConfig, SimKernel};

fn main() {
    println!("== Fig 6 / SpMV (N=1): ours vs cuSPARSE ==");
    eprintln!("building collection …");
    let matrices = load_bench_matrices();
    let sel = AdaptiveSelector::default();
    let mut t = Table::new(&["gpu", "ours/cusparse", "rules/cusparse", "paper (ours)"]);
    let paper = [("v100", 1.14), ("rtx2080", 1.07), ("rtx3090", 1.11)];
    for (gpu, p) in GpuConfig::all().into_iter().zip(paper) {
        let cus = sim_suite(&matrices, SimKernel::CuSparse, 1, &gpu);
        let best = sim_ours_best(&matrices, 1, &gpu);
        let rules = sim_ours_rules(&matrices, &sel, 1, &gpu);
        t.row(vec![
            gpu.name.to_string(),
            format!("{:.2}×", geomean_speedup(&cus, &best)),
            format!("{:.2}×", geomean_speedup(&cus, &rules)),
            format!("{:.2}×", p.1),
        ]);
    }
    t.print();
}
