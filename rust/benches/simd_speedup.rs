//! SIMD speedup measurement (ISSUE 6): scalar vs vectorized inner loops,
//! at the microkernel grain and the kernel grain.
//!
//! Both `vec8` backends (scalar and 8-lane tiled) are always compiled, so
//! one binary measures the microkernel speedup regardless of features.
//! The kernel-grain rows compare the *configured* kernels against a local
//! always-scalar baseline: in a default build they should be ≈1.0× (same
//! code), under `--features simd` they show what the tiling buys end to
//! end. Kernel rows run on a serial pool so the ratio isolates
//! vectorization from threading. `--json <path>` records everything via
//! `BenchRecord` (convention in BENCHMARKS.md).
//!
//! Run: `cargo bench --bench simd_speedup [--features simd] -- --json
//! BENCH_simd_speedup_<date>.json`

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::kernels::{merge_path, sr_rs, vec8};
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::json::{obj, s, Json};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::threadpool::ThreadPool;
use std::hint::black_box;

fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Pinned scalar SpMM — same reduction order as `sr_rs`, never tiled, so
/// the kernel-grain ratio measures exactly what the `simd` feature buys.
fn spmm_scalar(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix) {
    let n = x.cols;
    y.data.fill(0.0);
    for r in 0..a.rows {
        let (cols, vals) = a.row(r);
        let out = &mut y.data[r * n..(r + 1) * n];
        for (&c, &v) in cols.iter().zip(vals) {
            let xrow = x.row(c as usize);
            for j in 0..n {
                out[j] += v * xrow[j];
            }
        }
    }
}

fn main() {
    let simd = cfg!(feature = "simd");
    let portable = cfg!(feature = "portable_simd");
    println!("== SIMD speedup (this machine) ==");
    println!("features: simd={simd} portable_simd={portable}");
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("simd_speedup").with_config(obj(vec![
                ("simd", Json::Bool(simd)),
                ("portable_simd", Json::Bool(portable)),
                ("note", s("speedups are scalar_median / vectorized_median (>1 = faster)")),
            ])),
        )
    });
    let push = |rec: &mut Option<(std::path::PathBuf, BenchRecord)>, name: &str, v: f64| {
        println!("  {name}: {v:.3}x");
        if let Some((_, r)) = rec.as_mut() {
            r.push_value(name, v, "x speedup");
        }
    };

    // --- microkernel grain: tiled vs scalar, amortized over many rows ---
    let mut rng = Xoshiro256::seeded(11);
    const ROWS: usize = 2048;
    let mut axpy_speedups = Vec::new();
    let mut dot_speedups = Vec::new();
    for len in [32usize, 64, 128, 256] {
        let x = DenseMatrix::random(1, len, 1.0, &mut rng).data;
        let mut buf = DenseMatrix::random(ROWS, len, 1.0, &mut rng).data;
        let sc = bench_fn(&format!("axpy_scalar len={len}"), || {
            for chunk in buf.chunks_exact_mut(len) {
                vec8::axpy_scalar(chunk, 1.000001, &x);
            }
            black_box(&buf);
        });
        let ti = bench_fn(&format!("axpy_tiled len={len}"), || {
            for chunk in buf.chunks_exact_mut(len) {
                vec8::axpy_tiled(chunk, 1.000001, &x);
            }
            black_box(&buf);
        });
        axpy_speedups.push(sc.median_s() / ti.median_s());
        push(&mut record, &format!("axpy len={len}"), sc.median_s() / ti.median_s());

        let a = DenseMatrix::random(ROWS, len, 1.0, &mut rng);
        let sc = bench_fn(&format!("dot_scalar d={len}"), || {
            let mut acc = 0f32;
            for r in 0..ROWS {
                acc += vec8::dot_scalar(a.row(r), &x);
            }
            black_box(acc);
        });
        let bl = bench_fn(&format!("dot_blocked d={len}"), || {
            let mut acc = 0f32;
            for r in 0..ROWS {
                acc += vec8::dot_blocked(a.row(r), &x);
            }
            black_box(acc);
        });
        dot_speedups.push(sc.median_s() / bl.median_s());
        push(&mut record, &format!("dot d={len}"), sc.median_s() / bl.median_s());
    }
    push(&mut record, "axpy geomean", geomean(&axpy_speedups));
    push(&mut record, "dot geomean", geomean(&dot_speedups));

    // --- kernel grain: configured sr_rs vs pinned scalar, serial pool ---
    let serial = ThreadPool::serial();
    let mut rng = Xoshiro256::seeded(13);
    let uniform = CsrMatrix::from_coo(&CooMatrix::random_uniform(4096, 4096, 0.002, &mut rng));
    let plaw = CsrMatrix::from_coo(
        &PowerLawConfig { rows: 4096, cols: 4096, alpha: 1.6, min_row: 1, max_row: 512 }
            .generate(&mut rng),
    );
    let mut kernel_speedups = Vec::new();
    for (mname, a) in [("uniform", &uniform), ("plaw", &plaw)] {
        for n in [32usize, 128] {
            let x = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
            let mut y = DenseMatrix::zeros(a.rows, n);
            let sc = bench_fn(&format!("{mname} n={n} scalar"), || {
                spmm_scalar(a, &x, &mut y);
            });
            let ke = bench_fn(&format!("{mname} n={n} sr_rs"), || {
                sr_rs::spmm(a, &x, &mut y, &serial);
            });
            kernel_speedups.push(sc.median_s() / ke.median_s());
            push(
                &mut record,
                &format!("sr_rs {mname} n={n}"),
                sc.median_s() / ke.median_s(),
            );
            let al = x.to_aligned();
            let ka = bench_fn(&format!("{mname} n={n} sr_rs aligned"), || {
                sr_rs::spmm_aligned(a, &al, &mut y, &serial);
            });
            push(
                &mut record,
                &format!("sr_rs+aligned {mname} n={n}"),
                sc.median_s() / ka.median_s(),
            );
        }
    }
    push(&mut record, "sr_rs geomean", geomean(&kernel_speedups));

    // --- traversal: merge-path vs blocked on the heavy tail (parallel) ---
    let pool = ThreadPool::default_parallel();
    let n = 32;
    let x = DenseMatrix::random(plaw.cols, n, 1.0, &mut rng);
    let mut y = DenseMatrix::zeros(plaw.rows, n);
    let blocked = bench_fn("plaw n=32 sr_rs blocked", || {
        sr_rs::spmm(&plaw, &x, &mut y, &pool);
    });
    let mp = bench_fn("plaw n=32 sr_rs merge-path", || {
        merge_path::spmm(&plaw, &x, &mut y, &pool);
    });
    push(&mut record, "merge_path vs blocked (plaw n=32)", blocked.median_s() / mp.median_s());

    if let Some((path, mut rec)) = record {
        rec.set_notes(&format!(
            "scalar/vectorized latency ratios, features simd={simd} portable_simd={portable}; \
             kernel rows use a serial pool to isolate vectorization from threading; \
             values ≈1.0 are expected in a default (scalar) build"
        ));
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
