//! §3.2: performance loss of the rule-based selection vs the oracle, and
//! vs always running a single fixed kernel, averaged over the collection
//! and all N.
//!
//! Paper: rules lose 12%/5%/10% (V100/2080/3090) vs oracle; the best
//! fixed-kernel policy loses ≥68%.

use ge_spmm::bench::figures::{load_bench_matrices, sim_ours_best, sim_ours_rules, sim_suite};
use ge_spmm::bench::Table;
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::sim::{GpuConfig, SimKernel};
use ge_spmm::util::stats;

fn main() {
    println!("== §3.2: selection loss vs oracle, rules vs fixed kernels ==");
    eprintln!("building collection …");
    let matrices = load_bench_matrices();
    let sel = AdaptiveSelector::default();
    let n_values = [1usize, 4, 32, 128];

    for gpu in GpuConfig::all() {
        let mut ratios_rules = Vec::new();
        let mut ratios_fixed: [Vec<f64>; 4] = Default::default();
        for &n in &n_values {
            let best = sim_ours_best(&matrices, n, &gpu);
            let rules = sim_ours_rules(&matrices, &sel, n, &gpu);
            for i in 0..matrices.len() {
                ratios_rules.push(rules[i] / best[i]);
            }
            for (ki, &k) in SimKernel::OURS.iter().enumerate() {
                let t = sim_suite(&matrices, k, n, &gpu);
                for i in 0..matrices.len() {
                    ratios_fixed[ki].push(t[i] / best[i]);
                }
            }
        }
        let mut t = Table::new(&["policy", "mean loss vs oracle"]);
        t.row(vec![
            "rule-based (ours)".into(),
            format!("{:.1}%", (stats::geomean(&ratios_rules) - 1.0) * 100.0),
        ]);
        let mut best_fixed = f64::INFINITY;
        for (ki, k) in SimKernel::OURS.iter().enumerate() {
            let loss = stats::geomean(&ratios_fixed[ki]) - 1.0;
            best_fixed = best_fixed.min(loss);
            t.row(vec![
                format!("always {}", k.label()),
                format!("{:.1}%", loss * 100.0),
            ]);
        }
        println!("\n--- {} ---", gpu.name);
        t.print();
        println!(
            "best fixed-kernel loss: {:.1}% (paper: ≥68%); rules (paper: 5–12%)",
            best_fixed * 100.0
        );
    }
}
