//! Fig. 6, SpMM rows: ours vs cuSPARSE across N, and vs ASpT at the
//! N ∈ {32, 128} settings ASpT supports, on all three GPU models.
//!
//! Paper: ours/cuSPARSE ranges 1.26–1.41× (V100), 1.09–1.44× (RTX2080),
//! 1.22–1.57× (RTX3090); ours/ASpT = 1.21/1.14/1.16× at N=32 and
//! 1.18/1.14/1.06× at N=128.

use ge_spmm::bench::figures::{
    geomean_speedup, load_bench_matrices, sim_ours_best, sim_ours_rules, sim_suite,
};
use ge_spmm::bench::Table;
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::sim::{GpuConfig, SimKernel};

fn main() {
    println!("== Fig 6 / SpMM: ours vs cuSPARSE and ASpT ==");
    eprintln!("building collection …");
    let matrices = load_bench_matrices();
    let sel = AdaptiveSelector::default();
    for gpu in GpuConfig::all() {
        println!("\n--- {} ---", gpu.name);
        let mut t = Table::new(&["N", "ours/cusparse", "rules/cusparse", "ours/aspt"]);
        for n in [2usize, 4, 8, 16, 32, 64, 128] {
            let cus = sim_suite(&matrices, SimKernel::CuSparse, n, &gpu);
            let aspt = sim_suite(&matrices, SimKernel::Aspt, n, &gpu);
            let best = sim_ours_best(&matrices, n, &gpu);
            let rules = sim_ours_rules(&matrices, &sel, n, &gpu);
            t.row(vec![
                n.to_string(),
                format!("{:.2}×", geomean_speedup(&cus, &best)),
                format!("{:.2}×", geomean_speedup(&cus, &rules)),
                if n >= 32 {
                    format!("{:.2}×", geomean_speedup(&aspt, &best))
                } else {
                    "-".into()
                },
            ]);
        }
        t.print();
    }
    println!("\npaper ranges: cuSPARSE 1.26–1.41 / 1.09–1.44 / 1.22–1.57; ASpT n32 1.21/1.14/1.16, n128 1.18/1.14/1.06");
}
