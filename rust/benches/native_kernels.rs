//! Wallclock benchmark of the native CPU kernels (the Rust ports of the
//! four designs plus the baselines) — the L3 hot path measured on this
//! machine. Not a paper figure; feeds DESIGN.md §Perf (recording
//! convention in BENCHMARKS.md).

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::gen::Collection;
use ge_spmm::kernels::baseline::{aspt_like_spmm, cusparse_like_spmm, AsptMatrix};
use ge_spmm::kernels::{pr_rs, pr_wb, sr_rs, sr_wb, KernelKind, WARP};
use ge_spmm::sparse::{DenseMatrix, SegmentedMatrix};
use ge_spmm::util::json::{num, obj, Json};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::threadpool::ThreadPool;

fn main() {
    println!("== native kernel wallclock (this machine) ==");
    let pool = ThreadPool::default_parallel();
    println!("threads: {}", pool.workers());
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("native_kernels").with_config(obj(vec![
                ("threads", num(pool.workers() as f64)),
                (
                    "n_values",
                    Json::Arr([1usize, 4, 32, 128].iter().map(|&n| num(n as f64)).collect()),
                ),
            ])),
        )
    });
    let specs: Vec<_> = ["uniform_s12_e8", "rmat_s12_e8_g500", "band_n16384_b8"]
        .iter()
        .filter_map(|n| Collection::suite().into_iter().find(|s| &s.name == n))
        .collect();
    for spec in specs {
        let csr = spec.build();
        // Same prepared layouts NativeBackend builds, but hand-held here so
        // the timed region is the kernel alone (no output allocation).
        let segments = SegmentedMatrix::from_csr(&csr, WARP);
        let aspt = AsptMatrix::from_csr(&csr);
        println!(
            "\n--- {} ({}x{}, nnz {}) ---",
            spec.name,
            csr.rows,
            csr.cols,
            csr.nnz()
        );
        for n in [1usize, 4, 32, 128] {
            let mut rng = Xoshiro256::seeded(7);
            let x = DenseMatrix::random(csr.cols, n, 1.0, &mut rng);
            let mut y = DenseMatrix::zeros(csr.rows, n);
            let flops = 2.0 * csr.nnz() as f64 * n as f64;
            let mut report = |s: &ge_spmm::bench::BenchStats| {
                println!("{}  ({:.2} GFLOP/s)", s.line(), flops / s.median_s() / 1e9);
                if let Some((_, rec)) = record.as_mut() {
                    rec.push_latency(s);
                    rec.push_value(
                        &format!("{} throughput", s.name),
                        flops / s.median_s() / 1e9,
                        "GFLOP/s",
                    );
                }
            };
            for kind in KernelKind::ALL {
                let s = bench_fn(&format!("{} n={n} {}", spec.name, kind.label()), || {
                    match kind {
                        KernelKind::SrRs => sr_rs::spmm(&csr, &x, &mut y, &pool),
                        KernelKind::SrWb => sr_wb::spmm(&segments, &x, &mut y, &pool),
                        KernelKind::PrRs => pr_rs::spmm(&csr, &x, &mut y, &pool),
                        KernelKind::PrWb => pr_wb::spmm(&segments, &x, &mut y, &pool),
                    }
                });
                report(&s);
            }
            let s = bench_fn(&format!("{} n={n} cusparse-like", spec.name), || {
                cusparse_like_spmm(&csr, &x, &mut y, &pool);
            });
            report(&s);
            let s = bench_fn(&format!("{} n={n} aspt-like", spec.name), || {
                aspt_like_spmm(&aspt, &x, &mut y, &pool);
            });
            report(&s);
        }
    }
    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
