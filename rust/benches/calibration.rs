//! Measured-calibration bench: wallclock cost of profiling the suite on
//! this machine, and the selection quality (geomean slowdown vs the
//! measured oracle) of the paper-default thresholds vs the
//! measured-calibrated ones. This is the `calibrate --measured` path
//! under measurement itself — the number that justifies shipping a
//! hardware profile with a deployment. See BENCHMARKS.md for recording
//! (`-- --json <path>` writes the record automatically).

use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::gen::Collection;
use ge_spmm::selector::measured::{collect_samples, MeasureConfig};
use ge_spmm::selector::{calibrate, AdaptiveSelector};
use ge_spmm::sparse::CsrMatrix;
use ge_spmm::util::json::{num, obj, Json};
use std::time::Instant;

/// Per-cell measurement budget (ms). Small: the suite has
/// |matrices| × |N| × 4 cells.
const BUDGET_MS: u64 = 20;
const N_VALUES: [usize; 3] = [1, 4, 32];

fn main() {
    println!("== measured calibration (this machine) ==");
    let backend = ge_spmm::backend::NativeBackend::default();
    let specs = Collection::mini_suite();
    let matrices: Vec<CsrMatrix> = specs.iter().map(|s| s.build()).collect();
    println!(
        "suite: {} matrices x N in {N_VALUES:?}, {BUDGET_MS} ms/cell budget",
        matrices.len()
    );

    let cfg = MeasureConfig::default().with_budget_ms(BUDGET_MS);
    let t0 = Instant::now();
    let samples = collect_samples(&matrices, &N_VALUES, &backend, &cfg).expect("profiling");
    let profile_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let cal = calibrate::calibrate(&samples);
    let fit_secs = t1.elapsed().as_secs_f64();

    let default_loss = calibrate::selector_loss(&AdaptiveSelector::default(), &samples);
    println!(
        "profiled {} samples in {profile_secs:.2}s; grid search {fit_secs:.4}s",
        samples.len()
    );
    println!(
        "default thresholds   T_avg={:<5} T_cv={:<5} geomean slowdown vs oracle: {:.4}",
        AdaptiveSelector::default().t_avg,
        AdaptiveSelector::default().t_cv,
        default_loss
    );
    println!(
        "measured-calibrated  T_avg={:<5} T_cv={:<5} geomean slowdown vs oracle: {:.4}",
        cal.selector.t_avg, cal.selector.t_cv, cal.mean_loss
    );
    println!(
        "calibration recovers {:.1}% of the default's loss over the oracle",
        if default_loss > 1.0 {
            100.0 * (default_loss - cal.mean_loss) / (default_loss - 1.0)
        } else {
            0.0
        }
    );

    if let Some(path) = json_path_arg() {
        let mut rec = BenchRecord::new("calibration").with_config(obj(vec![
            ("matrices", num(matrices.len() as f64)),
            ("n_values", Json::Arr(N_VALUES.iter().map(|&n| num(n as f64)).collect())),
            ("budget_ms", num(BUDGET_MS as f64)),
        ]));
        rec.push_value("profiling wallclock", profile_secs, "s");
        rec.push_value("grid-search wallclock", fit_secs, "s");
        rec.push_value("default thresholds loss", default_loss, "geomean slowdown");
        rec.push_value("calibrated loss", cal.mean_loss, "geomean slowdown");
        rec.push_value("calibrated T_avg", cal.selector.t_avg, "");
        rec.push_value("calibrated T_cv", cal.selector.t_cv, "");
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
