//! Wallclock scaling of `ShardedBackend` vs the unsharded `NativeBackend`
//! across shard counts {1, 2, 4, 8} on a `gen/` power-law and a uniform
//! matrix at N ∈ {4, 32, 128} — the fan-out/gather overhead vs
//! parallelism trade of the sharded execution subsystem. Feeds the
//! DESIGN.md experiment index; per-shard kernel choices are reported via
//! the backend's `Metrics` shard counters and the execution artifact.

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::bench::harness::bench_fn;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::gen::Collection;
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::shard::ShardedBackend;
use ge_spmm::sparse::DenseMatrix;
use ge_spmm::util::prng::Xoshiro256;

const MATRICES: [&str; 2] = ["plaw_n16384_a1.6_d16", "uniform_s12_e8"];
const SHARDS: [usize; 4] = [1, 2, 4, 8];
const WIDTHS: [usize; 3] = [4, 32, 128];

fn main() {
    println!("== sharded fan-out scaling (this machine) ==");
    let suite = Collection::suite();
    for name in MATRICES {
        let spec = suite
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no suite matrix named '{name}'"));
        let csr = spec.build();
        let feats = MatrixFeatures::of(&csr);
        println!("\n--- {name} ({}) ---", feats.summary());
        let selector = AdaptiveSelector::default();
        let native = NativeBackend::default();
        let op = native.prepare(&csr).expect("native prepare");
        for n in WIDTHS {
            let mut rng = Xoshiro256::seeded(17);
            let x = DenseMatrix::random(csr.cols, n, 1.0, &mut rng);
            let kernel = selector.select(&feats, n);
            let base = bench_fn(&format!("{name} n={n} native/{}", kernel.label()), || {
                native.execute(&op, &x, kernel).expect("native execute");
            });
            println!("{}", base.line());
            for k in SHARDS {
                let backend = ShardedBackend::new(k).adaptive(selector);
                let sop = backend.prepare(&csr).expect("sharded prepare");
                // one untimed pass to surface the per-shard kernel choices
                let exec = backend.execute(&sop, &x, kernel).expect("sharded execute");
                let stats = bench_fn(&format!("{name} n={n} sharded k={k}"), || {
                    backend.execute(&sop, &x, kernel).expect("sharded execute");
                });
                let counts = backend.metrics().shard_kernel_counts();
                println!(
                    "{}  x{:.2} vs native  {}  shard execs [sr_rs={} sr_wb={} pr_rs={} pr_wb={}]",
                    stats.line(),
                    base.median_s() / stats.median_s(),
                    exec.artifact,
                    counts[0],
                    counts[1],
                    counts[2],
                    counts[3],
                );
            }
        }
    }
}
