//! Serving-layer throughput on this machine: (1) registration cost with
//! and without the prepared-matrix cache — the prepare-once/execute-many
//! amortization the serving layer exists for — and (2) end-to-end
//! requests/sec through the multi-worker `Server` across worker counts
//! on a mixed-matrix workload. Feeds the DESIGN.md experiment index; see
//! BENCHMARKS.md for how to record results.

use ge_spmm::bench::harness::{bench_fn_with, BenchConfig};
use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const MATRICES: usize = 4;
const PRODUCERS: usize = 4;
const REQUESTS_PER_PRODUCER: usize = 128;
const WIDTH: usize = 8;
const ROWS: usize = 1024;
const DENSITY: f64 = 0.01;

fn mix_matrix(i: usize) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(7000 + i as u64);
    CsrMatrix::from_coo(&CooMatrix::random_uniform(ROWS, ROWS, DENSITY, &mut rng))
}

fn registration_cost() {
    println!("-- registration: prepared-matrix cache on vs off --");
    let csr = mix_matrix(0);
    // every uncached iteration retains a prepared matrix in the engine's
    // handle map — keep the iteration budget small to bound memory
    let budget = BenchConfig {
        warmup: Duration::from_millis(30),
        measure: Duration::from_millis(200),
        min_iters: 5,
        max_iters: 200,
    };
    let uncached = SpmmEngine::native();
    let base = bench_fn_with("register (no cache)", budget, || {
        uncached.register(csr.clone()).expect("register");
    });
    println!("{}", base.line());
    let cached = SpmmEngine::native().with_prepared_cache(64 << 20);
    let warm = bench_fn_with("register (cache hit)", budget, || {
        cached.register(csr.clone()).expect("register");
    });
    println!(
        "{}  x{:.1} vs no cache  ({})",
        warm.line(),
        base.median_s() / warm.median_s(),
        cached.metrics.summary(),
    );
}

/// Push the fixed workload through a server with `workers` workers;
/// returns (completed, wallclock).
fn run_traffic(workers: usize) -> (u64, Duration) {
    let engine = Arc::new(SpmmEngine::serving(64 << 20, usize::MAX, 1));
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            workers,
            ..ServerConfig::default()
        },
    );
    let t0 = Instant::now();
    let ok = std::thread::scope(|s| {
        let joins: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let engine = engine.clone();
                let server = &server;
                s.spawn(move || {
                    let handles: Vec<_> = (0..MATRICES)
                        .map(|i| engine.register(mix_matrix(i)).expect("register"))
                        .collect();
                    let mut rng = Xoshiro256::seeded(7100 + p as u64);
                    let mut replies = Vec::with_capacity(REQUESTS_PER_PRODUCER);
                    for r in 0..REQUESTS_PER_PRODUCER {
                        let (rtx, rrx) = mpsc::channel();
                        server.submit(Request::spmm(
                            handles[r % handles.len()],
                            DenseMatrix::random(ROWS, WIDTH, 1.0, &mut rng),
                            (p * REQUESTS_PER_PRODUCER + r) as u64,
                            rtx,
                        ));
                        replies.push(rrx);
                    }
                    replies
                        .into_iter()
                        .filter(|rrx| {
                            matches!(
                                rrx.recv_timeout(Duration::from_secs(120)),
                                Ok(ServerReply::Ok(_))
                            )
                        })
                        .count() as u64
                })
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("producer panicked"))
            .sum::<u64>()
    });
    let elapsed = t0.elapsed();
    server.shutdown();
    (ok, elapsed)
}

fn main() {
    println!("== serving throughput (this machine) ==");
    registration_cost();
    println!(
        "\n-- server: {PRODUCERS} producers x {REQUESTS_PER_PRODUCER} requests, \
         {MATRICES} matrices ({ROWS}x{ROWS}, density {DENSITY}), n={WIDTH} --"
    );
    let mut base_rps = None;
    for workers in [1usize, 2, 4] {
        let (ok, elapsed) = run_traffic(workers);
        let rps = ok as f64 / elapsed.as_secs_f64().max(1e-9);
        let speedup = base_rps.map(|b: f64| rps / b).unwrap_or(1.0);
        base_rps.get_or_insert(rps);
        println!(
            "workers={workers}  completed={ok}  {elapsed:?}  {rps:.0} req/s  x{speedup:.2} vs 1 worker"
        );
    }
}
