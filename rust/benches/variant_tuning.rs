//! Tuner economics (ISSUE 9): what the budgeted successive-halving
//! search costs and what its winners buy back, on this machine.
//!
//! Two questions the variant registry raises that the canonical-four
//! default never had to answer:
//!
//! 1. **Search cost** — wallclock of `tune_variants` as the per-cell
//!    `--budget-ms` grows. Halving is sub-linear in the variant count
//!    (losers get small slices), so doubling the budget should much less
//!    than double the non-canonical discovery rate.
//! 2. **Selection quality** — with the winners installed, the per-bucket
//!    dispatch cost of the tuned policy vs always running each family's
//!    canonical point, measured directly (geomean of tuned/canonical
//!    medians over matrix × N cells; < 1.0 means tuning paid for itself).
//!
//! Supports `--json <path>` self-recording (see BENCHMARKS.md).

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::bench::harness::{bench_fn_with, BenchConfig};
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::bench::Table;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::{registry, SparseOp};
use ge_spmm::selector::measured::{tune_variants, MeasureConfig};
use ge_spmm::selector::online::feature_bucket;
use ge_spmm::selector::profile::ProfileVariant;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::json::{num, obj};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::stats;
use std::time::Instant;

const N_VALUES: [usize; 2] = [8, 32];
const D_VALUES: [usize; 1] = [16];
const BUDGETS_MS: [u64; 3] = [4, 12, 32];

fn suite(rng: &mut Xoshiro256) -> Vec<(&'static str, CsrMatrix)> {
    let uniform = CsrMatrix::from_coo(&CooMatrix::random_uniform(1024, 1024, 0.008, rng));
    let plaw = CsrMatrix::from_coo(
        &PowerLawConfig {
            rows: 1024,
            cols: 1024,
            alpha: 1.6,
            min_row: 1,
            max_row: 192,
        }
        .generate(rng),
    );
    let rmat = CsrMatrix::from_coo(&RmatConfig::new(9, 8.0).generate(rng));
    vec![("uniform", uniform), ("plaw", plaw), ("rmat", rmat)]
}

/// Median seconds of one variant (by label) on one prepared cell.
fn time_label(
    backend: &dyn SpmmBackend,
    operand: &ge_spmm::backend::PreparedOperand,
    x: &DenseMatrix,
    label: &str,
) -> f64 {
    let entry = registry()
        .by_label(SparseOp::Spmm, label)
        .expect("winner label resolves");
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(2),
        measure: std::time::Duration::from_millis(10),
        ..BenchConfig::default()
    };
    let stats = bench_fn_with(label, cfg, || {
        let exec = backend
            .execute_variant(operand, x, entry)
            .expect("quality-check execute");
        std::hint::black_box(&exec.y.data);
    });
    stats.median_s().max(1e-9)
}

fn main() {
    println!("== variant-tuning economics (this machine) ==");
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("variant_tuning").with_config(obj(vec![
                ("n_values", num(N_VALUES.len() as f64)),
                ("d_values", num(D_VALUES.len() as f64)),
                ("variants", num(registry().len() as f64)),
            ])),
        )
    });
    let mut rng = Xoshiro256::seeded(0x7e21);
    let named = suite(&mut rng);
    let matrices: Vec<CsrMatrix> = named.iter().map(|(_, m)| m.clone()).collect();
    let backend = NativeBackend::default();

    // 1. search cost vs budget
    let mut t = Table::new(&["budget/cell", "search s", "cells", "winners", "non-canonical"]);
    let mut last_winners: Vec<ProfileVariant> = Vec::new();
    let mut cases: Vec<(String, f64)> = Vec::new();
    for ms in BUDGETS_MS {
        let cfg = MeasureConfig::default().with_budget_ms(ms);
        let t0 = Instant::now();
        let report = tune_variants(&backend, &matrices, &N_VALUES, &D_VALUES, &cfg)
            .expect("tuning the bench suite");
        let took = t0.elapsed().as_secs_f64();
        t.row(vec![
            format!("{ms} ms"),
            format!("{took:.2}"),
            report.cells_timed.to_string(),
            report.winners.len().to_string(),
            report.non_canonical().to_string(),
        ]);
        cases.push((format!("search_s/budget_{ms}ms"), took));
        cases.push((
            format!("non_canonical/budget_{ms}ms"),
            report.non_canonical() as f64,
        ));
        last_winners = report.winners;
    }
    t.print();

    // 2. selection quality of the largest-budget winners: for every
    // (matrix, n) cell, the tuned winner of the cell's bucket vs the
    // family's canonical point, same family both sides — isolating what
    // the *generated* variants add over the four-kernel default.
    let mut ratios = Vec::new();
    let mut q = Table::new(&["cell", "family", "winner", "tuned/canonical"]);
    for (name, a) in &named {
        let operand = backend.prepare(a).expect("prepare");
        let features = MatrixFeatures::of(a);
        for &n in &N_VALUES {
            let x = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
            let bucket = feature_bucket(&features, n);
            for w in last_winners
                .iter()
                .filter(|w| w.op == SparseOp::Spmm && w.bucket == bucket)
            {
                let canonical = w.family.label();
                if w.label == canonical {
                    continue; // canonical won — nothing to compare
                }
                let tuned_s = time_label(&backend, &operand, &x, &w.label);
                let canon_s = time_label(&backend, &operand, &x, canonical);
                let ratio = tuned_s / canon_s;
                ratios.push(ratio);
                q.row(vec![
                    format!("{name}/n{n}"),
                    canonical.to_string(),
                    w.label.clone(),
                    format!("{ratio:.3}"),
                ]);
            }
        }
    }
    if ratios.is_empty() {
        println!(
            "every winner was canonical at the largest budget — the generated \
             variants bought nothing on this machine/suite (valid outcome; \
             recorded as quality ratio 1.0)"
        );
        ratios.push(1.0);
    } else {
        q.print();
    }
    let quality = stats::geomean(&ratios);
    println!(
        "geomean tuned/canonical ratio: {quality:.3} ({} non-canonical cells; < 1.0 = tuning won)",
        ratios.len()
    );
    cases.push(("geomean_tuned_over_canonical".to_string(), quality));

    if let Some((_, rec)) = record.as_mut() {
        for (name, v) in &cases {
            rec.push_value(name, *v, "");
        }
    }
    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
