//! Fig. 5: validation of the adaptive strategy (three panels).
//!
//!   left   — WB benefit at N=1 correlates negatively with avg_row
//!   middle — PR beats SR only at small N (crossover ≈ paper's N≤4 rule)
//!   right  — WB benefit at N=128 correlates with stdv/avg

use ge_spmm::bench::figures::{load_bench_matrices, sim_suite, N_SWEEP};
use ge_spmm::bench::Table;
use ge_spmm::sim::{GpuConfig, SimKernel};
use ge_spmm::util::stats;

fn bucket_table(
    label: &str,
    xs: &[f64],
    benefit: &[f64],
    buckets: &[(f64, f64)],
) {
    let mut t = Table::new(&[label, "matrices", "geomean WB benefit"]);
    for &(lo, hi) in buckets {
        let sel: Vec<f64> = (0..xs.len())
            .filter(|&i| xs[i] >= lo && xs[i] < hi)
            .map(|i| benefit[i])
            .collect();
        if !sel.is_empty() {
            t.row(vec![
                if hi > 1e8 {
                    format!("≥{lo}")
                } else {
                    format!("{lo}–{hi}")
                },
                sel.len().to_string(),
                format!("{:.2}×", stats::geomean(&sel)),
            ]);
        }
    }
    t.print();
}

fn main() {
    println!("== Fig 5: adaptive-strategy validation (rtx3090 model) ==");
    let gpu = GpuConfig::rtx3090();
    eprintln!("building collection …");
    let matrices = load_bench_matrices();

    println!("\n[left] WB benefit (PR-RS/PR-WB) at N=1 vs avg_row");
    let pr_rs = sim_suite(&matrices, SimKernel::PrRs, 1, &gpu);
    let pr_wb = sim_suite(&matrices, SimKernel::PrWb, 1, &gpu);
    let benefit1: Vec<f64> = pr_rs.iter().zip(&pr_wb).map(|(a, b)| a / b).collect();
    let avg: Vec<f64> = matrices.iter().map(|m| m.features.avg_row).collect();
    bucket_table("avg_row", &avg, &benefit1, &[(0.0, 4.0), (4.0, 12.0), (12.0, 40.0), (40.0, 1e9)]);
    println!(
        "spearman(avg_row, benefit) = {:.2} (paper: negative)",
        stats::spearman(&avg, &benefit1)
    );

    println!("\n[middle] SR/PR geomean across N (>1 ⇒ PR wins; paper: PR wins only small N)");
    let mut t = Table::new(&["N", "SR/PR"]);
    for n in N_SWEEP {
        let sr = sim_suite(&matrices, SimKernel::SrRs, n, &gpu);
        let pr = sim_suite(&matrices, SimKernel::PrRs, n, &gpu);
        let r: Vec<f64> = sr.iter().zip(&pr).map(|(s, p)| s / p).collect();
        t.row(vec![n.to_string(), format!("{:.2}×", stats::geomean(&r))]);
    }
    t.print();

    println!("\n[right] WB benefit (SR-RS/SR-WB) at N=128 vs stdv/avg");
    let sr_rs = sim_suite(&matrices, SimKernel::SrRs, 128, &gpu);
    let sr_wb = sim_suite(&matrices, SimKernel::SrWb, 128, &gpu);
    let benefit128: Vec<f64> = sr_rs.iter().zip(&sr_wb).map(|(a, b)| a / b).collect();
    let cv: Vec<f64> = matrices.iter().map(|m| m.features.cv_row).collect();
    bucket_table("stdv/avg", &cv, &benefit128, &[(0.0, 0.25), (0.25, 1.0), (1.0, 3.0), (3.0, 1e9)]);
    println!(
        "spearman(stdv/avg, benefit) = {:.2} (paper: positive)",
        stats::spearman(&cv, &benefit128)
    );
}
