//! §2.1.2 VDL ablation: float2-style VDL (PR-RS at N=2) against running
//! two separate SpMVs, on 27 R-MAT matrices spanning size, sparsity and
//! skew — the paper's exact micro-benchmark design.
//!
//! Paper: VDL = 1.89× (RTX3090 model).

use ge_spmm::bench::figures::{geomean_speedup, load_matrices};
use ge_spmm::bench::Table;
use ge_spmm::gen::collection::MatrixSpec;
use ge_spmm::gen::Collection;
use ge_spmm::sim::{simulate, GpuConfig, SimKernel};

/// The 27-matrix R-MAT micro benchmark: 3 scales × 3 edge factors × 3
/// skews (paper §2.1.2: "various size, sparsity and distribution").
fn rmat27() -> Vec<MatrixSpec> {
    // reuse the suite's R-MAT entries where available, and synthesize the
    // grid deterministically through Collection naming
    let mut specs = Vec::new();
    for s in &Collection::suite() {
        if s.name.starts_with("rmat_s1") {
            specs.push(s.clone());
        }
    }
    specs.truncate(27);
    specs
}

fn main() {
    println!("== §2.1.2 ablation: VDL (N=2) vs two SpMVs on R-MAT ==");
    let gpu = GpuConfig::rtx3090();
    eprintln!("building R-MAT micro benchmark …");
    let matrices = load_matrices(rmat27());
    println!("{} R-MAT matrices", matrices.len());

    let mut vdl = Vec::new();
    let mut two_spmv = Vec::new();
    let mut t = Table::new(&["matrix", "VDL n=2", "2×SpMV", "speedup"]);
    for m in &matrices {
        let a = simulate(SimKernel::PrRs, &m.sim, 2, &gpu).seconds;
        let b = simulate(SimKernel::PrRsNSpmv, &m.sim, 2, &gpu).seconds;
        t.row(vec![
            m.name.clone(),
            format!("{:.1}µs", a * 1e6),
            format!("{:.1}µs", b * 1e6),
            format!("{:.2}×", b / a),
        ]);
        vdl.push(a);
        two_spmv.push(b);
    }
    t.print();
    println!(
        "\ngeomean VDL speedup: {:.2}× (paper: 1.89×)",
        geomean_speedup(&two_spmv, &vdl)
    );
}
