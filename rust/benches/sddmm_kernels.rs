//! Wallclock benchmark of the native SDDMM kernels — the second sparse
//! op's 2×2 design space measured on this machine, the SDDMM companion
//! of `native_kernels`. Feeds DESIGN.md §SDDMM (recording convention in
//! BENCHMARKS.md; supports `--json <path>` self-recording).

use ge_spmm::bench::harness::bench_fn;
use ge_spmm::bench::record::{json_path_arg, BenchRecord};
use ge_spmm::gen::Collection;
use ge_spmm::kernels::{KernelKind, WARP};
use ge_spmm::sddmm;
use ge_spmm::sparse::{DenseMatrix, SegmentedMatrix};
use ge_spmm::util::json::{num, obj, Json};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::threadpool::ThreadPool;

fn main() {
    println!("== native SDDMM kernel wallclock (this machine) ==");
    let pool = ThreadPool::default_parallel();
    println!("threads: {}", pool.workers());
    let d_values = [4usize, 16, 32, 128];
    let mut record = json_path_arg().map(|path| {
        (
            path,
            BenchRecord::new("sddmm_kernels").with_config(obj(vec![
                ("threads", num(pool.workers() as f64)),
                (
                    "d_values",
                    Json::Arr(d_values.iter().map(|&d| num(d as f64)).collect()),
                ),
            ])),
        )
    });
    let specs: Vec<_> = ["uniform_s12_e8", "rmat_s12_e8_g500", "band_n16384_b8"]
        .iter()
        .filter_map(|n| Collection::suite().into_iter().find(|s| &s.name == n))
        .collect();
    for spec in specs {
        let csr = spec.build();
        // Same prepared layouts NativeBackend builds, hand-held so the
        // timed region is the kernel alone (no output allocation).
        let segments = SegmentedMatrix::from_csr(&csr, WARP);
        println!(
            "\n--- {} ({}x{}, nnz {}) ---",
            spec.name,
            csr.rows,
            csr.cols,
            csr.nnz()
        );
        for d in d_values {
            let mut rng = Xoshiro256::seeded(7);
            let u = DenseMatrix::random(csr.rows, d, 1.0, &mut rng);
            let v = DenseMatrix::random(csr.cols, d, 1.0, &mut rng);
            let mut out = vec![0f32; csr.nnz()];
            let flops = 2.0 * csr.nnz() as f64 * d as f64;
            for kind in KernelKind::ALL {
                let s = bench_fn(&format!("{} d={d} {}", spec.name, kind.label()), || {
                    sddmm::run(kind, &csr, &segments, &u, &v, &mut out, &pool);
                });
                println!("{}  ({:.2} GFLOP/s)", s.line(), flops / s.median_s() / 1e9);
                if let Some((_, rec)) = record.as_mut() {
                    rec.push_latency(&s);
                    rec.push_value(
                        &format!("{} throughput", s.name),
                        flops / s.median_s() / 1e9,
                        "GFLOP/s",
                    );
                }
            }
        }
    }
    if let Some((path, rec)) = record {
        rec.save(&path).expect("writing bench record");
        println!("wrote {}", path.display());
    }
}
