//! API-compatible stub of the `xla` PJRT binding.
//!
//! The real crate wraps libxla (PJRT CPU client, HLO parsing, compiled
//! executables). That native library is not available in this build
//! environment, so this stub provides the exact API surface the `ge-spmm`
//! crate uses behind its `pjrt` feature:
//!
//! - **Host-side [`Literal`] operations work for real** (construction,
//!   reshape, shape queries, element readback) — they are plain Rust data
//!   manipulation, so code and tests touching only literals behave
//!   identically to the real binding.
//! - **Client / compile / execute operations fail fast** with
//!   [`Error::Unavailable`]: [`PjRtClient::cpu`] errors immediately, so no
//!   artifact path can be reached at runtime.
//!
//! Replacing this directory with the real binding (same crate name) enables
//! actual artifact execution without touching the `ge-spmm` sources.

use std::fmt;

/// Errors surfaced by the stub. Mirrors the shape of the real crate's
/// error enough for `anyhow` interop (`Display + std::error::Error`).
#[derive(Debug)]
pub enum Error {
    /// Operation needs libxla, which this stub does not link.
    Unavailable(String),
    /// Host-side usage error (shape mismatch, wrong element type, ...).
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(m) => write!(f, "xla stub: {m}"),
            Error::Usage(m) => write!(f, "xla stub usage error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::Unavailable(format!(
        "{what} requires libxla, which is not linked in this build \
         (vendor/xla is an API stub — see DESIGN.md §Substitutions)"
    ))
}

/// Element types the coordinator exchanges with artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Internal element storage. Public only because [`NativeType`] mentions
/// it; not part of the emulated API surface.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Array shape of a literal: element type + dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Element types that can move between host vectors and literals.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(Error::Usage("literal holds i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error::Usage("literal holds f32, asked for i32".into())),
        }
    }
}

/// A host-resident tensor value — fully functional in the stub.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Same elements under a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error::Usage(format!(
                "reshape {:?} -> {:?} changes element count",
                self.dims, dims
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape {
            ty,
            dims: self.dims.clone(),
        })
    }

    /// Read elements back to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Decompose a tuple literal. Stub literals are never tuples (tuples
    /// only come back from executions, which the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Usage("stub literal is not a tuple".into()))
    }
}

/// Parsed HLO module. The stub only retains the source text.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file. Parsing/validation needs libxla, so the stub
    /// only checks the file is readable.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Usage(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation handle built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Construction always fails in the stub, so the
/// unreachable methods below exist purely to satisfy the type checker.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client — unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — unreachable (no client can exist).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable — unreachable in the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed literal arguments.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer — unreachable in the stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.array_shape().unwrap().ty(), ElementType::S32);
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("libxla"));
    }
}
