//! Minimal JSON value model, parser and writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by the
//! Python AOT path and read by the Rust runtime) and for bench reports.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers are `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (rejects negatives / non-integers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..(n * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: `Json::Num`.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Convenience: `Json::Str`.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse error with byte position.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"x",true,null],"m":{"n":-7}}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::Str("quote \" slash \\ newline \n tab \t".into());
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "b": false}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
