//! Deterministic pseudo-random number generation.
//!
//! All experiments in this repo are seeded so that every figure, table and
//! test is exactly reproducible. Two generators are provided:
//!
//! - [`SplitMix64`] — tiny, used for seeding and for shrink-free property
//!   tests.
//! - [`Xoshiro256`] — xoshiro256**, the workhorse generator for matrix
//!   synthesis and workload generation.

/// SplitMix64 (Steele et al.). Mainly used to expand a single `u64` seed
/// into the larger state of [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion, per the xoshiro authors' guidance.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit mantissa fill).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)`. Uses Lemire's multiply-shift trick
    /// with rejection to avoid modulo bias.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a slice with uniform values in `[-scale, scale)`.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = (self.next_f32() * 2.0 - 1.0) * scale;
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_reference_stream_differs_by_seed() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams from different seeds should diverge");
    }

    #[test]
    fn below_respects_bound_and_hits_all_values() {
        let mut r = Xoshiro256::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should occur");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Xoshiro256::seeded(4);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seeded(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "gaussian var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(6);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_has_no_duplicates() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..50 {
            let s = r.sample_distinct(50, 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&i| i < 50));
        }
    }
}
