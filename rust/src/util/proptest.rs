//! Seeded property-testing harness (proptest is not vendored).
//!
//! A property test here is a function from a [`Gen`] (seeded generator with
//! size hints) to `Result<(), String>`. The runner executes `cases`
//! iterations with growing size; on failure it retries the same seed with
//! progressively smaller size bounds — a cheap shrinking strategy that in
//! practice localizes failures to small matrices.
//!
//! ```no_run
//! # // no_run: doctest binaries lack the libxla rpath in this container
//! use ge_spmm::util::proptest::{run_prop, Gen};
//! run_prop("addition commutes", 64, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::prng::Xoshiro256;

/// Generator handed to property bodies: a seeded PRNG plus the current
/// "size" used to bound generated structures.
pub struct Gen {
    rng: Xoshiro256,
    size: usize,
}

impl Gen {
    /// Current size bound (grows with the case index).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Uniform i64 in `[lo, hi]`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// A "dimension": 1..=size (never zero) — handy for matrix shapes.
    pub fn dim(&mut self) -> usize {
        self.usize_in(1, self.size.max(1) + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f32 in `[-1, 1)`, the typical kernel-value distribution.
    pub fn value(&mut self) -> f32 {
        self.rng.next_f32() * 2.0 - 1.0
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }

    /// Vector of `len` f32 values in `[-1, 1)`.
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.value()).collect()
    }

    /// Access the underlying PRNG (for generator modules that take one).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Result of a property run, for introspection in tests of the harness
/// itself.
#[derive(Debug)]
pub struct PropReport {
    pub cases_run: usize,
    pub failure: Option<PropFailure>,
}

/// Details of the minimal observed failure.
#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run a property for `cases` iterations. Panics with a reproduction line
/// on failure. Sizes ramp from 2 to 64 across the run.
pub fn run_prop<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let report = run_prop_with_seed(name, 0xC0FFEE ^ hash_name(name), cases, &prop);
    if let Some(f) = report.failure {
        panic!(
            "property '{name}' failed (seed={:#x}, size={}): {}",
            f.seed, f.size, f.message
        );
    }
}

/// Like [`run_prop`] but returns the report instead of panicking, and takes
/// an explicit base seed. Used internally and by the harness's own tests.
pub fn run_prop_with_seed<F>(_name: &str, base_seed: u64, cases: usize, prop: &F) -> PropReport
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 2 + (case * 62) / cases.max(1); // ramp 2..=64
        if let Err(msg) = run_one(seed, size, prop) {
            // Shrink: retry the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut min_size = size;
            let mut min_msg = msg;
            let mut s = size / 2;
            while s >= 2 {
                match run_one(seed, s, prop) {
                    Err(m) => {
                        min_size = s;
                        min_msg = m;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            return PropReport {
                cases_run: case + 1,
                failure: Some(PropFailure {
                    seed,
                    size: min_size,
                    message: min_msg,
                }),
            };
        }
    }
    PropReport {
        cases_run: cases,
        failure: None,
    }
}

fn run_one<F>(seed: u64, size: usize, prop: &F) -> Result<(), String>
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Xoshiro256::seeded(seed),
        size,
    };
    prop(&mut g)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, good enough to decorrelate test seeds.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are elementwise close with mixed abs/rel
/// tolerance; reports the worst offender. Shared by kernel tests.
pub fn assert_close(actual: &[f32], expect: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if actual.len() != expect.len() {
        return Err(format!(
            "length mismatch: {} vs {}",
            actual.len(),
            expect.len()
        ));
    }
    let mut worst = (0usize, 0.0f32);
    for i in 0..actual.len() {
        let diff = (actual[i] - expect[i]).abs();
        let tol = atol + rtol * expect[i].abs();
        let excess = diff - tol;
        if excess > worst.1 {
            worst = (i, excess);
        }
    }
    if worst.1 > 0.0 {
        let i = worst.0;
        Err(format!(
            "mismatch at [{i}]: actual={} expected={} (|diff|={}, atol={atol}, rtol={rtol})",
            actual[i],
            expect[i],
            (actual[i] - expect[i]).abs()
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = run_prop_with_seed("ok", 1, 50, &|g: &mut Gen| {
            let v = g.usize_in(0, 10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(r.cases_run, 50);
        assert!(r.failure.is_none());
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        // Fails whenever size >= 8; shrinking should walk below the first
        // failing size.
        let r = run_prop_with_seed("bad", 2, 100, &|g: &mut Gen| {
            if g.size() >= 8 {
                Err(format!("size {}", g.size()))
            } else {
                Ok(())
            }
        });
        let f = r.failure.expect("must fail");
        assert!(f.size >= 8, "shrunk below the failure threshold: {}", f.size);
        assert!(f.size <= 16, "shrink did not reduce size: {}", f.size);
    }

    #[test]
    fn gen_ranges_hold() {
        let r = run_prop_with_seed("ranges", 3, 200, &|g: &mut Gen| {
            let d = g.dim();
            if d == 0 || d > 65 {
                return Err(format!("dim {d}"));
            }
            let x = g.f64_in(-2.0, 3.0);
            if !(-2.0..3.0).contains(&x) {
                return Err(format!("f64 {x}"));
            }
            let v = g.value();
            if !(-1.0..1.0).contains(&v) {
                return Err(format!("value {v}"));
            }
            Ok(())
        });
        assert!(r.failure.is_none(), "{:?}", r.failure);
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0, 2.1], &[1.0, 2.0], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
        // rel tolerance scales with magnitude
        assert!(assert_close(&[1000.1], &[1000.0], 0.0, 1e-3).is_ok());
    }

    #[test]
    fn determinism_same_seed_same_failure() {
        let prop = |g: &mut Gen| -> Result<(), String> {
            let v = g.usize_in(0, 1000);
            if v > 900 {
                Err(format!("{v}"))
            } else {
                Ok(())
            }
        };
        let a = run_prop_with_seed("det", 42, 500, &prop);
        let b = run_prop_with_seed("det", 42, 500, &prop);
        match (a.failure, b.failure) {
            (Some(x), Some(y)) => {
                assert_eq!(x.seed, y.seed);
                assert_eq!(x.message, y.message);
            }
            (None, None) => {}
            _ => panic!("nondeterministic outcome"),
        }
    }
}
