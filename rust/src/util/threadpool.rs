//! A small fixed-size thread pool with scoped parallel-for.
//!
//! The vendored registry has neither rayon nor tokio, so the native kernels
//! and the simulator parallelize through this pool. It provides:
//!
//! - [`ThreadPool::scope_chunks`] — parallel iteration over index ranges
//!   (static chunking), the shape every kernel here needs;
//! - [`ThreadPool::run_dynamic`] — dynamic work-stealing-lite via an atomic
//!   cursor, for irregular workloads (e.g. skewed rows).
//!
//! Work items borrow from the caller's stack via `std::thread::scope`-style
//! lifetimes: we spawn the pool threads lazily per call using scoped
//! threads, which keeps the implementation safe without `unsafe`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread pool facade. Threads are scoped per call (cheap at the sizes used
/// here: kernel invocations are >100µs), so the pool is just a worker-count
/// policy object and can be freely cloned.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// Pool with an explicit worker count (min 1).
    pub fn new(workers: usize) -> Self {
        Self {
            workers: workers.max(1),
        }
    }

    /// Pool sized to available parallelism.
    pub fn default_parallel() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    /// Serial pool (useful to A/B threading in benches).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Threshold below which parallelism does not pay: scoped threads are
    /// spawned per call (~tens of µs for a full pool), so small kernels
    /// run serially (§Perf).
    pub const SERIAL_WORK_THRESHOLD: usize = 1 << 18;

    /// A pool sized for `work` abstract units (≈ flops/bytes touched):
    /// serial below the threshold, `self` otherwise.
    pub fn for_work(&self, work: usize) -> ThreadPool {
        if work < Self::SERIAL_WORK_THRESHOLD {
            ThreadPool::serial()
        } else {
            *self
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `body(range)` over `0..n` split into contiguous chunks, one chunk
    /// stream per worker. `body` must be `Sync` (called concurrently).
    ///
    /// Chunks are statically assigned: worker `w` gets chunk indices
    /// `w, w+W, w+2W, ...` of size `chunk`.
    pub fn scope_chunks<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let nchunks = n.div_ceil(chunk);
        if self.workers == 1 || nchunks == 1 {
            for c in 0..nchunks {
                let lo = c * chunk;
                body(lo..(lo + chunk).min(n));
            }
            return;
        }
        let workers = self.workers.min(nchunks);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let body = &body;
                scope.spawn(move || {
                    let mut c = w;
                    while c < nchunks {
                        let lo = c * chunk;
                        body(lo..(lo + chunk).min(n));
                        c += workers;
                    }
                });
            }
        });
    }

    /// Dynamic scheduling: workers repeatedly claim the next `chunk`-sized
    /// slice of `0..n` from a shared atomic cursor. Use when per-item cost
    /// is highly skewed (the exact situation the paper's workload-balanced
    /// kernels address on the GPU).
    pub fn run_dynamic<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        if self.workers == 1 {
            let mut lo = 0;
            while lo < n {
                body(lo..(lo + chunk).min(n));
                lo += chunk;
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                let body = &body;
                let cursor = &cursor;
                scope.spawn(move || loop {
                    let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if lo >= n {
                        break;
                    }
                    body(lo..(lo + chunk).min(n));
                });
            }
        });
    }

    /// Map over disjoint mutable output chunks: splits `out` into
    /// `chunk`-row pieces (rows of width `width`) and calls
    /// `body(first_row, rows_slice)` in parallel. This is the safe pattern
    /// for "each worker writes its own rows" kernels.
    pub fn for_each_row_chunk<T, F>(&self, out: &mut [T], width: usize, chunk_rows: usize, body: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(width > 0, "row width must be positive");
        assert_eq!(out.len() % width, 0, "output not a whole number of rows");
        let chunk_rows = chunk_rows.max(1);
        if self.workers == 1 {
            for (c, rows) in out.chunks_mut(chunk_rows * width).enumerate() {
                body(c * chunk_rows, rows);
            }
            return;
        }
        std::thread::scope(|scope| {
            // Hand contiguous row blocks to scoped threads round-robin.
            let mut pieces: Vec<(usize, &mut [T])> = Vec::new();
            for (c, rows) in out.chunks_mut(chunk_rows * width).enumerate() {
                pieces.push((c * chunk_rows, rows));
            }
            let nworkers = self.workers.min(pieces.len().max(1));
            let queue: Vec<Vec<(usize, &mut [T])>> = split_round_robin(pieces, nworkers);
            for worker_items in queue {
                let body = &body;
                scope.spawn(move || {
                    for (first_row, rows) in worker_items {
                        body(first_row, rows);
                    }
                });
            }
        });
    }
}

fn split_round_robin<T>(items: Vec<T>, ways: usize) -> Vec<Vec<T>> {
    let mut out: Vec<Vec<T>> = (0..ways).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        out[i % ways].push(item);
    }
    out
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::default_parallel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn scope_chunks_covers_every_index_once() {
        let n = 1003;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(4).scope_chunks(n, 17, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_dynamic_covers_every_index_once() {
        let n = 997;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(8).run_dynamic(n, 13, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn serial_pool_matches_parallel_result() {
        let n = 256;
        let sum_with = |pool: ThreadPool| {
            let acc = AtomicU64::new(0);
            pool.scope_chunks(n, 10, |r| {
                let local: u64 = r.map(|i| i as u64).sum();
                acc.fetch_add(local, Ordering::Relaxed);
            });
            acc.load(Ordering::Relaxed)
        };
        assert_eq!(sum_with(ThreadPool::serial()), sum_with(ThreadPool::new(6)));
    }

    #[test]
    fn for_each_row_chunk_writes_disjoint_rows() {
        let rows = 37;
        let width = 8;
        let mut out = vec![0u32; rows * width];
        ThreadPool::new(4).for_each_row_chunk(&mut out, width, 5, |first_row, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (first_row + i / width) as u32;
            }
        });
        for r in 0..rows {
            for c in 0..width {
                assert_eq!(out[r * width + c], r as u32);
            }
        }
    }

    #[test]
    fn zero_items_is_noop() {
        ThreadPool::new(4).scope_chunks(0, 8, |_| panic!("should not run"));
        ThreadPool::new(4).run_dynamic(0, 8, |_| panic!("should not run"));
    }
}
