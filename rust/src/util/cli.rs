//! Declarative command-line argument parsing (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, and auto-generated `--help`. Just enough for the `ge-spmm`
//! binary, the examples and the bench harness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// If `true` the option is a boolean flag (no value).
    pub is_flag: bool,
    /// Default value (rendered in help); `None` means required-if-queried.
    pub default: Option<&'static str>,
}

/// A parsed argument set.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Get an option value as string (falling back to the spec default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Get with default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Get parsed as `T`.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Get parsed as `usize`, clamped to at least 1 — for count-like
    /// options (shard counts, worker counts) where 0 is never meaningful.
    pub fn parse_positive(&self, name: &str, default: usize) -> usize {
        self.parse_or(name, default).max(1)
    }

    /// Whether a boolean flag is set.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Parse a comma-separated list of `T`.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

/// A command (or subcommand) definition.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// New command with no options.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Add a valued option.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default,
        });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            default: None,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}\n", self.name, self.about);
        let _ = writeln!(out, "OPTIONS:");
        for o in &self.opts {
            let meta = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <value>", o.name)
            };
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(out, "  {meta:<28} {}{default}", o.help);
        }
        out
    }

    /// Parse raw tokens (no program name). On `--help`, returns
    /// `Err(CliError::Help(text))` so callers can print and exit(0).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(CliError::Help(self.help()));
            }
            if let Some(body) = t.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::Unknown(key.to_string()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError::FlagWithValue(key.to_string()));
                    }
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.to_string()))?
                        }
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

/// CLI parse failures.
#[derive(Debug)]
pub enum CliError {
    /// `--help` requested; payload is the rendered help text.
    Help(String),
    Unknown(String),
    MissingValue(String),
    FlagWithValue(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(h) => write!(f, "{h}"),
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::FlagWithValue(k) => write!(f, "flag --{k} does not take a value"),
        }
    }
}

impl std::error::Error for CliError {}

/// Split `std::env::args()` into `(subcommand, rest)`; `None` if no
/// subcommand was given.
pub fn split_subcommand(mut argv: Vec<String>) -> (Option<String>, Vec<String>) {
    if argv.is_empty() {
        return (None, argv);
    }
    let first = argv.remove(0);
    if first.starts_with('-') {
        argv.insert(0, first);
        (None, argv)
    } else {
        (Some(first), argv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("bench", "run benches")
            .opt("gpu", "GPU model", Some("v100"))
            .opt("n", "dense width", Some("32"))
            .flag("verbose", "print more")
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&toks(&[])).unwrap();
        assert_eq!(a.get("gpu"), Some("v100"));
        assert_eq!(a.parse_or("n", 0usize), 32);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = cmd().parse(&toks(&["--gpu", "rtx3090", "--n=64"])).unwrap();
        assert_eq!(a.get("gpu"), Some("rtx3090"));
        assert_eq!(a.parse_or("n", 0usize), 64);
    }

    #[test]
    fn parse_positive_clamps_zero_and_garbage() {
        let c = Command::new("t", "t").opt("shards", "row shards", Some("1"));
        assert_eq!(c.parse(&toks(&[])).unwrap().parse_positive("shards", 1), 1);
        assert_eq!(
            c.parse(&toks(&["--shards", "4"])).unwrap().parse_positive("shards", 1),
            4
        );
        assert_eq!(
            c.parse(&toks(&["--shards", "0"])).unwrap().parse_positive("shards", 1),
            1
        );
        assert_eq!(
            c.parse(&toks(&["--shards", "nope"])).unwrap().parse_positive("shards", 3),
            3
        );
    }

    #[test]
    fn flags_and_positionals() {
        let a = cmd().parse(&toks(&["--verbose", "input.mtx"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["input.mtx".to_string()]);
    }

    #[test]
    fn errors() {
        assert!(matches!(
            cmd().parse(&toks(&["--nope"])),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            cmd().parse(&toks(&["--gpu"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(
            cmd().parse(&toks(&["--verbose=yes"])),
            Err(CliError::FlagWithValue(_))
        ));
        assert!(matches!(
            cmd().parse(&toks(&["--help"])),
            Err(CliError::Help(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let a = cmd().parse(&toks(&["--n", "1,2,4, 8"])).unwrap();
        assert_eq!(a.parse_list("n", &[0usize]), vec![1, 2, 4, 8]);
        let b = cmd().parse(&toks(&[])).unwrap();
        assert_eq!(b.parse_list("missing", &[7usize]), vec![7]);
    }

    #[test]
    fn subcommand_split() {
        let (sub, rest) = split_subcommand(toks(&["bench", "--gpu", "v100"]));
        assert_eq!(sub.as_deref(), Some("bench"));
        assert_eq!(rest.len(), 2);
        let (none, rest2) = split_subcommand(toks(&["--gpu", "v100"]));
        assert!(none.is_none());
        assert_eq!(rest2.len(), 2);
    }
}
