//! Timing helpers for the bench harness and ad-hoc profiling.

use std::time::{Duration, Instant};

/// Measure the wall time of `f`, returning `(result, elapsed)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A named stopwatch that accumulates across start/stop pairs.
/// Used by the coordinator's metrics and in profiling examples.
#[derive(Debug)]
pub struct Stopwatch {
    name: String,
    total: Duration,
    laps: u64,
    started: Option<Instant>,
}

impl Stopwatch {
    /// New stopped stopwatch.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            total: Duration::ZERO,
            laps: 0,
            started: None,
        }
    }

    /// Begin a lap. Panics if already running.
    pub fn start(&mut self) {
        assert!(self.started.is_none(), "stopwatch '{}' already running", self.name);
        self.started = Some(Instant::now());
    }

    /// End the current lap. Panics if not running.
    pub fn stop(&mut self) {
        let s = self
            .started
            .take()
            .unwrap_or_else(|| panic!("stopwatch '{}' not running", self.name));
        self.total += s.elapsed();
        self.laps += 1;
    }

    /// Time a closure as one lap.
    pub fn lap<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        self.total
    }

    /// Number of completed laps.
    pub fn laps(&self) -> u64 {
        self.laps
    }

    /// Mean lap duration (zero if no laps).
    pub fn mean(&self) -> Duration {
        if self.laps == 0 {
            Duration::ZERO
        } else {
            self.total / self.laps as u32
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: total {:?} over {} laps (mean {:?})",
            self.name,
            self.total,
            self.laps,
            self.mean()
        )
    }
}

/// Format a duration in adaptive units (ns/µs/ms/s), e.g. for bench tables.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value_and_positive_time() {
        let (v, d) = time_it(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(d >= Duration::ZERO);
    }

    #[test]
    fn stopwatch_accumulates_laps() {
        let mut sw = Stopwatch::new("t");
        for _ in 0..3 {
            sw.lap(|| std::hint::black_box((0..100).sum::<u64>()));
        }
        assert_eq!(sw.laps(), 3);
        assert!(sw.total() >= sw.mean());
        assert!(sw.summary().contains("3 laps"));
    }

    #[test]
    #[should_panic(expected = "already running")]
    fn double_start_panics() {
        let mut sw = Stopwatch::new("x");
        sw.start();
        sw.start();
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
