//! Cache-line/vector aligned f32 buffers.
//!
//! `Vec<f32>` guarantees only 4-byte alignment, so an 8-lane f32 tile
//! load can straddle a cache line (and, without padding, a dense-matrix
//! row boundary). [`AlignedBuf`] allocates at [`ALIGN`]-byte alignment —
//! enough for any current vector ISA — and `sparse::AlignedDense` builds
//! the padded-stride dense layout on top of it.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Allocation alignment in bytes (one x86 cache line; covers AVX-512's
/// 64-byte vectors and everything smaller).
pub const ALIGN: usize = 64;

/// A heap `[f32]` aligned to [`ALIGN`] bytes, zero-initialized.
pub struct AlignedBuf {
    ptr: NonNull<f32>,
    len: usize,
}

// SAFETY: AlignedBuf uniquely owns its allocation; f32 is Send + Sync.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Zero-filled buffer of `len` floats. `len == 0` allocates nothing.
    pub fn zeros(len: usize) -> Self {
        if len == 0 {
            return Self {
                ptr: NonNull::dangling(),
                len: 0,
            };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0).
        let raw = unsafe { alloc_zeroed(layout) } as *mut f32;
        let ptr = NonNull::new(raw).unwrap_or_else(|| handle_alloc_error(layout));
        Self { ptr, len }
    }

    /// Number of floats.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn layout(len: usize) -> Layout {
        Layout::from_size_align(len * std::mem::size_of::<f32>(), ALIGN)
            .expect("aligned buffer layout overflow")
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `zeros` with the identical layout.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl Deref for AlignedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: ptr/len describe one live, properly aligned allocation
        // (or a dangling ptr with len 0, which is a valid empty slice).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as above, plus &mut self guarantees exclusivity.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let mut out = Self::zeros(self.len);
        out.copy_from_slice(self);
        out
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf(len={}, align={})", self.len, ALIGN)
    }
}

impl PartialEq for AlignedBuf {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_and_zero_init() {
        for len in [1usize, 7, 8, 63, 64, 1000] {
            let b = AlignedBuf::zeros(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "len={len}");
            assert!(b.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn empty_buffer_is_a_valid_slice() {
        let b = AlignedBuf::zeros(0);
        assert!(b.is_empty());
        assert_eq!(&b[..], &[] as &[f32]);
        let c = b.clone();
        assert_eq!(b, c);
    }

    #[test]
    fn write_read_clone() {
        let mut b = AlignedBuf::zeros(10);
        for (i, v) in b.iter_mut().enumerate() {
            *v = i as f32;
        }
        let c = b.clone();
        assert_eq!(b, c);
        assert_eq!(c[9], 9.0);
    }

    #[test]
    fn threads_can_share_it() {
        let b = AlignedBuf::zeros(128);
        std::thread::scope(|s| {
            s.spawn(|| assert_eq!(b[0], 0.0));
            s.spawn(|| assert_eq!(b[127], 0.0));
        });
    }
}
