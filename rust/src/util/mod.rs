//! Shared substrates: PRNG, statistics, JSON, CLI parsing, thread pool,
//! timers, aligned buffers, and the property-test harness.
//!
//! The offline build environment vendors only `xla` and `anyhow`, so the
//! conveniences a production crate would pull from crates.io (rayon, clap,
//! criterion, proptest, serde_json) are implemented here from scratch, each
//! scoped to exactly what this project needs.

pub mod aligned;
pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod threadpool;
pub mod timer;
