//! Small statistics helpers used by feature extraction, the selector
//! calibration, and the benchmark harness.

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0 for slices of length < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (`stddev / mean`); 0 when the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Quantile with linear interpolation, `q` in `[0, 1]`.
/// Sorts a copy; fine for the sizes used here.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Geometric mean; ignores non-positive entries (they would be -inf in
/// log space). Returns 0 if nothing remains. The paper's speedup summaries
/// are geometric means over the benchmark suite.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Gini coefficient of a non-negative distribution — used as an auxiliary
/// row-imbalance feature (0 = perfectly balanced, →1 = maximally skewed).
pub fn gini(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total: f64 = v.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    // G = (2 * sum_i i*x_(i) ) / (n * sum x) - (n+1)/n, with i starting at 1.
    let weighted: f64 = v.iter().enumerate().map(|(i, x)| (i as f64 + 1.0) * x).sum();
    (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
}

/// Pearson correlation coefficient. Returns 0 if either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// Spearman rank correlation (Pearson over ranks, average ranks for ties).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg_rank;
        }
        i = j + 1;
    }
    out
}

/// Simple online histogram with fixed log-spaced bin edges; used in bench
/// reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
}

impl Histogram {
    /// Log-spaced bins between `lo` and `hi` (both > 0).
    pub fn log_spaced(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && bins > 0);
        let ratio = (hi / lo).powf(1.0 / bins as f64);
        let mut edges = Vec::with_capacity(bins + 1);
        let mut e = lo;
        for _ in 0..=bins {
            edges.push(e);
            e *= ratio;
        }
        Self {
            counts: vec![0; bins + 2], // underflow + bins + overflow
            edges,
        }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let nbins = self.edges.len() - 1;
        if x < self.edges[0] {
            self.counts[0] += 1;
        } else if x >= self.edges[nbins] {
            self.counts[nbins + 1] += 1;
        } else {
            // binary search for the bin
            let mut lo = 0;
            let mut hi = nbins;
            while hi - lo > 1 {
                let mid = (lo + hi) / 2;
                if x < self.edges[mid] {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            self.counts[lo + 1] += 1;
        }
    }

    /// (bin lower edge, count) pairs, including under/overflow as
    /// `-inf`/last-edge pseudo bins when non-empty.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let nbins = self.edges.len() - 1;
        let mut out = Vec::new();
        if self.counts[0] > 0 {
            out.push((f64::NEG_INFINITY, self.counts[0]));
        }
        for b in 0..nbins {
            out.push((self.edges[b], self.counts[b + 1]));
        }
        if self.counts[nbins + 1] > 0 {
            out.push((self.edges[nbins], self.counts[nbins + 1]));
        }
        out
    }

    /// Total observations recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
        assert!((cv(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[3.0]), 0.0);
        assert_eq!(cv(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(gini(&[]), 0.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 0.5];
        assert!((geomean(&xs) - 1.0).abs() < 1e-12);
        // non-positive entries are ignored
        assert!((geomean(&[4.0, 0.0, -1.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn gini_extremes() {
        assert!(gini(&[1.0, 1.0, 1.0, 1.0]).abs() < 1e-12);
        let skewed = gini(&[0.0, 0.0, 0.0, 100.0]);
        assert!(skewed > 0.7, "gini of fully-concentrated dist: {skewed}");
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0; 4]), 0.0);
    }

    #[test]
    fn spearman_is_rank_based() {
        // monotone but nonlinear -> spearman 1, pearson < 1
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!(pearson(&xs, &ys) < 1.0);
    }

    #[test]
    fn histogram_bins_and_overflow() {
        let mut h = Histogram::log_spaced(1.0, 100.0, 4);
        for x in [0.5, 1.5, 15.0, 99.0, 200.0] {
            h.record(x);
        }
        assert_eq!(h.total(), 5);
        let rows = h.rows();
        assert!(rows[0].0.is_infinite()); // underflow present
        assert_eq!(rows.last().unwrap().1, 1); // overflow count
    }
}
