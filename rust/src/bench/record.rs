//! `BENCH_*.json` record emission — the machine side of the recording
//! convention documented in `BENCHMARKS.md`.
//!
//! Wallclock benches accept `--json <path>` (after `cargo bench --bench
//! <target> --`) and write their results through [`BenchRecord`] instead
//! of asking the operator to transcribe stdout by hand. Records are
//! committed at the repository root as `BENCH_<target>_<YYYYMMDD>.json`.

use super::harness::BenchStats;
use crate::util::json::{num, obj, s, Json};
use std::path::Path;

/// Builder for one bench-run record.
pub struct BenchRecord {
    bench: String,
    config: Json,
    results: Vec<Json>,
    notes: String,
}

impl BenchRecord {
    /// Start a record for bench target `bench`.
    pub fn new(bench: &str) -> Self {
        Self {
            bench: bench.to_string(),
            config: Json::Obj(Default::default()),
            results: Vec::new(),
            notes: String::new(),
        }
    }

    /// Attach the bench's configuration object.
    pub fn with_config(mut self, config: Json) -> Self {
        self.config = config;
        self
    }

    /// Free-text notes (thermal state, anomalies, …).
    pub fn set_notes(&mut self, notes: &str) {
        self.notes = notes.to_string();
    }

    /// Record a latency-style case from harness stats (unit `"s"`).
    pub fn push_latency(&mut self, stats: &BenchStats) {
        self.results.push(obj(vec![
            ("name", s(&stats.name)),
            ("median_s", num(stats.median.as_secs_f64())),
            ("p10_s", num(stats.p10.as_secs_f64())),
            ("p90_s", num(stats.p90.as_secs_f64())),
            ("unit", s("s")),
        ]));
    }

    /// Record a headline-number case (throughput, ratios, losses).
    pub fn push_value(&mut self, name: &str, value: f64, unit: &str) {
        self.results.push(obj(vec![
            ("name", s(name)),
            ("value", num(value)),
            ("unit", s(unit)),
        ]));
    }

    /// Assemble the record document (commit/date/host are best-effort).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bench", s(&self.bench)),
            ("commit", s(&git_short_head())),
            ("date", s(&utc_date())),
            ("host", s(&host_label())),
            ("config", self.config.clone()),
            ("results", Json::Arr(self.results.clone())),
            ("notes", s(&self.notes)),
        ])
    }

    /// Write the record to `path` (pretty-printed, trailing newline).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

/// `git rev-parse --short HEAD`, or `"unknown"` outside a repo.
fn git_short_head() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Best-effort machine hostname: `$HOSTNAME` (interactive shells export
/// it rarely), then `/etc/hostname`, then `"unknown"`. Shared with
/// [`crate::selector::profile::HardwareProfile`] provenance stamping.
pub fn hostname() -> String {
    std::env::var("HOSTNAME")
        .ok()
        .filter(|h| !h.trim().is_empty())
        .or_else(|| {
            std::fs::read_to_string("/etc/hostname")
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|h| !h.is_empty())
        })
        .unwrap_or_else(|| "unknown".to_string())
}

/// Hostname plus core count, e.g. `"buildbox (16 cores)"`.
fn host_label() -> String {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    format!("{} ({cores} cores)", hostname())
}

/// Today's UTC date as `YYYY-MM-DD` (civil-from-days, no chrono).
fn utc_date() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Days-since-epoch → (year, month, day); Howard Hinnant's algorithm.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097); // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Parse `--json <path>` from a bench binary's argument list (cargo
/// passes everything after `--` through). Returns `None` when absent.
pub fn json_path_arg() -> Option<std::path::PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn record_round_trips_through_the_parser() {
        let mut rec = BenchRecord::new("native_kernels")
            .with_config(obj(vec![("n", Json::Arr(vec![num(1.0), num(32.0)]))]));
        rec.push_value("uniform n=32 sr_rs", 12.5, "GFLOP/s");
        rec.push_latency(&BenchStats {
            name: "case".into(),
            iterations: 10,
            median: Duration::from_micros(500),
            p10: Duration::from_micros(400),
            p90: Duration::from_micros(700),
            mean: Duration::from_micros(520),
        });
        rec.set_notes("test");
        let j = rec.to_json();
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("native_kernels"));
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 2);
        let lat = &back.get("results").unwrap().as_arr().unwrap()[1];
        assert_eq!(lat.get("median_s").unwrap().as_f64(), Some(0.0005));
        assert_eq!(back.get("notes").unwrap().as_str(), Some("test"));
        assert!(back.get("date").unwrap().as_str().unwrap().len() == 10);
    }

    #[test]
    fn civil_dates_are_correct() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // 2024-01-01
        assert_eq!(civil_from_days(20_663), (2026, 7, 29)); // leap-aware
    }
}
