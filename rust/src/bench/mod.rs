//! Benchmark substrate: a criterion-like measurement harness plus table
//! formatting shared by `rust/benches/*` (all `harness = false`, since
//! criterion is not in the offline registry).

pub mod figures;
pub mod harness;
pub mod record;
pub mod table;

pub use harness::{bench_fn, BenchStats};
pub use record::BenchRecord;
pub use table::Table;
