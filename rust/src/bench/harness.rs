//! Wallclock measurement: warmup, calibrated iteration count, robust
//! summary statistics. The shape criterion users expect, sized for this
//! project.

use crate::util::stats;
use std::time::{Duration, Instant};

/// Summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iterations: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchStats {
    /// Median seconds (convenience for ratio computations).
    pub fn median_s(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// One-line report.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>12} median  [{} .. {}]  ({} iters)",
            self.name,
            crate::util::timer::fmt_duration(self.median),
            crate::util::timer::fmt_duration(self.p10),
            crate::util::timer::fmt_duration(self.p90),
            self.iterations
        )
    }
}

/// Measurement budget.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Measure `f` under the default budget.
pub fn bench_fn<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench_fn_with(name, BenchConfig::default(), f)
}

/// Measure `f` under an explicit budget.
pub fn bench_fn_with<F: FnMut()>(name: &str, config: BenchConfig, mut f: F) -> BenchStats {
    // warmup + single-shot estimate
    let start = Instant::now();
    let mut warm_iters = 0usize;
    while start.elapsed() < config.warmup || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > config.max_iters {
            break;
        }
    }
    let per_iter = start.elapsed().as_secs_f64() / warm_iters as f64;
    let iters = ((config.measure.as_secs_f64() / per_iter.max(1e-9)) as usize)
        .clamp(config.min_iters, config.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let median = stats::median(&samples);
    let p10 = stats::quantile(&samples, 0.1);
    let p90 = stats::quantile(&samples, 0.9);
    let mean = stats::mean(&samples);
    BenchStats {
        name: name.to_string(),
        iterations: iters,
        median: Duration::from_secs_f64(median),
        p10: Duration::from_secs_f64(p10),
        p90: Duration::from_secs_f64(p90),
        mean: Duration::from_secs_f64(mean),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
        };
        let mut acc = 0u64;
        let stats = bench_fn_with("spin", cfg, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(stats.iterations >= 3);
        assert!(stats.median > Duration::ZERO);
        assert!(stats.p10 <= stats.median && stats.median <= stats.p90);
        assert!(stats.line().contains("spin"));
    }
}
