//! Shared machinery for the figure/table reproductions in `rust/benches/`.
//!
//! Every bench needs the same pipeline: build the benchmark collection
//! (in parallel), extract features, run the simulator for a set of kernel
//! designs across N and GPU configs, and aggregate speedups. Centralizing
//! it keeps each bench file focused on the paper artifact it regenerates.

use crate::features::MatrixFeatures;
use crate::gen::collection::{Collection, Family, MatrixSpec};
use crate::sim::{simulate, GpuConfig, SimKernel, SimMatrix};
use crate::util::stats;
use crate::util::threadpool::ThreadPool;
use std::sync::Mutex;

/// A prepared benchmark matrix.
pub struct BenchMatrix {
    pub name: String,
    pub family: Family,
    pub features: MatrixFeatures,
    pub sim: SimMatrix,
}

/// Build the bench suite in parallel (preprocessing dominates; the
/// simulations themselves are run by the callers).
pub fn load_bench_matrices() -> Vec<BenchMatrix> {
    load_matrices(Collection::bench_suite())
}

/// Build an arbitrary spec list in parallel, preserving order.
pub fn load_matrices(specs: Vec<MatrixSpec>) -> Vec<BenchMatrix> {
    let pool = ThreadPool::default_parallel();
    let out: Mutex<Vec<(usize, BenchMatrix)>> = Mutex::new(Vec::with_capacity(specs.len()));
    pool.run_dynamic(specs.len(), 1, |range| {
        for i in range {
            let spec = &specs[i];
            let csr = spec.build();
            let features = MatrixFeatures::of(&csr);
            let bm = BenchMatrix {
                name: spec.name.clone(),
                family: spec.family,
                features,
                sim: SimMatrix::new(csr),
            };
            out.lock().unwrap().push((i, bm));
        }
    });
    let mut v = out.into_inner().unwrap();
    v.sort_by_key(|(i, _)| *i);
    v.into_iter().map(|(_, bm)| bm).collect()
}

/// Per-matrix simulated seconds for one kernel at (n, gpu), parallel over
/// matrices.
pub fn sim_suite(
    matrices: &[BenchMatrix],
    kernel: SimKernel,
    n: usize,
    gpu: &GpuConfig,
) -> Vec<f64> {
    let pool = ThreadPool::default_parallel();
    let out: Mutex<Vec<(usize, f64)>> = Mutex::new(Vec::with_capacity(matrices.len()));
    pool.run_dynamic(matrices.len(), 1, |range| {
        for i in range {
            let s = simulate(kernel, &matrices[i].sim, n, gpu).seconds;
            out.lock().unwrap().push((i, s));
        }
    });
    let mut v = out.into_inner().unwrap();
    v.sort_by_key(|(i, _)| *i);
    v.into_iter().map(|(_, s)| s).collect()
}

/// Best-of-the-four-designs seconds per matrix (the paper's "ours",
/// offline-profiled mode).
pub fn sim_ours_best(matrices: &[BenchMatrix], n: usize, gpu: &GpuConfig) -> Vec<f64> {
    let per_kernel: Vec<Vec<f64>> = SimKernel::OURS
        .iter()
        .map(|&k| sim_suite(matrices, k, n, gpu))
        .collect();
    (0..matrices.len())
        .map(|i| per_kernel.iter().map(|v| v[i]).fold(f64::INFINITY, f64::min))
        .collect()
}

/// Rule-selected seconds per matrix (the paper's "ours with rule-based").
pub fn sim_ours_rules(
    matrices: &[BenchMatrix],
    sel: &crate::selector::AdaptiveSelector,
    n: usize,
    gpu: &GpuConfig,
) -> Vec<f64> {
    matrices
        .iter()
        .map(|m| {
            let k = sel.select(&m.features, n);
            simulate(SimKernel::from_kind(k), &m.sim, n, gpu).seconds
        })
        .collect()
}

/// Geometric-mean speedup of `ours` over `baseline` (elementwise ratios).
pub fn geomean_speedup(baseline: &[f64], ours: &[f64]) -> f64 {
    let ratios: Vec<f64> = baseline
        .iter()
        .zip(ours)
        .map(|(b, o)| b / o)
        .collect();
    stats::geomean(&ratios)
}

/// The paper's N sweep.
pub const N_SWEEP: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_suite_is_reasonably_sized() {
        let specs = Collection::bench_suite();
        assert!(
            (25..=45).contains(&specs.len()),
            "bench suite has {} entries",
            specs.len()
        );
        // covers every family
        let fams: std::collections::HashSet<_> = specs.iter().map(|s| s.family).collect();
        assert!(fams.len() >= 6, "families covered: {}", fams.len());
    }

    #[test]
    fn geomean_speedup_basic() {
        assert!((geomean_speedup(&[2.0, 2.0], &[1.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!((geomean_speedup(&[1.0, 4.0], &[2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn load_and_sim_mini() {
        let ms = load_matrices(Collection::mini_suite());
        assert!(!ms.is_empty());
        let gpu = GpuConfig::v100();
        let times = sim_suite(&ms, SimKernel::SrRs, 32, &gpu);
        assert_eq!(times.len(), ms.len());
        assert!(times.iter().all(|&t| t.is_finite() && t > 0.0));
        let best = sim_ours_best(&ms, 32, &gpu);
        for i in 0..ms.len() {
            assert!(best[i] <= times[i] + 1e-15);
        }
    }
}
