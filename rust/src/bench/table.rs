//! Minimal fixed-width table formatter for bench output — keeps every
//! figure/table reproduction readable in a terminal and greppable in
//! `bench_output.txt`.

/// A simple left-aligned-first-column table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column sizing.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", cells[i], w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a ratio as `1.23×`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}×")
}

/// Format seconds adaptively.
pub fn secs(s: f64) -> String {
    crate::util::timer::fmt_duration(std::time::Duration::from_secs_f64(s.max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["matrix", "ours", "cusparse", "speedup"]);
        t.row(vec!["rmat_s10".into(), "1.2ms".into(), "1.5ms".into(), ratio(1.25)]);
        t.row(vec!["x".into(), "900µs".into(), "1.1ms".into(), ratio(1.22)]);
        let r = t.render();
        assert!(r.contains("matrix"));
        assert!(r.contains("1.25×"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].chars().count(), lines[2].chars().count());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
