//! The **variant registry**: executable, monomorphized entries for every
//! [`KernelVariant`] this build ships.
//!
//! `kernels/variant.rs` names the widened design space; this module makes
//! it runnable. Macro invocations stamp out the SpMM and SDDMM inner
//! loops over the non-family axes (lane tile, row-chunk scale) into plain
//! `fn` items, the hand-written kernels supply the canonical points, and
//! [`VariantRegistry`] collects everything into a dense, id-indexed table
//! of fn-pointer entries. All entries share two uniform signatures —
//!
//! ```text
//! SpMM:  fn(&CsrMatrix, &SegmentedMatrix, &DenseMatrix, &mut DenseMatrix, &ThreadPool)
//! SDDMM: fn(&CsrMatrix, &SegmentedMatrix, &DenseMatrix, &DenseMatrix, &mut [f32], &ThreadPool)
//! ```
//!
//! — the caller (the native backend) resolves the segmented layout for
//! the variant's `seg_len`; row-split entries simply ignore it. Segment
//! variants of one family therefore share a single fn pointer: the
//! monomorphization axis is the *layout*, not the code.
//!
//! Registry ids are **dense and global across both ops** (SpMM and SDDMM
//! variants occupy one id space), which is what lets
//! [`crate::coordinator::Metrics`] size its counter/histogram/cost banks
//! `registry().len()` wide and index them directly by variant id. Ids are
//! a *build-local* ordering — anything persisted (profiles, baselines,
//! audit lines) uses the stable labels, never ids.
//!
//! Everything here is panic-free by construction: lookups return
//! `Option`, execution returns `Result`, and the canonical points are
//! precomputed at build so family→variant resolution cannot fail.

use super::variant::KernelVariant;
use super::{merge_path, pr_rs, pr_wb, sr_rs, sr_wb, KernelKind, SparseOp, Traversal};
use crate::sparse::{CsrMatrix, DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::sync::OnceLock;

/// Uniform SpMM entry signature (row-split entries ignore `seg`).
pub type SpmmVariantFn =
    fn(&CsrMatrix, &SegmentedMatrix, &DenseMatrix, &mut DenseMatrix, &ThreadPool);

/// Uniform SDDMM entry signature (row-split entries ignore `seg`).
pub type SddmmVariantFn =
    fn(&CsrMatrix, &SegmentedMatrix, &DenseMatrix, &DenseMatrix, &mut [f32], &ThreadPool);

/// The executable payload of one entry, tagged by op.
enum VariantFn {
    Spmm(SpmmVariantFn),
    Sddmm(SddmmVariantFn),
}

/// One registry entry: descriptor, stable label, dense id, entry point.
pub struct VariantEntry {
    /// Dense registry id (index into every registry-sized metric bank).
    pub id: usize,
    /// The descriptor this entry monomorphizes.
    pub variant: KernelVariant,
    /// The descriptor's stable canonical label, leaked once at registry
    /// build so the observability layer can use it as `&'static str`.
    pub label: &'static str,
    run: VariantFn,
}

impl VariantEntry {
    /// Execute an SpMM entry. `seg` must carry the entry's `seg_len` when
    /// the family is workload-balanced (row-split entries ignore it).
    pub fn run_spmm(
        &self,
        csr: &CsrMatrix,
        seg: &SegmentedMatrix,
        x: &DenseMatrix,
        y: &mut DenseMatrix,
        pool: &ThreadPool,
    ) -> Result<()> {
        let VariantFn::Spmm(f) = self.run else {
            return Err(anyhow!("variant '{}' is not an SpMM entry", self.label));
        };
        if self.variant.family.is_balanced() && seg.seg_len != self.variant.seg_len {
            return Err(anyhow!(
                "variant '{}' needs a segment length of {}, got a layout of {}",
                self.label,
                self.variant.seg_len,
                seg.seg_len
            ));
        }
        f(csr, seg, x, y, pool);
        Ok(())
    }

    /// Execute an SDDMM entry. Same layout contract as
    /// [`VariantEntry::run_spmm`].
    pub fn run_sddmm(
        &self,
        csr: &CsrMatrix,
        seg: &SegmentedMatrix,
        u: &DenseMatrix,
        v: &DenseMatrix,
        out: &mut [f32],
        pool: &ThreadPool,
    ) -> Result<()> {
        let VariantFn::Sddmm(f) = self.run else {
            return Err(anyhow!("variant '{}' is not an SDDMM entry", self.label));
        };
        if self.variant.family.is_balanced() && seg.seg_len != self.variant.seg_len {
            return Err(anyhow!(
                "variant '{}' needs a segment length of {}, got a layout of {}",
                self.label,
                self.variant.seg_len,
                seg.seg_len
            ));
        }
        f(csr, seg, u, v, out, pool);
        Ok(())
    }
}

/// Stable dense index of a family within per-family tables — the
/// registry-era replacement for `KernelKind::ALL.iter().position(..)
/// .unwrap()` chains (total over the enum, so it cannot fail).
pub fn family_index(kernel: KernelKind) -> usize {
    match kernel {
        KernelKind::SrRs => 0,
        KernelKind::SrWb => 1,
        KernelKind::PrRs => 2,
        KernelKind::PrWb => 3,
    }
}

fn op_index(op: SparseOp) -> usize {
    match op {
        SparseOp::Spmm => 0,
        SparseOp::Sddmm => 1,
    }
}

/// The dense table of all generated variants, plus precomputed canonical
/// points per (op, family). Built once per process by [`registry`].
pub struct VariantRegistry {
    entries: Vec<VariantEntry>,
    canonical: [[usize; 4]; 2],
}

impl VariantRegistry {
    /// Number of variants (the width of every registry-indexed bank).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the registry is empty (never, but keeps clippy honest).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries, ordered by id.
    pub fn entries(&self) -> &[VariantEntry] {
        &self.entries
    }

    /// Entry by dense id.
    pub fn get(&self, id: usize) -> Option<&VariantEntry> {
        self.entries.get(id)
    }

    /// Entry by (op, stable label).
    pub fn by_label(&self, op: SparseOp, label: &str) -> Option<&VariantEntry> {
        self.entries
            .iter()
            .find(|e| e.variant.op == op && e.label == label)
    }

    /// The canonical entry of a family — the hand-written kernel.
    /// Infallible: the canonical table is verified at build.
    pub fn canonical(&self, op: SparseOp, family: KernelKind) -> &VariantEntry {
        &self.entries[self.canonical[op_index(op)][family_index(family)]]
    }

    /// Dense id of a family's canonical entry.
    pub fn canonical_id(&self, op: SparseOp, family: KernelKind) -> usize {
        self.canonical[op_index(op)][family_index(family)]
    }

    /// All variants of one (op, family), ordered by id (canonical first
    /// by construction).
    pub fn family_variants(&self, op: SparseOp, family: KernelKind) -> Vec<&VariantEntry> {
        self.entries
            .iter()
            .filter(|e| e.variant.op == op && e.variant.family == family)
            .collect()
    }

    /// All variants of one op, ordered by id.
    pub fn op_variants(&self, op: SparseOp) -> Vec<&VariantEntry> {
        self.entries.iter().filter(|e| e.variant.op == op).collect()
    }

    fn build() -> Self {
        let mut entries: Vec<VariantEntry> = Vec::new();
        let mut push = |variant: KernelVariant, run: VariantFn| {
            let label: &'static str = Box::leak(variant.label().into_boxed_str());
            entries.push(VariantEntry {
                id: entries.len(),
                variant,
                label,
                run,
            });
        };

        use KernelKind::*;
        use SparseOp::*;
        let c = KernelVariant::canonical;

        // --- SpMM -------------------------------------------------------
        // Canonical entries first within each family, so family_variants()
        // always leads with the hand-written kernel.
        push(c(Spmm, SrRs), VariantFn::Spmm(spmm_sr_rs));
        push(c(Spmm, SrRs).with_lane_tile(1), VariantFn::Spmm(spmm_sr_rs_t1));
        push(c(Spmm, SrRs).with_lane_tile(4), VariantFn::Spmm(spmm_sr_rs_t4));
        push(
            c(Spmm, SrRs).with_traversal(Traversal::MergePath),
            VariantFn::Spmm(spmm_sr_mp),
        );
        // The segment variants of one family share a single fn pointer:
        // the monomorphization axis is the prepared layout, not the code.
        push(c(Spmm, SrWb), VariantFn::Spmm(spmm_sr_wb));
        push(c(Spmm, SrWb).with_seg_len(16), VariantFn::Spmm(spmm_sr_wb));
        push(c(Spmm, SrWb).with_seg_len(64), VariantFn::Spmm(spmm_sr_wb));
        push(c(Spmm, PrRs), VariantFn::Spmm(spmm_pr_rs));
        // PR-WB's VSR scan network is written against whole WARP multiples
        // (`pr_wb::spmm` rejects anything else), so the 16-nnz segment
        // point exists only for SDDMM, whose WB kernels are seg-agnostic.
        push(c(Spmm, PrWb), VariantFn::Spmm(spmm_pr_wb));
        push(c(Spmm, PrWb).with_seg_len(64), VariantFn::Spmm(spmm_pr_wb));

        // --- SDDMM ------------------------------------------------------
        push(c(Sddmm, SrRs), VariantFn::Sddmm(sddmm_sr_rs));
        push(c(Sddmm, SrRs).with_lane_tile(1), VariantFn::Sddmm(sddmm_sr_rs_c16));
        push(c(Sddmm, SrWb), VariantFn::Sddmm(sddmm_sr_wb));
        push(c(Sddmm, SrWb).with_seg_len(16), VariantFn::Sddmm(sddmm_sr_wb));
        push(c(Sddmm, SrWb).with_seg_len(64), VariantFn::Sddmm(sddmm_sr_wb));
        push(c(Sddmm, PrRs), VariantFn::Sddmm(sddmm_pr_rs));
        push(c(Sddmm, PrWb), VariantFn::Sddmm(sddmm_pr_wb));
        push(c(Sddmm, PrWb).with_seg_len(64), VariantFn::Sddmm(sddmm_pr_wb));

        // Precompute the canonical table; a missing point is a registry
        // construction bug, caught at first use in any test.
        let mut canonical = [[usize::MAX; 4]; 2];
        for e in &entries {
            if e.variant.is_canonical() {
                canonical[op_index(e.variant.op)][family_index(e.variant.family)] = e.id;
            }
        }
        debug_assert!(
            canonical.iter().flatten().all(|&id| id < entries.len()),
            "registry is missing a canonical point"
        );
        Self { entries, canonical }
    }
}

/// The process-wide registry (built on first use).
pub fn registry() -> &'static VariantRegistry {
    static REG: OnceLock<VariantRegistry> = OnceLock::new();
    REG.get_or_init(VariantRegistry::build)
}

// ---------------------------------------------------------------------------
// Entry points. The canonical points delegate to the hand-written kernels;
// the generated points are stamped out by the macros below.

fn spmm_sr_rs(a: &CsrMatrix, _s: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, p: &ThreadPool) {
    sr_rs::spmm(a, x, y, p);
}

fn spmm_sr_mp(a: &CsrMatrix, _s: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, p: &ThreadPool) {
    merge_path::spmm(a, x, y, p);
}

fn spmm_sr_wb(_a: &CsrMatrix, s: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, p: &ThreadPool) {
    sr_wb::spmm(s, x, y, p);
}

fn spmm_pr_rs(a: &CsrMatrix, _s: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, p: &ThreadPool) {
    pr_rs::spmm(a, x, y, p);
}

fn spmm_pr_wb(_a: &CsrMatrix, s: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, p: &ThreadPool) {
    pr_wb::spmm(s, x, y, p);
}

fn sddmm_sr_rs(a: &CsrMatrix, _s: &SegmentedMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], p: &ThreadPool) {
    crate::sddmm::sr_rs::sddmm(a, u, v, out, p);
}

fn sddmm_sr_wb(_a: &CsrMatrix, s: &SegmentedMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], p: &ThreadPool) {
    crate::sddmm::sr_wb::sddmm(s, u, v, out, p);
}

fn sddmm_pr_rs(a: &CsrMatrix, _s: &SegmentedMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], p: &ThreadPool) {
    crate::sddmm::pr_rs::sddmm(a, u, v, out, p);
}

fn sddmm_pr_wb(_a: &CsrMatrix, s: &SegmentedMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], p: &ThreadPool) {
    crate::sddmm::pr_wb::sddmm(s, u, v, out, p);
}

/// Stamp out an SR-RS SpMM whose dense-width inner loop is tiled at a
/// fixed width instead of routing through the `vec8` microkernel. The
/// tile loop is the *outer* j loop, so every output element still
/// accumulates its non-zeros in ascending-`k` order — bit-for-bit the
/// dense reference in every feature configuration, exactly like the
/// canonical kernel.
macro_rules! gen_spmm_sr_rs_tiled {
    ($name:ident, $tile:literal) => {
        fn $name(
            a: &CsrMatrix,
            _s: &SegmentedMatrix,
            x: &DenseMatrix,
            y: &mut DenseMatrix,
            pool: &ThreadPool,
        ) {
            assert_eq!(a.cols, x.rows, "inner dimension mismatch");
            assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
            const TILE: usize = $tile;
            let n = x.cols;
            let w = n.max(1);
            let pool = &pool.for_work(a.nnz() * w);
            pool.for_each_row_chunk(&mut y.data, w, 64, |first_row, rows| {
                rows.fill(0.0);
                let nrows = rows.len() / w;
                for i in 0..nrows {
                    let r = first_row + i;
                    if r >= a.rows {
                        break;
                    }
                    let (cols, vals) = a.row(r);
                    let out = &mut rows[i * n..(i + 1) * n];
                    let mut jt = 0;
                    while jt < n {
                        let hi = (jt + TILE).min(n);
                        for (&c, &v) in cols.iter().zip(vals) {
                            let xr = x.row(c as usize);
                            for j in jt..hi {
                                out[j] += v * xr[j];
                            }
                        }
                        jt = hi;
                    }
                }
            });
        }
    };
}

gen_spmm_sr_rs_tiled!(spmm_sr_rs_t1, 1);
gen_spmm_sr_rs_tiled!(spmm_sr_rs_t4, 4);

/// Stamp out an SR-RS SDDMM with a fixed row-chunk granularity (the
/// canonical kernel uses 64-row chunks). Dot products go through the
/// shared canonical [`crate::sddmm::dot_sr`], so results stay bit-for-bit
/// across chunkings in every feature configuration.
macro_rules! gen_sddmm_sr_rs_chunk {
    ($name:ident, $chunk:literal) => {
        fn $name(
            a: &CsrMatrix,
            _s: &SegmentedMatrix,
            u: &DenseMatrix,
            v: &DenseMatrix,
            out: &mut [f32],
            pool: &ThreadPool,
        ) {
            assert_eq!(u.rows, a.rows, "U rows mismatch");
            assert_eq!(v.rows, a.cols, "V rows mismatch");
            assert_eq!(u.cols, v.cols, "U/V width mismatch");
            assert_eq!(out.len(), a.nnz(), "output length mismatch");
            if a.nnz() == 0 {
                return;
            }
            let d = u.cols;
            let pool = &pool.for_work(a.nnz() * d.max(1));
            let shared = crate::sddmm::SharedValues::new(out);
            pool.scope_chunks(a.rows, $chunk, |rows| {
                let lo = a.indptr[rows.start] as usize;
                let hi = a.indptr[rows.end] as usize;
                if lo == hi {
                    return;
                }
                // SAFETY: row blocks have disjoint nnz spans (indptr is
                // monotone), per the SharedValues contract.
                let out = unsafe { shared.slice_mut(lo, hi) };
                for r in rows {
                    let (cols, vals) = a.row(r);
                    let base = a.indptr[r] as usize - lo;
                    let urow = u.row(r);
                    for k in 0..cols.len() {
                        let vrow = v.row(cols[k] as usize);
                        out[base + k] = vals[k] * crate::sddmm::dot_sr(urow, vrow);
                    }
                }
            });
        }
    };
}

gen_sddmm_sr_rs_chunk!(sddmm_sr_rs_c16, 16);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::{sddmm_reference, spmm_reference};
    use crate::kernels::WARP;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn registry_spans_both_ops_with_enough_variants() {
        let reg = registry();
        assert!(reg.len() >= 12, "only {} variants", reg.len());
        assert!(reg.op_variants(SparseOp::Spmm).len() >= 6);
        assert!(reg.op_variants(SparseOp::Sddmm).len() >= 6);
        // dense ids, unique labels per op
        for (i, e) in reg.entries().iter().enumerate() {
            assert_eq!(e.id, i);
            assert_eq!(e.label, e.variant.label());
            assert_eq!(reg.by_label(e.variant.op, e.label).map(|x| x.id), Some(i));
        }
    }

    #[test]
    fn canonical_points_carry_the_family_labels() {
        let reg = registry();
        for op in [SparseOp::Spmm, SparseOp::Sddmm] {
            for family in KernelKind::ALL {
                let e = reg.canonical(op, family);
                assert_eq!(e.label, family.label());
                assert!(e.variant.is_canonical());
                assert_eq!(reg.canonical_id(op, family), e.id);
                // canonical leads its family's variant list
                let fam = reg.family_variants(op, family);
                assert!(!fam.is_empty());
                assert_eq!(fam[0].id, e.id);
            }
        }
    }

    #[test]
    fn every_spmm_variant_matches_the_reference() {
        let mut rng = Xoshiro256::seeded(901);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 60, 0.1, &mut rng));
        let x = DenseMatrix::random(60, 9, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(80, 9);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(3);
        for e in registry().op_variants(SparseOp::Spmm) {
            let seg = SegmentedMatrix::from_csr(&a, e.variant.seg_len);
            let mut got = DenseMatrix::zeros(80, 9);
            e.run_spmm(&a, &seg, &x, &mut got, &pool).unwrap();
            crate::util::proptest::assert_close(&got.data, &want.data, 1e-5, 1e-5)
                .unwrap_or_else(|err| panic!("{}: {err}", e.label));
        }
    }

    #[test]
    fn tiled_spmm_variants_are_bit_identical_to_the_canonical_kernel() {
        let mut rng = Xoshiro256::seeded(902);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(50, 50, 0.15, &mut rng));
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let pool = ThreadPool::new(2);
        for n in [1usize, 7, 8, 33] {
            let x = DenseMatrix::random(50, n, 1.0, &mut rng);
            let reg = registry();
            let canon = reg.canonical(SparseOp::Spmm, KernelKind::SrRs);
            let mut base = DenseMatrix::zeros(50, n);
            canon.run_spmm(&a, &seg, &x, &mut base, &pool).unwrap();
            for label in ["sr_rs.t1", "sr_rs.t4"] {
                let e = reg.by_label(SparseOp::Spmm, label).unwrap();
                let mut got = DenseMatrix::zeros(50, n);
                e.run_spmm(&a, &seg, &x, &mut got, &pool).unwrap();
                for (g, b) in got.data.iter().zip(&base.data) {
                    assert_eq!(g.to_bits(), b.to_bits(), "{label} n={n}");
                }
            }
        }
    }

    #[test]
    fn every_sddmm_variant_is_bit_identical_to_the_reference() {
        let mut rng = Xoshiro256::seeded(903);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 45, 0.12, &mut rng));
        let pool = ThreadPool::new(3);
        for d in [1usize, 8, 33] {
            let u = DenseMatrix::random(60, d, 1.0, &mut rng);
            let v = DenseMatrix::random(45, d, 1.0, &mut rng);
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            for e in registry().op_variants(SparseOp::Sddmm) {
                let seg = SegmentedMatrix::from_csr(&a, e.variant.seg_len);
                let mut got = vec![0f32; a.nnz()];
                e.run_sddmm(&a, &seg, &u, &v, &mut got, &pool).unwrap();
                assert_eq!(got, want, "{} d={d}", e.label);
            }
        }
    }

    #[test]
    fn mismatched_usage_errors_instead_of_panicking() {
        let reg = registry();
        let a = CsrMatrix::from_parts(0, 0, vec![0], vec![], vec![]);
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let x = DenseMatrix::zeros(0, 0);
        let mut y = DenseMatrix::zeros(0, 0);
        let pool = ThreadPool::serial();
        // op mismatch
        let sddmm = reg.canonical(SparseOp::Sddmm, KernelKind::SrRs);
        assert!(sddmm.run_spmm(&a, &seg, &x, &mut y, &pool).is_err());
        // wrong segment layout for a balanced variant
        let s64 = reg.by_label(SparseOp::Spmm, "sr_wb.s64").unwrap();
        assert!(s64.run_spmm(&a, &seg, &x, &mut y, &pool).is_err());
        // unknown ids and labels are None, not panics
        assert!(reg.get(usize::MAX).is_none());
        assert!(reg.by_label(SparseOp::Spmm, "sr_rs.t9").is_none());
    }
}
