//! PR-WB — the paper's **VSR** (vectorized segment reduction), §2.1.1.
//!
//! The combination of workload-balancing and parallel-reduction: each lane
//! bundle processes a fixed-size segment of the non-zero stream, and since
//! a segment may span row boundaries, the merge tree is replaced by a
//! *segmented* scan network: the reduction "adds if the row indices of the
//! two elements match". After the scan, each lane compares its row index
//! with its neighbor to detect segment boundaries and dumps its result.
//!
//! This file ports the SIMD-shuffle network literally: `scan` runs the
//! log-step shifted adds over 32-lane arrays with a double buffer
//! (simultaneous shuffle semantics), and the dump rule is the paper's
//! neighbor comparison. Tests pin the network against a scalar
//! segmented-sum oracle, independent of the SpMM result tests. The
//! per-lane N-wide loads/adds are elementwise and run through
//! [`crate::kernels::vec8`] — bit-identical with and without the `simd`
//! feature.

use super::{vec8, WARP};
use crate::kernels::sr_wb::SharedRows;
use crate::sparse::{DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;

/// One step of the paper's segmented-scan network over a window:
/// suffix-direction inclusive scan where lane `l` accumulates lane `l+d`
/// iff they belong to the same row. After all log₂(WARP) steps, the lane at
/// each row-run *start* holds that run's total.
///
/// `vals` is `WARP × n` (lane-major); `rows` is the per-lane row index.
#[inline]
fn segmented_scan(vals: &mut [f32], rows: &[u32; WARP], n: usize, scratch: &mut [f32]) {
    let mut d = 1;
    while d < WARP {
        scratch[..WARP * n].copy_from_slice(&vals[..WARP * n]);
        for l in 0..WARP - d {
            if rows[l] == rows[l + d] {
                let src = &scratch[(l + d) * n..(l + d + 1) * n];
                let dst = &mut vals[l * n..(l + 1) * n];
                vec8::add_assign(dst, src);
            }
        }
        d <<= 1;
    }
}

/// Dump rule: lane `l` is a row-run start iff `l == 0` or
/// `rows[l-1] != rows[l]`. Returns the dumping lanes.
#[inline]
fn run_starts(rows: &[u32; WARP]) -> impl Iterator<Item = usize> + '_ {
    (0..WARP).filter(move |&l| l == 0 || rows[l - 1] != rows[l])
}

/// PR-WB (VSR) SpMM over the segmented format. Supports any N; the paper
/// pairs it with VDL-style `(1, N)` lane loads for N ≤ 4.
pub fn spmm(a: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    assert_eq!(a.seg_len % WARP, 0, "segment length must be a multiple of WARP");
    let n = x.cols;
    if n == 0 {
        return;
    }
    y.data.fill(0.0);

    let pool = &pool.for_work(a.nnz * n);
    let workers = pool.workers().min(a.num_segments).max(1);
    let per = a.num_segments.div_ceil(workers);
    let shared = SharedRows::new(&mut y.data, n);

    // Each worker owns contiguous segments; rows whose first nnz lies in
    // the worker's range are written directly (exclusive), the worker's
    // first row partial is carried to a sequential fix-up (same ownership
    // scheme as sr_wb, see there).
    let carries: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let shared = &shared;
            let seg_lo = w * per;
            let seg_hi = ((w + 1) * per).min(a.num_segments);
            handles.push(scope.spawn(move || vsr_worker(a, x, shared, seg_lo, seg_hi)));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    for (row, partial) in carries {
        let out = &mut y.data[row * n..(row + 1) * n];
        vec8::add_assign(out, &partial);
    }
}

fn vsr_worker(
    a: &SegmentedMatrix,
    x: &DenseMatrix,
    y: &SharedRows,
    seg_lo: usize,
    seg_hi: usize,
) -> Vec<(usize, Vec<f32>)> {
    let n = x.cols;
    if seg_lo >= seg_hi {
        return Vec::new();
    }
    let lo = seg_lo * a.seg_len;
    let hi = seg_hi * a.seg_len;
    let first_row = a.row_idx[lo] as usize;
    let mut first_carry = vec![0f32; n];

    let mut lane_vals = vec![0f32; WARP * n];
    let mut scratch = vec![0f32; WARP * n];
    let mut lane_rows = [0u32; WARP];

    let mut win = lo;
    while win < hi {
        // 1. parallel load + multiply: lane l handles element win+l.
        //    VDL: each lane pulls the contiguous (1, N) fragment of X.
        for l in 0..WARP {
            let i = win + l;
            lane_rows[l] = a.row_idx[i];
            let lane = &mut lane_vals[l * n..(l + 1) * n];
            // Bound the gather by the true nnz: padding lanes must never
            // touch X (their 0.0 value would still turn a non-finite
            // dense entry into NaN, poisoning the run they merge into).
            // Real entries always gather, so explicit stored zeros
            // propagate NaN/Inf exactly like the dense reference.
            if i < a.nnz {
                let v = a.values[i];
                let xrow = x.row(a.col_idx[i] as usize);
                vec8::mul_store(lane, v, xrow);
            } else {
                lane.fill(0.0);
            }
        }
        // 2. the VSR segmented-scan network
        segmented_scan(&mut lane_vals, &lane_rows, n, &mut scratch);
        // 3. dump at row-run starts
        for l in run_starts(&lane_rows) {
            let row = lane_rows[l] as usize;
            let lane = &lane_vals[l * n..(l + 1) * n];
            if row == first_row {
                // possibly shared with the previous worker → carry
                vec8::add_assign(&mut first_carry, lane);
            } else {
                // first nnz of `row` lies in this worker's range → exclusive
                // SAFETY: see SharedRows contract.
                let out = unsafe { y.row_mut(row) };
                vec8::add_assign(out, lane);
            }
        }
        win += WARP;
    }
    vec![(first_row, first_carry)]
}

/// PR-WB (VSR) SpMV — the headline §2.1.1 kernel (N = 1).
pub fn spmv(a: &SegmentedMatrix, x: &[f32], y: &mut [f32], pool: &ThreadPool) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let xm = DenseMatrix::from_vec(x.len(), 1, x.to_vec());
    let mut ym = DenseMatrix::zeros(y.len(), 1);
    spmm(a, &xm, &mut ym, pool);
    y.copy_from_slice(&ym.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::proptest::{assert_close, run_prop};

    /// Scalar segmented-sum oracle for the scan network.
    fn oracle_segment_sums(vals: &[f32; WARP], rows: &[u32; WARP]) -> Vec<(u32, f32)> {
        let mut out: Vec<(u32, f32)> = Vec::new();
        for l in 0..WARP {
            match out.last_mut() {
                Some((r, acc)) if *r == rows[l] => *acc += vals[l],
                _ => out.push((rows[l], vals[l])),
            }
        }
        out
    }

    #[test]
    fn scan_network_matches_scalar_oracle() {
        run_prop("vsr scan network", 200, |g| {
            let mut vals = [0f32; WARP];
            let mut rows = [0u32; WARP];
            let mut r = 0u32;
            for l in 0..WARP {
                vals[l] = g.value();
                // random run lengths, occasionally repeated rows
                if l > 0 && g.chance(0.35) {
                    r += 1;
                }
                rows[l] = r;
            }
            let mut lane_vals = vals.to_vec();
            let mut scratch = vec![0f32; WARP];
            segmented_scan(&mut lane_vals, &rows, 1, &mut scratch);
            let oracle = oracle_segment_sums(&vals, &rows);
            let starts: Vec<usize> = run_starts(&rows).collect();
            if starts.len() != oracle.len() {
                return Err(format!(
                    "run count mismatch: {} starts vs {} runs",
                    starts.len(),
                    oracle.len()
                ));
            }
            for (idx, &l) in starts.iter().enumerate() {
                let (orow, osum) = oracle[idx];
                if rows[l] != orow {
                    return Err(format!("row mismatch at lane {l}"));
                }
                let diff = (lane_vals[l] - osum).abs();
                if diff > 1e-4 {
                    return Err(format!(
                        "sum mismatch at lane {l}: {} vs {osum}",
                        lane_vals[l]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scan_handles_single_run_and_alternating() {
        // single run: start lane 0 holds the total
        let vals = [1f32; WARP];
        let rows = [5u32; WARP];
        let mut lane_vals = vals.to_vec();
        let mut scratch = vec![0f32; WARP];
        segmented_scan(&mut lane_vals, &rows, 1, &mut scratch);
        assert_eq!(lane_vals[0], WARP as f32);

        // alternating rows: every lane is its own run
        let mut rows2 = [0u32; WARP];
        for (l, r) in rows2.iter_mut().enumerate() {
            *r = l as u32;
        }
        let mut lane_vals2: Vec<f32> = (0..WARP).map(|l| l as f32).collect();
        segmented_scan(&mut lane_vals2, &rows2, 1, &mut scratch);
        for l in 0..WARP {
            assert_eq!(lane_vals2[l], l as f32);
        }
        assert_eq!(run_starts(&rows2).count(), WARP);
    }

    #[test]
    fn spmm_matches_reference() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(401);
        // skewed: exactly the workload VSR exists for
        let cfg = crate::gen::powerlaw::PowerLawConfig {
            rows: 120,
            cols: 90,
            alpha: 1.7,
            min_row: 1,
            max_row: 80,
        };
        let a = CsrMatrix::from_coo(&cfg.generate(&mut rng));
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        for n in [1usize, 2, 4, 32] {
            let x = DenseMatrix::random(90, n, 1.0, &mut rng);
            let mut want = DenseMatrix::zeros(120, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(120, n);
            spmm(&seg, &x, &mut got, &ThreadPool::new(4));
            assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn property_vs_reference() {
        run_prop("pr_wb spmm vs reference", 25, |g| {
            let rows = g.dim() * 2;
            let cols = g.dim() * 2;
            let n = *g.choose(&[1usize, 2, 4, 8]);
            let workers = *g.choose(&[1usize, 3, 6]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let seg = SegmentedMatrix::from_csr(&a, WARP);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            spmm(&seg, &x, &mut got, &ThreadPool::new(workers));
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
        });
    }

    #[test]
    #[should_panic(expected = "multiple of WARP")]
    fn rejects_non_warp_segments() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        let seg = SegmentedMatrix::from_csr(&a, 8);
        let x = DenseMatrix::zeros(4, 1);
        let mut y = DenseMatrix::zeros(4, 1);
        spmm(&seg, &x, &mut y, &ThreadPool::serial());
    }
}
