//! SR-WB — sequential reduction over fixed-nnz segments (paper Fig. 2(b)).
//!
//! The workload-balancing principle: instead of whole rows, every worker is
//! assigned an equal number of *non-zeros* (segments of `WARP` entries), so
//! no worker is bottlenecked by a pathological row. Because segments cross
//! row boundaries, each worker must carry partial sums for rows shared with
//! its neighbors; the carries are merged in a short sequential fix-up pass
//! (the GPU kernels do the same with atomics or a spine pass — merge-path /
//! CSR-stream style).
//!
//! Dense-width loops (gather, flush, fix-up) run through the
//! [`crate::kernels::vec8`] elementwise microkernels — bit-identical
//! with and without the `simd` feature.

use crate::kernels::vec8;
use crate::sparse::{DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;
use std::cell::UnsafeCell;

/// Shared mutable output rows. SAFETY contract: concurrent writers must
/// touch disjoint row ranges; the carry scheme below guarantees it (each
/// row is written directly only by the worker that owns its first nnz).
pub(crate) struct SharedRows<'a> {
    data: &'a UnsafeCell<[f32]>,
    pub n: usize,
}

unsafe impl Sync for SharedRows<'_> {}

impl<'a> SharedRows<'a> {
    pub fn new(data: &'a mut [f32], n: usize) -> Self {
        assert!(n > 0 && data.len() % n == 0);
        // SAFETY: &mut guarantees exclusivity; UnsafeCell re-shares it under
        // the disjoint-rows contract documented above.
        let cell = unsafe { &*(data as *mut [f32] as *const UnsafeCell<[f32]>) };
        Self { data: cell, n }
    }

    /// Mutable view of one row. SAFETY: caller must ensure no other thread
    /// accesses row `r` concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, r: usize) -> &mut [f32] {
        let base = (*self.data.get()).as_mut_ptr();
        std::slice::from_raw_parts_mut(base.add(r * self.n), self.n)
    }
}

/// A carried partial row: `(row, values)` produced at a worker boundary.
type Carry = (usize, Vec<f32>);

/// SR-WB SpMM over the segmented format.
pub fn spmm(a: &SegmentedMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    y.data.fill(0.0);

    let pool = &pool.for_work(a.nnz * n.max(1));
    let workers = pool.workers().min(a.num_segments).max(1);
    // contiguous, equal segment ranges per worker = equal nnz per worker
    let per = a.num_segments.div_ceil(workers);
    let shared = SharedRows::new(&mut y.data, n.max(1));

    let carries: Vec<Carry> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let shared = &shared;
            let seg_lo = w * per;
            let seg_hi = ((w + 1) * per).min(a.num_segments);
            handles.push(scope.spawn(move || {
                worker_pass(a, x, shared, seg_lo, seg_hi)
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    // sequential fix-up: add boundary partials
    for (row, partial) in carries {
        let out = &mut y.data[row * n..(row + 1) * n];
        vec8::add_assign(out, &partial);
    }
}

/// Process segments `[seg_lo, seg_hi)` sequentially; returns the carried
/// first-row partial (if any work was done).
fn worker_pass(
    a: &SegmentedMatrix,
    x: &DenseMatrix,
    y: &SharedRows,
    seg_lo: usize,
    seg_hi: usize,
) -> Vec<Carry> {
    let n = x.cols;
    if seg_lo >= seg_hi {
        return Vec::new();
    }
    let lo = seg_lo * a.seg_len;
    let hi = (seg_hi * a.seg_len).min(a.values.len());
    if lo >= hi {
        return Vec::new();
    }

    let first_row = a.row_idx[lo] as usize;
    let mut acc = vec![0f32; n];
    let mut cur_row = first_row;
    let mut carries: Vec<Carry> = Vec::new();
    let mut flushed_first = false;

    let flush = |row: usize,
                     acc: &mut Vec<f32>,
                     flushed_first: &mut bool,
                     carries: &mut Vec<Carry>| {
        if !*flushed_first {
            // first distinct row may be shared with the previous worker:
            // defer to the sequential fix-up
            carries.push((row, std::mem::replace(acc, vec![0f32; n])));
            *flushed_first = true;
        } else {
            // rows after the first start inside this worker's range: we own
            // their first nnz, nobody else writes them directly.
            // SAFETY: per the ownership argument above.
            let out = unsafe { y.row_mut(row) };
            vec8::add_assign(out, acc.as_slice());
            acc.fill(0.0);
        }
    };

    for i in lo..hi {
        let r = a.row_idx[i] as usize;
        if r != cur_row {
            flush(cur_row, &mut acc, &mut flushed_first, &mut carries);
            cur_row = r;
        }
        // Bound the gather by the true nnz: padding slots must never
        // touch X. Their value is 0.0, but `0.0 * NaN = NaN`, so a
        // non-finite dense entry reachable only through a padded slot's
        // (repeated) column index would otherwise poison the carry row.
        if i < a.nnz {
            let v = a.values[i];
            let xrow = x.row(a.col_idx[i] as usize);
            vec8::axpy(&mut acc, v, xrow);
        }
    }
    // the trailing row may continue into the next worker: carry it too
    carries.push((cur_row, acc));
    carries
}

/// SR-WB SpMV (N = 1): scalar accumulator version of [`spmm`].
pub fn spmv(a: &SegmentedMatrix, x: &[f32], y: &mut [f32], pool: &ThreadPool) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let xm = DenseMatrix::from_vec(x.len(), 1, x.to_vec());
    let mut ym = DenseMatrix::zeros(y.len(), 1);
    spmm(a, &xm, &mut ym, pool);
    y.copy_from_slice(&ym.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::proptest::{assert_close, run_prop};

    fn check(a: &CsrMatrix, n: usize, seg_len: usize, workers: usize, seed: u64) {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let seg = SegmentedMatrix::from_csr(a, seg_len);
        let x = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(a, &x, &mut want);
        let mut got = DenseMatrix::zeros(a.rows, n);
        spmm(&seg, &x, &mut got, &ThreadPool::new(workers));
        assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn matches_reference_balanced_and_skewed() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(201);
        let balanced =
            CsrMatrix::from_coo(&CooMatrix::random_uniform(100, 80, 0.1, &mut rng));
        check(&balanced, 8, 32, 4, 202);
        check(&balanced, 1, 32, 3, 203);

        // one huge row spanning many segments and worker boundaries
        let mut coo = CooMatrix::new(50, 300);
        for c in 0..300 {
            coo.push(7, c, 0.01 * c as f32);
        }
        for r in 0..50 {
            coo.push(r, r, 1.0);
        }
        let skewed = CsrMatrix::from_coo(&coo);
        check(&skewed, 4, 16, 5, 204);
        check(&skewed, 128, 8, 7, 205);
    }

    #[test]
    fn row_spanning_all_workers() {
        // a single row holds ALL nnz: every worker carries partials for it
        let mut coo = CooMatrix::new(3, 256);
        for c in 0..256 {
            coo.push(1, c, 1.0);
        }
        let a = CsrMatrix::from_coo(&coo);
        let seg = SegmentedMatrix::from_csr(&a, 8);
        let x = DenseMatrix::from_vec(256, 1, vec![1.0; 256]);
        let mut y = DenseMatrix::zeros(3, 1);
        spmm(&seg, &x, &mut y, &ThreadPool::new(6));
        assert_eq!(y.data, vec![0.0, 256.0, 0.0]);
    }

    #[test]
    fn property_vs_reference() {
        run_prop("sr_wb spmm vs reference", 25, |g| {
            let rows = g.dim() * 2;
            let cols = g.dim() * 2;
            let n = *g.choose(&[1usize, 3, 8, 32]);
            let seg_len = *g.choose(&[1usize, 4, 16, 32]);
            let workers = *g.choose(&[1usize, 2, 5]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.2, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let seg = SegmentedMatrix::from_csr(&a, seg_len);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            spmm(&seg, &x, &mut got, &ThreadPool::new(workers));
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn spmv_wrapper() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(206);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 60, 0.15, &mut rng));
        let seg = SegmentedMatrix::from_csr(&a, 32);
        let x: Vec<f32> = (0..60).map(|i| i as f32 * 0.1).collect();
        let mut want = vec![0.0; 60];
        crate::kernels::dense::spmv_reference(&a, &x, &mut want);
        let mut got = vec![0.0; 60];
        spmv(&seg, &x, &mut got, &ThreadPool::new(3));
        assert_close(&got, &want, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn empty_matrix() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(5, 5));
        let seg = SegmentedMatrix::from_csr(&a, 32);
        let x = DenseMatrix::zeros(5, 4);
        let mut y = DenseMatrix::from_vec(5, 4, vec![9.0; 20]);
        spmm(&seg, &x, &mut y, &ThreadPool::new(2));
        assert_eq!(y.data, vec![0.0; 20]);
    }
}
