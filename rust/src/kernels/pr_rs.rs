//! PR-RS — parallel reduction, row split (CSR-Vector, Bell & Garland),
//! plus the VDL (vector-type dense-row loading) optimization of §2.1.2.
//!
//! A SIMD bundle of `WARP` lanes owns one row: lanes multiply value×dense
//! element in parallel, then a log₂(WARP) merge tree reduces the partial
//! products. The merge tree is implemented literally over lane arrays so
//! the algorithm (not just its result) matches the CUDA `__shfl_down`
//! network.
//!
//! For SpMM the naive approach is N independent SpMV passes
//! ([`spmm_n_spmv`], the paper's strawman). **VDL** instead makes each lane
//! load the `(1, N)` dense-row fragment for its non-zero — one float2/4
//! vector load in CUDA — and keep N partial sums ([`spmm`]); the paper
//! applies it for N ≤ 4.
//!
//! Lane accumulation and the merge tree are elementwise over N and run
//! through [`crate::kernels::vec8`] — bit-identical with and without the
//! `simd` feature.

use super::{vec8, WARP};
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::threadpool::ThreadPool;

/// Rows per parallel work item.
const ROW_CHUNK: usize = 64;

/// Merge-tree reduction over one lane array (the `__shfl_down` network).
/// Returns the total in lane 0's slot.
#[inline]
fn tree_reduce(lanes: &mut [f32; WARP]) -> f32 {
    let mut d = WARP / 2;
    while d > 0 {
        for l in 0..d {
            lanes[l] += lanes[l + d];
        }
        d /= 2;
    }
    lanes[0]
}

/// PR-RS SpMV: one lane bundle per row, merge-tree reduction per window.
pub fn spmv(a: &CsrMatrix, x: &[f32], y: &mut [f32], pool: &ThreadPool) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let pool = &pool.for_work(a.nnz());
    pool.for_each_row_chunk(y, 1, ROW_CHUNK * 4, |first_row, out| {
        let mut lanes = [0f32; WARP];
        for (i, o) in out.iter_mut().enumerate() {
            let r = first_row + i;
            if r >= a.rows {
                break;
            }
            let (cols, vals) = a.row(r);
            let mut acc = 0.0f32;
            let mut k = 0;
            while k < cols.len() {
                let w = (cols.len() - k).min(WARP);
                // parallel elementwise multiply (lanes beyond w idle — the
                // waste the paper's Fig. 2(d) highlights for short rows)
                for l in 0..w {
                    lanes[l] = vals[k + l] * x[cols[k + l] as usize];
                }
                for l in w..WARP {
                    lanes[l] = 0.0;
                }
                acc += tree_reduce(&mut lanes);
                k += w;
            }
            *o = acc;
        }
    });
}

/// PR-RS SpMM with **VDL**: each lane loads the `(1, N)` dense-row fragment
/// of its non-zero with one vector operation and keeps `N` partial sums.
/// Correct for any N; the paper recommends it only for N ≤ 4 (beyond that
/// the lane-private partials blow up — exactly Insight 1).
pub fn spmm(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    if n == 0 {
        return;
    }
    let pool = &pool.for_work(a.nnz() * n);
    pool.for_each_row_chunk(&mut y.data, n, ROW_CHUNK, |first_row, rows| {
        rows.fill(0.0);
        let nrows = rows.len() / n;
        // lane-private partial sums: lanes × N
        let mut lanes = vec![0f32; WARP * n];
        for i in 0..nrows {
            let r = first_row + i;
            if r >= a.rows {
                break;
            }
            let (cols, vals) = a.row(r);
            let out = &mut rows[i * n..(i + 1) * n];
            if cols.is_empty() {
                out.fill(0.0);
                continue;
            }
            // §Perf: only the lanes a row actually occupies participate —
            // short rows zero and merge a power-of-two prefix instead of
            // the full warp (the idle lanes hold zeros on the GPU too;
            // skipping them changes nothing numerically).
            let active = cols.len().min(WARP).next_power_of_two();
            lanes[..active * n].fill(0.0);
            let mut k = 0;
            while k < cols.len() {
                let w = (cols.len() - k).min(WARP);
                for l in 0..w {
                    // VDL: one contiguous (1, N) load per lane
                    let xrow = x.row(cols[k + l] as usize);
                    let v = vals[k + l];
                    let lane = &mut lanes[l * n..(l + 1) * n];
                    vec8::axpy(lane, v, xrow);
                }
                k += w;
            }
            // merge tree across the active lanes, elementwise over N
            let mut d = active / 2;
            while d > 0 {
                for l in 0..d {
                    let (dst, src) = lanes.split_at_mut((l + d) * n);
                    let dst = &mut dst[l * n..l * n + n];
                    let src = &src[..n];
                    vec8::add_assign(dst, src);
                }
                d /= 2;
            }
            out.copy_from_slice(&lanes[..n]);
        }
    });
}

/// The paper's strawman for PR SpMM: N independent SpMV passes, one per
/// dense column (§2.1.2 "two-SpMV solution"). Used as the VDL ablation
/// baseline.
pub fn spmm_n_spmv(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    let mut xcol = vec![0f32; x.rows];
    let mut ycol = vec![0f32; a.rows];
    for j in 0..n {
        for r in 0..x.rows {
            xcol[r] = x.at(r, j);
        }
        spmv(a, &xcol, &mut ycol, pool);
        for r in 0..a.rows {
            *y.at_mut(r, j) = ycol[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::{spmm_reference, spmv_reference};
    use crate::sparse::CooMatrix;
    use crate::util::proptest::{assert_close, run_prop};

    #[test]
    fn tree_reduce_sums_lanes() {
        let mut lanes = [0f32; WARP];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = i as f32;
        }
        let total = tree_reduce(&mut lanes);
        assert_eq!(total, (0..WARP as i32).sum::<i32>() as f32);
    }

    #[test]
    fn spmv_matches_reference() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(301);
        // include rows shorter and longer than WARP
        let mut coo = CooMatrix::random_uniform(100, 120, 0.05, &mut rng);
        for c in 0..100 {
            coo.push(3, c, 0.01 * c as f32); // 100-nnz row: multiple windows
        }
        let a = CsrMatrix::from_coo(&coo);
        let x: Vec<f32> = (0..120).map(|i| (i as f32).sin()).collect();
        let mut want = vec![0.0; 100];
        spmv_reference(&a, &x, &mut want);
        let mut got = vec![0.0; 100];
        spmv(&a, &x, &mut got, &ThreadPool::new(4));
        assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn vdl_spmm_matches_reference_small_and_large_n() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(302);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(64, 48, 0.15, &mut rng));
        for n in [1usize, 2, 4, 16, 128] {
            let x = DenseMatrix::random(48, n, 1.0, &mut rng);
            let mut want = DenseMatrix::zeros(64, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(64, n);
            spmm(&a, &x, &mut got, &ThreadPool::new(3));
            assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn n_spmv_strawman_matches_vdl() {
        run_prop("n-spmv equals vdl", 20, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let n = *g.choose(&[1usize, 2, 4]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut via_vdl = DenseMatrix::zeros(rows, n);
            spmm(&a, &x, &mut via_vdl, &ThreadPool::serial());
            let mut via_nspvm = DenseMatrix::zeros(rows, n);
            spmm_n_spmv(&a, &x, &mut via_nspvm, &ThreadPool::serial());
            assert_close(&via_nspvm.data, &via_vdl.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn property_vs_reference() {
        run_prop("pr_rs spmm vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let n = *g.choose(&[1usize, 2, 7, 32]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            spmm(&a, &x, &mut got, &ThreadPool::new(2));
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
        });
    }
}
