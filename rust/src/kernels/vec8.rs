//! f32×8 microkernels — the vectorized inner loops behind every kernel.
//!
//! The paper's kernels lean on SIMD-shuffle primitives; on CPU the same
//! hot loops are 8-lane (`f32x8`-shaped, one AVX2 register / two NEON
//! registers) elementwise tiles with a scalar tail. Three backends share
//! one contract:
//!
//! - **scalar** (`*_scalar`): the plain loops the kernels shipped with —
//!   always compiled, the baseline `benches/simd_speedup` measures against;
//! - **tiled** (`*_tiled`): hand-tiled fixed-width loops over
//!   `chunks_exact(LANES)` that every autovectorizer turns into vector
//!   code on stable Rust;
//! - **portable** (`portable_simd` cargo feature, nightly): the tiled
//!   bodies re-expressed over `std::simd::Simd<f32, LANES>` so the lanes
//!   are explicit rather than inferred.
//!
//! Dispatch: the public entry points ([`axpy`], [`add_assign`],
//! [`mul_store`], [`dot`]) pick the tiled path iff the `simd` cargo
//! feature is on, the scalar path otherwise — so a default build's
//! floating-point behavior is byte-for-byte what it was before this
//! module existed.
//!
//! ## Numerics contract
//!
//! The elementwise kernels (`axpy`, `add_assign`, `mul_store`) perform
//! exactly one multiply and/or add per output element: every backend is
//! **bit-for-bit identical** (SpMM's reduction axis is nnz, never the
//! dense width these loops run over, so tiling the width regroups
//! nothing). The reduction kernel `dot_blocked` keeps `LANES` parallel
//! partial sums and merges them in a fixed sequential order — the same
//! order in the tiled and portable backends (the portable body reduces
//! via `to_array`, not a hardware tree), so the two vector backends agree
//! bitwise with *each other*, but both reassociate the sum relative to
//! [`dot_scalar`]. Agreement across that boundary is a ≤ 4-ULP property
//! (`tests/simd_agreement.rs`); no path uses FMA.

/// Vector width of the microkernels (f32 lanes per tile).
pub const LANES: usize = 8;

/// `acc[j] += a * x[j]` — plain scalar loop (always compiled; the
/// baseline the speedup bench measures against).
#[inline]
pub fn axpy_scalar(acc: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (o, &v) in acc.iter_mut().zip(x) {
        *o += a * v;
    }
}

/// `acc[j] += a * x[j]` — 8-lane tiles with a scalar tail. Bit-identical
/// to [`axpy_scalar`] (elementwise; no reassociation).
#[cfg(not(feature = "portable_simd"))]
#[inline]
pub fn axpy_tiled(acc: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ta, tx) in (&mut ac).zip(&mut xc) {
        for l in 0..LANES {
            ta[l] += a * tx[l];
        }
    }
    for (o, &v) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * v;
    }
}

/// `acc[j] += a * x[j]` — `std::simd` lanes (nightly `portable_simd`).
#[cfg(feature = "portable_simd")]
#[inline]
pub fn axpy_tiled(acc: &mut [f32], a: f32, x: &[f32]) {
    use std::simd::Simd;
    debug_assert_eq!(acc.len(), x.len());
    let av = Simd::<f32, LANES>::splat(a);
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ta, tx) in (&mut ac).zip(&mut xc) {
        let out = Simd::<f32, LANES>::from_slice(ta) + av * Simd::<f32, LANES>::from_slice(tx);
        ta.copy_from_slice(&out.to_array());
    }
    for (o, &v) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * v;
    }
}

/// `acc[j] += a * x[j]` with the build's configured backend: tiled when
/// the `simd` feature is on, scalar otherwise.
#[inline]
pub fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
    if cfg!(feature = "simd") {
        axpy_tiled(acc, a, x);
    } else {
        axpy_scalar(acc, a, x);
    }
}

/// `acc[j] += src[j]` — scalar loop.
#[inline]
pub fn add_assign_scalar(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (o, &v) in acc.iter_mut().zip(src) {
        *o += v;
    }
}

/// `acc[j] += src[j]` — 8-lane tiles, scalar tail.
#[cfg(not(feature = "portable_simd"))]
#[inline]
pub fn add_assign_tiled(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (ta, ts) in (&mut ac).zip(&mut sc) {
        for l in 0..LANES {
            ta[l] += ts[l];
        }
    }
    for (o, &v) in ac.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += v;
    }
}

/// `acc[j] += src[j]` — `std::simd` lanes (nightly `portable_simd`).
#[cfg(feature = "portable_simd")]
#[inline]
pub fn add_assign_tiled(acc: &mut [f32], src: &[f32]) {
    use std::simd::Simd;
    debug_assert_eq!(acc.len(), src.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (ta, ts) in (&mut ac).zip(&mut sc) {
        let out = Simd::<f32, LANES>::from_slice(ta) + Simd::<f32, LANES>::from_slice(ts);
        ta.copy_from_slice(&out.to_array());
    }
    for (o, &v) in ac.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += v;
    }
}

/// `acc[j] += src[j]` with the build's configured backend.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    if cfg!(feature = "simd") {
        add_assign_tiled(acc, src);
    } else {
        add_assign_scalar(acc, src);
    }
}

/// `out[j] = a * x[j]` — scalar loop.
#[inline]
pub fn mul_store_scalar(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = a * v;
    }
}

/// `out[j] = a * x[j]` — 8-lane tiles, scalar tail.
#[cfg(not(feature = "portable_simd"))]
#[inline]
pub fn mul_store_tiled(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (to, tx) in (&mut oc).zip(&mut xc) {
        for l in 0..LANES {
            to[l] = a * tx[l];
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = a * v;
    }
}

/// `out[j] = a * x[j]` — `std::simd` lanes (nightly `portable_simd`).
#[cfg(feature = "portable_simd")]
#[inline]
pub fn mul_store_tiled(out: &mut [f32], a: f32, x: &[f32]) {
    use std::simd::Simd;
    debug_assert_eq!(out.len(), x.len());
    let av = Simd::<f32, LANES>::splat(a);
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (to, tx) in (&mut oc).zip(&mut xc) {
        let prod = av * Simd::<f32, LANES>::from_slice(tx);
        to.copy_from_slice(&prod.to_array());
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = a * v;
    }
}

/// `out[j] = a * x[j]` with the build's configured backend.
#[inline]
pub fn mul_store(out: &mut [f32], a: f32, x: &[f32]) {
    if cfg!(feature = "simd") {
        mul_store_tiled(out, a, x);
    } else {
        mul_store_scalar(out, a, x);
    }
}

/// `Σ_j a[j]·b[j]` — plain sequential ascending-`j` accumulation (the
/// order `kernels::dense::sddmm_reference` historically used).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// `Σ_j a[j]·b[j]` — `LANES` parallel partial sums over 8-wide tiles,
/// tail folded lane-wise, then a fixed sequential lane merge
/// (`acc[0] + acc[1] + … + acc[7]`). Deterministic, but the blocking
/// reassociates the sum relative to [`dot_scalar`].
#[cfg(not(feature = "portable_simd"))]
#[inline]
pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let ar = ac.remainder();
    let br = bc.remainder();
    for (ta, tb) in ac.zip(bc) {
        for l in 0..LANES {
            acc[l] += ta[l] * tb[l];
        }
    }
    for (l, (&x, &y)) in ar.iter().zip(br).enumerate() {
        acc[l] += x * y;
    }
    let mut total = 0.0f32;
    for &p in &acc {
        total += p;
    }
    total
}

/// `Σ_j a[j]·b[j]` — `std::simd` accumulator with the same tail and lane
/// merge order as the tiled backend (reduced via `to_array`, not a
/// hardware tree), so the two vector backends agree bit-for-bit.
#[cfg(feature = "portable_simd")]
#[inline]
pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    use std::simd::Simd;
    debug_assert_eq!(a.len(), b.len());
    let mut accv = Simd::<f32, LANES>::splat(0.0);
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let ar = ac.remainder();
    let br = bc.remainder();
    for (ta, tb) in ac.zip(bc) {
        accv = accv + Simd::<f32, LANES>::from_slice(ta) * Simd::<f32, LANES>::from_slice(tb);
    }
    let mut acc = accv.to_array();
    for (l, (&x, &y)) in ar.iter().zip(br).enumerate() {
        acc[l] += x * y;
    }
    let mut total = 0.0f32;
    for &p in &acc {
        total += p;
    }
    total
}

/// Canonical dot product with the build's configured backend: blocked
/// when the `simd` feature is on, sequential otherwise. The SDDMM
/// kernels **and** the dense SDDMM reference both route through this, so
/// within any one build configuration they remain bit-for-bit equal
/// (see `crate::sddmm` module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    if cfg!(feature = "simd") {
        dot_blocked(a, b)
    } else {
        dot_scalar(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn vecs(len: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256::seeded(seed);
        let mut a = vec![0f32; len];
        let mut b = vec![0f32; len];
        rng.fill_uniform_f32(&mut a, 1.0);
        rng.fill_uniform_f32(&mut b, 1.0);
        (a, b)
    }

    /// Map f32 bit patterns onto a monotone integer line (negative values
    /// mirror below zero), so ULP distance is plain integer subtraction.
    fn monotone(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7fff_ffff) as i64)
        } else {
            b as i64
        }
    }

    /// ULP distance between two finite f32 values.
    fn ulp_diff(a: f32, b: f32) -> u64 {
        (monotone(a) - monotone(b)).unsigned_abs()
    }

    #[test]
    fn elementwise_backends_are_bit_identical() {
        // tail lengths 0..LANES and multi-tile bodies
        for len in [0usize, 1, 7, 8, 9, 16, 23, 64, 100] {
            let (x, src) = vecs(len, 9000 + len as u64);
            let a = 0.37f32;

            let mut s = vec![0.25f32; len];
            let mut t = s.clone();
            axpy_scalar(&mut s, a, &x);
            axpy_tiled(&mut t, a, &x);
            assert_eq!(s, t, "axpy len={len}");

            let mut s2 = x.clone();
            let mut t2 = x.clone();
            add_assign_scalar(&mut s2, &src);
            add_assign_tiled(&mut t2, &src);
            assert_eq!(s2, t2, "add_assign len={len}");

            let mut s3 = vec![9.0f32; len];
            let mut t3 = vec![-9.0f32; len];
            mul_store_scalar(&mut s3, a, &x);
            mul_store_tiled(&mut t3, a, &x);
            assert_eq!(s3, t3, "mul_store len={len}");
        }
    }

    #[test]
    fn dot_backends_agree_within_ulps() {
        for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 64, 257] {
            let (a, b) = vecs(len, 9100 + len as u64);
            let seq = dot_scalar(&a, &b);
            let blk = dot_blocked(&a, &b);
            assert!(
                ulp_diff(seq, blk) <= 4,
                "len={len}: {seq} vs {blk} ({} ulps)",
                ulp_diff(seq, blk)
            );
        }
    }

    #[test]
    fn dot_blocked_is_deterministic_and_exact_on_integers() {
        // integer-valued inputs: both orders are exact, so they must agree
        let a: Vec<f32> = (0..37).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| ((i % 3) as f32) - 1.0).collect();
        assert_eq!(dot_scalar(&a, &b), dot_blocked(&a, &b));
        assert_eq!(dot_blocked(&a, &b), dot_blocked(&a, &b));
    }

    #[test]
    fn empty_and_zero_inputs() {
        assert_eq!(dot_scalar(&[], &[]), 0.0);
        assert_eq!(dot_blocked(&[], &[]), 0.0);
        assert_eq!(dot(&[], &[]), 0.0);
        let mut acc: Vec<f32> = Vec::new();
        axpy(&mut acc, 2.0, &[]);
        add_assign(&mut acc, &[]);
        mul_store(&mut acc, 2.0, &[]);
        assert!(acc.is_empty());
    }

    #[test]
    fn dispatch_matches_feature_config() {
        let (a, b) = vecs(50, 9200);
        let want = if cfg!(feature = "simd") {
            dot_blocked(&a, &b)
        } else {
            dot_scalar(&a, &b)
        };
        assert_eq!(dot(&a, &b).to_bits(), want.to_bits());
    }
}
