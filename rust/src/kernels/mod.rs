//! Native CPU implementations of the paper's kernel designs.
//!
//! These serve three purposes:
//!
//! 1. **Correctness cross-check** against the Pallas kernels and the dense
//!    reference (same algorithms, independent implementation);
//! 2. **Wallclock benchmarks** on this machine (`benches/native_kernels`);
//! 3. **Faithful algorithm ports** — `pr_wb` implements the paper's VSR
//!    segmented-scan network literally over 32-lane arrays, so the
//!    shuffle-network logic itself is under test, not just its result.
//!
//! The 2×2 design space (paper Fig. 2):
//!
//! |                    | row-split (RS)       | workload-balanced (WB)  |
//! |--------------------|----------------------|--------------------------|
//! | sequential (SR)    | [`sr_rs`] (+CSC)     | [`sr_wb`]                |
//! | parallel-red. (PR) | [`pr_rs`] (+VDL)     | [`pr_wb`] = VSR (+VDL)   |
//!
//! All kernels compute `Y = A · X` for `A: M×K` sparse, `X: K×N` dense
//! row-major, `Y: M×N` dense row-major. SpMV is the `N = 1` case.
//!
//! Callers never dispatch these directly: execution goes through
//! [`crate::backend::SpmmBackend`] (`DESIGN.md` §Execution backends);
//! the warp-to-VPU mapping behind the ports is described in `DESIGN.md`
//! §Hardware-Adaptation.
//!
//! Two cross-cutting modules support the designs rather than add new
//! ones: [`vec8`] holds the 8-lane dense-width microkernels every inner
//! loop routes through (scalar / hand-tiled / `std::simd`, selected by
//! the `simd` and `portable_simd` cargo features — `DESIGN.md`
//! §Vectorization), and [`merge_path`] is an alternative row traversal
//! for the SR family ([`Traversal::MergePath`]) that splits the merged
//! `rows + nnz` decision path evenly across workers.

pub mod baseline;
pub mod dense;
pub mod generator;
pub mod merge_path;
pub mod pr_rs;
pub mod pr_wb;
pub mod sr_rs;
pub mod sr_wb;
pub mod variant;
pub mod vec8;

pub use generator::{registry, VariantEntry, VariantRegistry};
pub use variant::KernelVariant;

/// Lane count of the simulated SIMD bundle (a CUDA warp; maps to a VPU
/// sublane group on TPU). The paper's kernels are written against 32.
pub const WARP: usize = 32;

/// The sparse operations the execution stack routes. The paper's design
/// space was built for SpMM/SpMV; `crate::sddmm` instantiates the same
/// 2×2 space for SDDMM (`S = sample(A, U·Vᵀ)`), SpMM's companion op in
/// attention-style GNN workloads, and the serving layer tags requests and
/// metrics with this.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SparseOp {
    /// Dense-output sparse-dense matmul `Y = A · X`.
    Spmm,
    /// Sampled dense-dense matmul `S = sample(A, U·Vᵀ)` (sparse output on
    /// A's pattern).
    Sddmm,
}

impl SparseOp {
    /// Short label used in logs and artifact names.
    pub fn label(&self) -> &'static str {
        match self {
            SparseOp::Spmm => "spmm",
            SparseOp::Sddmm => "sddmm",
        }
    }
}

/// The four kernel designs of the paper's 2×2 space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Sequential reduction, row split (CSR-scalar / cuSPARSE-default-like).
    SrRs,
    /// Sequential reduction over fixed-nnz segments (merge-path-like).
    SrWb,
    /// Parallel reduction, row split (CSR-vector).
    PrRs,
    /// Parallel reduction, workload-balanced — the paper's VSR.
    PrWb,
}

impl KernelKind {
    /// All four designs in a fixed order (bench iteration order).
    pub const ALL: [KernelKind; 4] = [
        KernelKind::SrRs,
        KernelKind::SrWb,
        KernelKind::PrRs,
        KernelKind::PrWb,
    ];

    /// Short label used in bench output and the manifest.
    pub fn label(&self) -> &'static str {
        match self {
            KernelKind::SrRs => "sr_rs",
            KernelKind::SrWb => "sr_wb",
            KernelKind::PrRs => "pr_rs",
            KernelKind::PrWb => "pr_wb",
        }
    }

    /// Parse from a label.
    pub fn from_label(s: &str) -> Option<KernelKind> {
        Self::ALL.iter().copied().find(|k| k.label() == s)
    }

    /// Whether this design uses workload-balancing (nnz-split).
    pub fn is_balanced(&self) -> bool {
        matches!(self, KernelKind::SrWb | KernelKind::PrWb)
    }

    /// Whether this design uses parallel reduction.
    pub fn is_parallel_reduction(&self) -> bool {
        matches!(self, KernelKind::PrRs | KernelKind::PrWb)
    }
}

/// Row-traversal strategy for the sequential-reduction (SR) designs.
/// Orthogonal to [`KernelKind`]: the reduction order per row is unchanged,
/// only how rows/non-zeros are walked and divided among workers differs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Traversal {
    /// Contiguous row blocks (the kernels' native chunking).
    Blocked,
    /// Equal spans of the merged `rows + nnz` path ([`merge_path`]) —
    /// robust to row-length skew.
    MergePath,
}

impl Traversal {
    /// Short label used in artifacts and logs.
    pub fn label(&self) -> &'static str {
        match self {
            Traversal::Blocked => "blocked",
            Traversal::MergePath => "merge_path",
        }
    }
}

// NOTE: the former `PreparedMatrix` / `run_kernel` free-function dispatch
// path lives in `crate::backend::NativeBackend` now — prepare-once /
// execute-many goes through the `SpmmBackend` trait so the native kernels
// and the PJRT artifacts share one pipeline.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::from_label(k.label()), Some(k));
        }
        assert_eq!(KernelKind::from_label("nope"), None);
    }

    #[test]
    fn design_space_flags() {
        assert!(!KernelKind::SrRs.is_balanced());
        assert!(KernelKind::SrWb.is_balanced());
        assert!(KernelKind::PrWb.is_balanced());
        assert!(!KernelKind::SrRs.is_parallel_reduction());
        assert!(KernelKind::PrRs.is_parallel_reduction());
        assert!(KernelKind::PrWb.is_parallel_reduction());
    }
}
