//! Merge-path row traversal for the SR family (Merrill & Garland's
//! merge-based SpMV, the CPU analogue per Bergmans et al., "Algorithms
//! for Parallel Shared-Memory SpMV on Unstructured Matrices").
//!
//! Row-split SR hands each worker whole rows, so one pathological row
//! serializes a worker; segment-split SR-WB balances non-zeros but pays
//! for the segmented layout. Merge-path splits the *merged decision
//! path* of length `rows + nnz` — the interleaving of "advance to the
//! next row" and "consume one non-zero" events — into equal spans with a
//! diagonal binary search over CSR `indptr`. Each worker gets the same
//! event count regardless of skew, lands mid-row when it must, and works
//! straight off CSR (no auxiliary layout, cache-friendly sequential
//! `indices`/`values` streams).
//!
//! Cross-worker row sharing reuses the carry scheme of
//! [`crate::kernels::sr_wb`]: a worker's first row may be shared with its
//! predecessor and is carried to a sequential fix-up; rows that *end*
//! strictly inside a worker's span are written directly (exclusive by
//! construction). Reduction per row stays sequential in ascending-`k`
//! order, so a single-worker run is bit-for-bit the dense reference.

use crate::kernels::sr_wb::SharedRows;
use crate::kernels::vec8;
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::threadpool::ThreadPool;

/// One split point on the merge path: `(row, nnz_offset)`. The worker
/// starting here resumes row `row` at its `nnz_offset`-th stored element
/// (global index into `values`).
pub type Split = (usize, usize);

/// Diagonal binary search: the split `(i, d - i)` of diagonal `d` on the
/// merge of row-end events (`indptr[1..]`) with the non-zero stream.
/// Returns the smallest `i` such that `indptr[i + 1] > d - i - 1`, i.e.
/// all row-end events before `i` precede all non-zeros from `d - i` on
/// (ties consume the row-end first, so empty trailing rows close on the
/// earlier worker).
fn diagonal_search(indptr: &[u32], rows: usize, nnz: usize, d: usize) -> Split {
    let mut lo = d.saturating_sub(nnz);
    let mut hi = d.min(rows);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if (indptr[mid + 1] as usize) <= d - mid - 1 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    (lo, d - lo)
}

/// Equal-length merge-path partition into `parts` spans: `parts + 1`
/// split points, first `(0, 0)`, last `(rows, nnz)`.
pub fn partition(a: &CsrMatrix, parts: usize) -> Vec<Split> {
    let parts = parts.max(1);
    let nnz = a.nnz();
    let total = a.rows + nnz;
    let per = total.div_ceil(parts.max(1)).max(1);
    let mut splits = Vec::with_capacity(parts + 1);
    for w in 0..=parts {
        let d = (w * per).min(total);
        splits.push(diagonal_search(&a.indptr, a.rows, nnz, d));
    }
    splits
}

/// Merge-path SR SpMM: sequential per-row reduction under an nnz+rows
/// balanced traversal. Same signature and result as
/// [`crate::kernels::sr_rs::spmm`]; selected by the backend when the
/// traversal rules call for it (`DESIGN.md` §Vectorization).
pub fn spmm(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    y.data.fill(0.0);
    if a.rows == 0 || n == 0 || a.nnz() == 0 {
        return;
    }

    let pool = &pool.for_work(a.nnz() * n);
    let workers = pool.workers().min(a.rows + a.nnz()).max(1);
    let splits = partition(a, workers);
    let shared = SharedRows::new(&mut y.data, n);

    let carries: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let shared = &shared;
            let start = splits[w];
            let end = splits[w + 1];
            handles.push(scope.spawn(move || worker_span(a, x, shared, start, end)));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    // sequential fix-up: add boundary partials (ascending worker order)
    for (row, partial) in carries {
        let out = &mut y.data[row * n..(row + 1) * n];
        vec8::add_assign(out, &partial);
    }
}

/// Consume the merge-path span `[start, end)`: rows `start.0 .. end.0`
/// close inside the span (direct write except the possibly-shared first
/// row), plus a trailing partial of row `end.0` when `end.1` lands
/// mid-row.
fn worker_span(
    a: &CsrMatrix,
    x: &DenseMatrix,
    y: &SharedRows,
    (r0, k0): Split,
    (r1, k1): Split,
) -> Vec<(usize, Vec<f32>)> {
    let n = x.cols;
    if r0 == r1 && k0 == k1 {
        return Vec::new();
    }
    let mut carries: Vec<(usize, Vec<f32>)> = Vec::new();
    let mut acc = vec![0f32; n];
    let mut k = k0;

    let gather = |acc: &mut [f32], lo: usize, hi: usize| {
        for i in lo..hi {
            let v = a.values[i];
            let xrow = x.row(a.indices[i] as usize);
            vec8::axpy(acc, v, xrow);
        }
    };

    // rows whose end event lies in this span
    for r in r0..r1 {
        let end = (a.indptr[r + 1] as usize).min(k1);
        if end > k {
            gather(&mut acc, k, end);
            k = end;
        }
        if r == r0 {
            // may be shared with the previous worker → fix-up adds it
            carries.push((r, std::mem::replace(&mut acc, vec![0f32; n])));
        } else {
            // this span owns the row's end (and, since r > r0, its whole
            // remaining nnz range) — exclusive direct write.
            // SAFETY: per the SharedRows ownership contract; row ranges
            // (r0, r1) of distinct workers are disjoint.
            let out = unsafe { y.row_mut(r) };
            vec8::add_assign(out, &acc);
            acc.fill(0.0);
        }
    }
    // trailing partial row: continues into the next span → carry
    if k < k1 {
        gather(&mut acc, k, k1);
        carries.push((r1, acc));
    }
    carries
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::kernels::sr_rs;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::{assert_close, run_prop};

    #[test]
    fn partition_covers_the_path_monotonically() {
        let mut rng = Xoshiro256::seeded(601);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(100, 80, 0.1, &mut rng));
        for parts in [1usize, 2, 3, 7, 16] {
            let splits = partition(&a, parts);
            assert_eq!(splits.len(), parts + 1);
            assert_eq!(splits[0], (0, 0));
            assert_eq!(splits[parts], (a.rows, a.nnz()));
            for w in 0..parts {
                let (r0, k0) = splits[w];
                let (r1, k1) = splits[w + 1];
                assert!(r0 <= r1 && k0 <= k1, "non-monotone split at {w}");
                // split lands inside the row it names
                assert!(k0 >= a.indptr[r0] as usize, "k below row start at {w}");
                if r0 < a.rows {
                    assert!(k0 <= a.indptr[r0 + 1] as usize, "k past row end at {w}");
                }
                // equal spans (±1 from div_ceil rounding at the tail)
                let span = (r1 - r0) + (k1 - k0);
                let per = (a.rows + a.nnz()).div_ceil(parts);
                assert!(span <= per, "span {span} > per {per} at {w}");
            }
        }
    }

    #[test]
    fn single_worker_is_bitwise_the_reference() {
        let mut rng = Xoshiro256::seeded(602);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 50, 0.15, &mut rng));
        let x = DenseMatrix::random(50, 9, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(60, 9);
        spmm_reference(&a, &x, &mut want);
        let mut got = DenseMatrix::zeros(60, 9);
        spmm(&a, &x, &mut got, &ThreadPool::serial());
        // identical gather order → identical bits (axpy is elementwise)
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn skewed_row_spanning_all_workers() {
        // one row holds nearly all nnz — the case row-split serializes
        let mut coo = CooMatrix::new(50, 300);
        for c in 0..300 {
            coo.push(7, c, 0.01 * c as f32);
        }
        for r in 0..50 {
            coo.push(r, r % 300, 1.0);
        }
        let a = CsrMatrix::from_coo(&coo);
        let mut rng = Xoshiro256::seeded(603);
        for n in [1usize, 4, 33] {
            let x = DenseMatrix::random(300, n, 1.0, &mut rng);
            let mut want = DenseMatrix::zeros(50, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(50, n);
            spmm(&a, &x, &mut got, &ThreadPool::new(6));
            assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(5, 5));
        let x = DenseMatrix::zeros(5, 4);
        let mut y = DenseMatrix::from_vec(5, 4, vec![9.0; 20]);
        spmm(&a, &x, &mut y, &ThreadPool::new(2));
        assert_eq!(y.data, vec![0.0; 20]);

        // rows with no nnz interleaved with populated rows
        let mut coo = CooMatrix::new(6, 6);
        coo.push(1, 1, 2.0);
        coo.push(4, 0, -1.0);
        let a = CsrMatrix::from_coo(&coo);
        let x = DenseMatrix::from_vec(6, 2, (0..12).map(|i| i as f32).collect());
        let mut want = DenseMatrix::zeros(6, 2);
        spmm_reference(&a, &x, &mut want);
        let mut got = DenseMatrix::zeros(6, 2);
        spmm(&a, &x, &mut got, &ThreadPool::new(3));
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn property_vs_reference_and_sr_rs() {
        run_prop("merge_path spmm vs reference", 25, |g| {
            let rows = g.dim() * 2;
            let cols = g.dim() * 2;
            let n = *g.choose(&[1usize, 3, 8, 32]);
            let workers = *g.choose(&[1usize, 2, 5, 9]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.2, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            spmm(&a, &x, &mut got, &ThreadPool::new(workers));
            assert_close(&got.data, &want.data, 1e-4, 1e-4)?;
            let mut via_rs = DenseMatrix::zeros(rows, n);
            sr_rs::spmm(&a, &x, &mut via_rs, &ThreadPool::new(workers));
            assert_close(&got.data, &via_rs.data, 1e-4, 1e-4)
        });
    }
}
