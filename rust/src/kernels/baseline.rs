//! Comparison baselines: a cuSPARSE-like adaptive vendor kernel and a
//! simplified ASpT (adaptive sparse tiling, Hong et al. PPoPP'19).
//!
//! These are the native counterparts of `sim::sched_cusparse` /
//! `sim::sched_aspt`; the paper compares against both (Fig. 6). See
//! `DESIGN.md` §Substitutions for what is and is not modeled.

use super::{pr_rs, sr_rs, WARP};
use crate::features::MatrixFeatures;
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::threadpool::ThreadPool;

/// cuSPARSE-csrmm-like baseline: row-split sequential reduction with a
/// light adaptive twist (CSR-Adaptive heuristics): short-row matrices take
/// the scalar row-per-thread path, long-row matrices take the vector path.
/// No nnz-level workload balancing — that is exactly the gap the paper
/// exploits on skewed inputs.
pub fn cusparse_like_spmm(
    a: &CsrMatrix,
    x: &DenseMatrix,
    y: &mut DenseMatrix,
    pool: &ThreadPool,
) {
    let feats = MatrixFeatures::of(a);
    if feats.avg_row >= WARP as f64 {
        // long rows: vector path (one lane bundle per row)
        pr_rs::spmm(a, x, y, pool);
    } else {
        // short rows: scalar path
        sr_rs::spmm(a, x, y, pool);
    }
}

/// cuSPARSE-csrmv-like baseline (N = 1).
pub fn cusparse_like_spmv(a: &CsrMatrix, x: &[f32], y: &mut [f32], pool: &ThreadPool) {
    let feats = MatrixFeatures::of(a);
    if feats.avg_row >= WARP as f64 {
        pr_rs::spmv(a, x, y, pool);
    } else {
        sr_rs::spmv(a, x, y, pool);
    }
}

/// Row-panel height used by the ASpT-like baseline.
pub const ASPT_PANEL: usize = 32;
/// A column is "dense" within a panel when it has at least this many
/// non-zeros in the panel.
pub const ASPT_DENSE_THRESHOLD: usize = 8;

/// Preprocessed ASpT operand: per row panel, the columns are split into
/// *dense tiles* (columns with many non-zeros in the panel, processed with
/// dense-row reuse) and a *sparse remainder* (CSR stream).
pub struct AsptMatrix {
    pub rows: usize,
    pub cols: usize,
    panels: Vec<Panel>,
}

struct Panel {
    row_lo: usize,
    row_hi: usize,
    /// columns classified dense in this panel
    dense_cols: Vec<u32>,
    /// per dense column: (local_row, value) pairs
    dense_entries: Vec<Vec<(u32, f32)>>,
    /// CSR remainder: per local row, (col, value) pairs
    sparse_rows: Vec<Vec<(u32, f32)>>,
}

impl AsptMatrix {
    /// Classify columns per panel (the "adaptive tiling" preprocessing;
    /// ASpT amortizes this over many SpMM invocations, and so do we: it
    /// runs outside the benchmarked region).
    pub fn from_csr(a: &CsrMatrix) -> Self {
        let mut panels = Vec::new();
        let mut row_lo = 0;
        while row_lo < a.rows {
            let row_hi = (row_lo + ASPT_PANEL).min(a.rows);
            // count nnz per column within the panel
            let mut col_count: std::collections::HashMap<u32, usize> =
                std::collections::HashMap::new();
            for r in row_lo..row_hi {
                let (cols, _) = a.row(r);
                for &c in cols {
                    *col_count.entry(c).or_insert(0) += 1;
                }
            }
            let mut dense_cols: Vec<u32> = col_count
                .iter()
                .filter(|&(_, &n)| n >= ASPT_DENSE_THRESHOLD)
                .map(|(&c, _)| c)
                .collect();
            dense_cols.sort_unstable();
            let dense_set: std::collections::HashSet<u32> =
                dense_cols.iter().copied().collect();
            let mut dense_entries: Vec<Vec<(u32, f32)>> =
                dense_cols.iter().map(|_| Vec::new()).collect();
            let col_slot: std::collections::HashMap<u32, usize> = dense_cols
                .iter()
                .enumerate()
                .map(|(i, &c)| (c, i))
                .collect();
            let mut sparse_rows: Vec<Vec<(u32, f32)>> =
                (row_lo..row_hi).map(|_| Vec::new()).collect();
            for r in row_lo..row_hi {
                let (cols, vals) = a.row(r);
                for k in 0..cols.len() {
                    if dense_set.contains(&cols[k]) {
                        dense_entries[col_slot[&cols[k]]].push((
                            (r - row_lo) as u32,
                            vals[k],
                        ));
                    } else {
                        sparse_rows[r - row_lo].push((cols[k], vals[k]));
                    }
                }
            }
            panels.push(Panel {
                row_lo,
                row_hi,
                dense_cols,
                dense_entries,
                sparse_rows,
            });
            row_lo = row_hi;
        }
        Self {
            rows: a.rows,
            cols: a.cols,
            panels,
        }
    }

    /// Fraction of non-zeros that landed in dense tiles — the quantity
    /// that determines ASpT's advantage (and what the simulator uses).
    pub fn dense_fraction(&self) -> f64 {
        let mut dense = 0usize;
        let mut total = 0usize;
        for p in &self.panels {
            dense += p.dense_entries.iter().map(|e| e.len()).sum::<usize>();
            total += dense_in_panel_total(p);
        }
        if total == 0 {
            0.0
        } else {
            dense as f64 / total as f64
        }
    }
}

/// Per-panel statistics consumed by the simulator's ASpT schedule.
#[derive(Clone, Copy, Debug)]
pub struct AsptPanelStats {
    /// rows in the panel
    pub rows: usize,
    /// columns classified dense
    pub dense_cols: usize,
    /// non-zeros living in dense tiles
    pub dense_entries: usize,
    /// non-zeros in the sparse remainder
    pub sparse_entries: usize,
}

impl AsptMatrix {
    /// Summaries of each panel for the cost model.
    pub fn panel_stats(&self) -> Vec<AsptPanelStats> {
        self.panels
            .iter()
            .map(|p| AsptPanelStats {
                rows: p.row_hi - p.row_lo,
                dense_cols: p.dense_cols.len(),
                dense_entries: p.dense_entries.iter().map(|e| e.len()).sum(),
                sparse_entries: p.sparse_rows.iter().map(|r| r.len()).sum(),
            })
            .collect()
    }
}

fn dense_in_panel_total(p: &Panel) -> usize {
    p.dense_entries.iter().map(|e| e.len()).sum::<usize>()
        + p.sparse_rows.iter().map(|r| r.len()).sum::<usize>()
}

/// ASpT-like SpMM: dense tiles first (dense-row reuse: the X row is loaded
/// once per panel and reused by every panel row touching that column),
/// then the sparse remainder.
pub fn aspt_like_spmm(a: &AsptMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    y.data.fill(0.0);
    let panels = &a.panels;
    pool.run_dynamic(panels.len(), 1, |range| {
        for pi in range {
            let p = &panels[pi];
            // panels own disjoint row ranges → disjoint output slices.
            // SAFETY: same argument as SharedRows; expressed here through a
            // raw pointer because the panel loop is data-parallel by rows.
            let y_ptr = y.data.as_ptr() as *mut f32;
            let out = unsafe {
                std::slice::from_raw_parts_mut(
                    y_ptr.add(p.row_lo * n),
                    (p.row_hi - p.row_lo) * n,
                )
            };
            // dense tiles: one X-row load, many row updates (the reuse)
            for (slot, &c) in p.dense_cols.iter().enumerate() {
                let xrow = x.row(c as usize);
                for &(lr, v) in &p.dense_entries[slot] {
                    let orow = &mut out[lr as usize * n..(lr as usize + 1) * n];
                    for j in 0..n {
                        orow[j] += v * xrow[j];
                    }
                }
            }
            // sparse remainder: plain CSR stream
            for (lr, entries) in p.sparse_rows.iter().enumerate() {
                let orow = &mut out[lr * n..(lr + 1) * n];
                for &(c, v) in entries {
                    let xrow = x.row(c as usize);
                    for j in 0..n {
                        orow[j] += v * xrow[j];
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::{spmm_reference, spmv_reference};
    use crate::sparse::CooMatrix;
    use crate::util::proptest::{assert_close, run_prop};

    #[test]
    fn cusparse_like_matches_reference_both_paths() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(501);
        // short-row matrix (scalar path) and long-row matrix (vector path)
        let short = CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 80, 0.05, &mut rng));
        let long = CsrMatrix::from_coo(&CooMatrix::random_uniform(40, 400, 0.3, &mut rng));
        let pool = ThreadPool::new(3);
        for a in [&short, &long] {
            let x = DenseMatrix::random(a.cols, 8, 1.0, &mut rng);
            let mut want = DenseMatrix::zeros(a.rows, 8);
            spmm_reference(a, &x, &mut want);
            let mut got = DenseMatrix::zeros(a.rows, 8);
            cusparse_like_spmm(a, &x, &mut got, &pool);
            assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();

            let xv: Vec<f32> = (0..a.cols).map(|i| (i as f32).cos()).collect();
            let mut wantv = vec![0.0; a.rows];
            spmv_reference(a, &xv, &mut wantv);
            let mut gotv = vec![0.0; a.rows];
            cusparse_like_spmv(a, &xv, &mut gotv, &pool);
            assert_close(&gotv, &wantv, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn aspt_split_preserves_all_entries() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(502);
        let a = CsrMatrix::from_coo(&crate::gen::blockdiag::block_random(
            4, 32, 0.3, 0.6, &mut rng,
        ));
        let t = AsptMatrix::from_csr(&a);
        let kept: usize = t
            .panels
            .iter()
            .map(dense_in_panel_total)
            .sum();
        assert_eq!(kept, a.nnz());
        // clustered matrix should put a sizable share into dense tiles
        assert!(t.dense_fraction() > 0.3, "dense frac {}", t.dense_fraction());
    }

    #[test]
    fn aspt_dense_fraction_low_for_scattered_matrix() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(503);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(256, 4096, 0.002, &mut rng));
        let t = AsptMatrix::from_csr(&a);
        assert!(t.dense_fraction() < 0.1, "dense frac {}", t.dense_fraction());
    }

    #[test]
    fn aspt_matches_reference_property() {
        run_prop("aspt spmm vs reference", 25, |g| {
            let rows = g.dim() * 3;
            let cols = g.dim() * 2;
            let n = *g.choose(&[1usize, 4, 16]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let t = AsptMatrix::from_csr(&a);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            aspt_like_spmm(&t, &x, &mut got, &ThreadPool::new(3));
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
        });
    }
}
