//! SR-RS — sequential reduction, row split (paper Fig. 2(a) baseline),
//! plus the CSC (coalesced sparse-row caching) optimization of §2.1.3.
//!
//! On the GPU, SR-RS assigns each row to a thread (CSR-scalar) or each row
//! to a warp iterating sequentially; here each pool worker owns a block of
//! rows. The CSC variant stages each 32-nnz chunk of the sparse row into a
//! stack scratch buffer first (the CUDA version stages into shared memory
//! with one coalesced load), then streams the dense rows — the structure
//! the paper uses to keep vectorized sparse loads under sequential
//! reduction.
//!
//! The dense-width inner loop is the [`crate::kernels::vec8`] `axpy`
//! microkernel: the per-nnz `n.max(1)` and bounds checks the original
//! scalar loop paid are hoisted out (iterator zips over `cols`/`vals`,
//! 8-lane tiles over the dense row), and with the `simd` feature the
//! tiles run vectorized. Elementwise over the dense width, so every
//! configuration is bit-for-bit identical.

use super::{vec8, WARP};
use crate::sparse::{AlignedDense, CsrMatrix, DenseMatrix, DenseX};
use crate::util::threadpool::ThreadPool;

/// Rows per parallel work item.
const ROW_CHUNK: usize = 64;

/// Generic-over-`X` body shared by [`spmm`] (packed rows) and
/// [`spmm_aligned`] (padded aligned rows).
fn spmm_impl<X: DenseX>(a: &CsrMatrix, x: &X, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.xrows(), "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.xcols()), "output shape mismatch");
    let n = x.xcols();
    let w = n.max(1); // hoisted: the row-chunk width never changes per nnz
    let pool = &pool.for_work(a.nnz() * w);
    pool.for_each_row_chunk(&mut y.data, w, ROW_CHUNK, |first_row, rows| {
        rows.fill(0.0);
        let nrows = rows.len() / w;
        for i in 0..nrows {
            let r = first_row + i;
            if r >= a.rows {
                break;
            }
            let (cols, vals) = a.row(r);
            let out = &mut rows[i * n..(i + 1) * n];
            for (&c, &v) in cols.iter().zip(vals) {
                vec8::axpy(out, v, x.xrow(c as usize));
            }
        }
    });
}

/// Plain SR-RS SpMM: each worker scans its rows sequentially.
pub fn spmm(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    spmm_impl(a, x, y, pool);
}

/// SR-RS SpMM gathering from the aligned padded-stride dense layout
/// ([`AlignedDense`]) — vector loads never straddle a row boundary.
/// Bit-identical results to [`spmm`] on the same logical `X`.
pub fn spmm_aligned(a: &CsrMatrix, x: &AlignedDense, y: &mut DenseMatrix, pool: &ThreadPool) {
    spmm_impl(a, x, y, pool);
}

/// SR-RS SpMM with **CSC** (coalesced sparse-row caching): row chunks of
/// `WARP` non-zeros are staged into a scratch buffer before the dense
/// accumulation loop. Functionally identical to [`spmm`]; structurally it
/// is the paper's §2.1.3 kernel and is what the simulator models as
/// `SrRs + csc`.
pub fn spmm_csc(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix, pool: &ThreadPool) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!((y.rows, y.cols), (a.rows, x.cols), "output shape mismatch");
    let n = x.cols;
    let w = n.max(1);
    let pool = &pool.for_work(a.nnz() * w);
    pool.for_each_row_chunk(&mut y.data, w, ROW_CHUNK, |first_row, rows| {
        rows.fill(0.0);
        let nrows = rows.len() / w;
        // "shared memory" tiles: one coalesced load of WARP (value, col)
        // pairs, then sequential iteration over the cached entries.
        let mut val_tile = [0f32; WARP];
        let mut col_tile = [0u32; WARP];
        for i in 0..nrows {
            let r = first_row + i;
            if r >= a.rows {
                break;
            }
            let (cols, vals) = a.row(r);
            let out = &mut rows[i * n..(i + 1) * n];
            let mut k = 0;
            while k < cols.len() {
                let tile = (cols.len() - k).min(WARP);
                // coalesced stage-in (the CUDA kernel does this with one
                // vector load per warp)
                val_tile[..tile].copy_from_slice(&vals[k..k + tile]);
                col_tile[..tile].copy_from_slice(&cols[k..k + tile]);
                // sequential reduction over the cached tile
                for t in 0..tile {
                    vec8::axpy(out, val_tile[t], x.row(col_tile[t] as usize));
                }
                k += tile;
            }
        }
    });
}

/// SR-RS SpMV (N = 1 fast path; avoids the inner-column loop).
pub fn spmv(a: &CsrMatrix, x: &[f32], y: &mut [f32], pool: &ThreadPool) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let pool = &pool.for_work(a.nnz());
    pool.for_each_row_chunk(y, 1, ROW_CHUNK * 4, |first_row, out| {
        for (i, o) in out.iter_mut().enumerate() {
            let r = first_row + i;
            if r >= a.rows {
                break;
            }
            let (cols, vals) = a.row(r);
            let mut acc = 0.0f32;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c as usize];
            }
            *o = acc;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::{spmm_reference, spmv_reference};
    use crate::sparse::CooMatrix;
    use crate::util::proptest::{assert_close, run_prop};

    fn check_vs_reference(rows: usize, cols: usize, n: usize, density: f64, seed: u64) {
        let mut rng = crate::util::prng::Xoshiro256::seeded(seed);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, &mut rng));
        let x = DenseMatrix::random(cols, n, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(rows, n);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(4);
        for f in [spmm, spmm_csc] {
            let mut got = DenseMatrix::zeros(rows, n);
            f(&a, &x, &mut got, &pool);
            assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn matches_reference_various_shapes() {
        check_vs_reference(50, 40, 8, 0.1, 101);
        check_vs_reference(128, 128, 1, 0.05, 102);
        check_vs_reference(7, 200, 33, 0.3, 103);
        check_vs_reference(200, 7, 2, 0.5, 104);
    }

    #[test]
    fn long_rows_exercise_csc_tiling() {
        // rows longer than WARP force multiple scratch tiles
        let mut coo = CooMatrix::new(4, 200);
        for c in 0..200 {
            coo.push(1, c, (c as f32) * 0.01);
        }
        let a = CsrMatrix::from_coo(&coo);
        let mut rng = crate::util::prng::Xoshiro256::seeded(105);
        let x = DenseMatrix::random(200, 16, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(4, 16);
        spmm_reference(&a, &x, &mut want);
        let mut got = DenseMatrix::zeros(4, 16);
        spmm_csc(&a, &x, &mut got, &ThreadPool::serial());
        assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn aligned_gather_is_bit_identical() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(106);
        // widths around the lane boundary exercise padded strides
        for n in [1usize, 7, 8, 9, 32, 33] {
            let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(40, 30, 0.2, &mut rng));
            let x = DenseMatrix::random(30, n, 1.0, &mut rng);
            let xa = x.to_aligned();
            let mut packed = DenseMatrix::zeros(40, n);
            spmm(&a, &x, &mut packed, &ThreadPool::new(3));
            let mut aligned = DenseMatrix::zeros(40, n);
            spmm_aligned(&a, &xa, &mut aligned, &ThreadPool::new(3));
            for (p, q) in packed.data.iter().zip(&aligned.data) {
                assert_eq!(p.to_bits(), q.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn spmv_matches_reference_property() {
        run_prop("sr_rs spmv vs reference", 30, |g| {
            let rows = g.dim() * 2;
            let cols = g.dim() * 2;
            let coo = CooMatrix::random_uniform(rows, cols, 0.2, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let x = g.vec_f32(cols);
            let mut want = vec![0.0; rows];
            spmv_reference(&a, &x, &mut want);
            let mut got = vec![0.0; rows];
            spmv(&a, &x, &mut got, &ThreadPool::new(2));
            assert_close(&got, &want, 1e-5, 1e-5)
        });
    }

    #[test]
    fn spmm_matches_reference_property() {
        run_prop("sr_rs spmm vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let n = *g.choose(&[1usize, 2, 4, 17, 32]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            spmm_reference(&a, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            spmm(&a, &x, &mut got, &ThreadPool::serial());
            assert_close(&got.data, &want.data, 1e-5, 1e-5)?;
            let mut got2 = DenseMatrix::zeros(rows, n);
            spmm_csc(&a, &x, &mut got2, &ThreadPool::serial());
            assert_close(&got2.data, &want.data, 1e-5, 1e-5)
        });
    }
}
