//! Dense reference SpMM — the correctness oracle for every other kernel.

use crate::sparse::{CsrMatrix, DenseMatrix};

/// Straightforward `Y = A · X` by row-wise gather; no threading, no tricks.
/// O(nnz · N). Every other kernel is tested against this.
pub fn spmm_reference(a: &CsrMatrix, x: &DenseMatrix, y: &mut DenseMatrix) {
    assert_eq!(a.cols, x.rows, "inner dimension mismatch");
    assert_eq!(y.rows, a.rows, "output rows mismatch");
    assert_eq!(y.cols, x.cols, "output cols mismatch");
    let n = x.cols;
    y.data.fill(0.0);
    for r in 0..a.rows {
        let (cols, vals) = a.row(r);
        let out = &mut y.data[r * n..(r + 1) * n];
        for k in 0..cols.len() {
            let xrow = x.row(cols[k] as usize);
            let v = vals[k];
            for j in 0..n {
                out[j] += v * xrow[j];
            }
        }
    }
}

/// Dense reference SDDMM — the correctness oracle for `crate::sddmm`.
///
/// `out[k] = a.values[k] * Σ_j u[r_k][j] · v[c_k][j]` for the `k`-th
/// non-zero `(r_k, c_k)` of `A`, in CSR stream order. The inner dot is
/// [`crate::kernels::vec8::dot`] — ascending-`j` order by default, the
/// 8-accumulator blocked order under the `simd` feature. Every SDDMM
/// kernel uses the same canonical order in the same configuration, so
/// agreement tests can pin **bit-for-bit** equality either way (see
/// `crate::sddmm` module docs, "Canonical dot under `simd`").
pub fn sddmm_reference(a: &CsrMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32]) {
    assert_eq!(u.rows, a.rows, "U rows mismatch");
    assert_eq!(v.rows, a.cols, "V rows mismatch");
    assert_eq!(u.cols, v.cols, "U/V width mismatch");
    assert_eq!(out.len(), a.nnz(), "output length mismatch");
    for r in 0..a.rows {
        let (cols, vals) = a.row(r);
        let base = a.indptr[r] as usize;
        let urow = u.row(r);
        for k in 0..cols.len() {
            let vrow = v.row(cols[k] as usize);
            out[base + k] = vals[k] * crate::kernels::vec8::dot(urow, vrow);
        }
    }
}

/// SpMV convenience wrapper over the reference (N = 1).
pub fn spmv_reference(a: &CsrMatrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let xm = DenseMatrix::from_vec(x.len(), 1, x.to_vec());
    let mut ym = DenseMatrix::zeros(y.len(), 1);
    spmm_reference(a, &xm, &mut ym);
    y.copy_from_slice(&ym.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    #[test]
    fn known_product() {
        // A = [[1, 2], [0, 3]], X = [[1, 10], [2, 20]]
        let mut coo = CooMatrix::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 1, 3.0);
        let a = CsrMatrix::from_coo(&coo);
        let x = DenseMatrix::from_vec(2, 2, vec![1.0, 10.0, 2.0, 20.0]);
        let mut y = DenseMatrix::zeros(2, 2);
        spmm_reference(&a, &x, &mut y);
        assert_eq!(y.data, vec![5.0, 50.0, 6.0, 60.0]);
    }

    #[test]
    fn spmv_matches_spmm_column() {
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 2, 1.5);
        coo.push(2, 0, -2.0);
        coo.push(2, 2, 4.0);
        let a = CsrMatrix::from_coo(&coo);
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        spmv_reference(&a, &x, &mut y);
        assert_eq!(y, [4.5, 0.0, 10.0]);
    }

    #[test]
    fn sddmm_known_product() {
        // A = [[2, 0], [0, 3]], U = [[1, 2], [3, 4]], V = [[5, 6], [7, 8]]
        // S[0,0] = 2 * (1*5 + 2*6) = 34; S[1,1] = 3 * (3*7 + 4*8) = 159
        let mut coo = CooMatrix::new(2, 2);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 3.0);
        let a = CsrMatrix::from_coo(&coo);
        let u = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let v = DenseMatrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let mut out = vec![0.0; 2];
        sddmm_reference(&a, &u, &v, &mut out);
        assert_eq!(out, vec![34.0, 159.0]);
    }

    #[test]
    fn sddmm_zero_width_dot_is_zero() {
        let mut coo = CooMatrix::new(2, 3);
        coo.push(0, 1, 4.0);
        let a = CsrMatrix::from_coo(&coo);
        let u = DenseMatrix::zeros(2, 0);
        let v = DenseMatrix::zeros(3, 0);
        let mut out = vec![9.0; 1];
        sddmm_reference(&a, &u, &v, &mut out);
        assert_eq!(out, vec![0.0]);
    }

    #[test]
    #[should_panic(expected = "inner dimension")]
    fn shape_check() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(2, 3));
        let x = DenseMatrix::zeros(2, 2);
        let mut y = DenseMatrix::zeros(2, 2);
        spmm_reference(&a, &x, &mut y);
    }
}
