//! Kernel **variant descriptors** — the widened, parameterized design
//! space behind the paper's 2×2 grid.
//!
//! The paper fixes four kernels (sequential/parallel reduction ×
//! row-split/workload-balanced). "Heuristic Adaptability to Input
//! Dynamics for SpMM on GPUs" (Dai et al.) and "Design Principles for
//! Sparse Matrix Multiplication on the GPU" (Yang et al.) both show the
//! remaining headroom lives in *secondary* axes — tile/unroll width and
//! segment granularity — searched per input and hardware. A
//! [`KernelVariant`] names one point of that widened space:
//!
//! - **family** ([`KernelKind`]) — the paper's 2×2 cell. Survives as the
//!   tag the Fig.-4 rule selector and every family-level metric keep
//!   using; variants refine a family, they never cross one.
//! - **lane tile** ∈ {1, 4, 8} — dense-width tile of the inner loop for
//!   the row-split SpMM designs (8 = the `vec8` microkernel path), and
//!   row-chunk granularity for the row-split SDDMM designs.
//! - **segment length** ∈ {`WARP`/2, `WARP`, 2·`WARP`} — the fixed-nnz
//!   segment size of the workload-balanced designs (`WARP` is the
//!   canonical layout every backend already prepares).
//! - **traversal** ([`Traversal`]) — blocked rows or merge-path, for the
//!   sequential-reduction designs.
//!
//! Each variant has a **stable canonical label**: the family label alone
//! for the canonical point (`sr_rs`, `pr_wb`, ...), suffixed with
//! `.t<tile>`, `.s<seg>`, `.mp` — in that order — for every non-default
//! axis (`sr_rs.t4`, `sr_wb.s64`, `sr_rs.mp`). Labels are what persists:
//! hardware profiles, audit entries, perfgate baselines and the stats
//! surface all refer to variants by label, so the scheme must never
//! change for an existing point.
//!
//! The executable registry over these descriptors lives in
//! [`crate::kernels::generator`].

use super::{KernelKind, SparseOp, Traversal, WARP};

/// Lane-tile axis values (dense-width tile for SpMM row-split, row-chunk
/// scale for SDDMM row-split). 8 is canonical — the `vec8` path.
pub const LANE_TILES: [usize; 3] = [1, 4, 8];

/// Segment-length axis values for the workload-balanced designs.
/// `WARP` (32) is canonical.
pub const SEG_LENS: [usize; 3] = [WARP / 2, WARP, 2 * WARP];

/// The canonical lane tile (the hand-written kernels' inner loop).
pub const CANONICAL_LANE_TILE: usize = 8;

/// The canonical segment length (the layout every backend prepares).
pub const CANONICAL_SEG_LEN: usize = WARP;

/// One point of the widened kernel design space. See the module docs for
/// the axes; construct via [`KernelVariant::canonical`] plus the `with_*`
/// builders so unconstrained fields keep their canonical values.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelVariant {
    /// Which sparse op the variant computes.
    pub op: SparseOp,
    /// The paper-family tag (selection rules operate on this).
    pub family: KernelKind,
    /// Dense-width tile (SpMM RS) / row-chunk scale (SDDMM RS).
    pub lane_tile: usize,
    /// Fixed-nnz segment length (workload-balanced families).
    pub seg_len: usize,
    /// Row traversal (sequential-reduction families).
    pub traversal: Traversal,
}

impl KernelVariant {
    /// The canonical point of a family: the hand-written kernel the
    /// registry keeps byte-compatible labels for.
    pub fn canonical(op: SparseOp, family: KernelKind) -> Self {
        Self {
            op,
            family,
            lane_tile: CANONICAL_LANE_TILE,
            seg_len: CANONICAL_SEG_LEN,
            traversal: Traversal::Blocked,
        }
    }

    /// Same variant with another lane tile.
    pub fn with_lane_tile(mut self, lane_tile: usize) -> Self {
        self.lane_tile = lane_tile;
        self
    }

    /// Same variant with another segment length.
    pub fn with_seg_len(mut self, seg_len: usize) -> Self {
        self.seg_len = seg_len;
        self
    }

    /// Same variant with another traversal.
    pub fn with_traversal(mut self, traversal: Traversal) -> Self {
        self.traversal = traversal;
        self
    }

    /// Whether this is the family's canonical point (label == family
    /// label; behavior == the pre-registry hand-written kernel).
    pub fn is_canonical(&self) -> bool {
        self.lane_tile == CANONICAL_LANE_TILE
            && self.seg_len == CANONICAL_SEG_LEN
            && self.traversal == Traversal::Blocked
    }

    /// The stable canonical label (module docs). Suffix order is fixed:
    /// tile, segment, traversal.
    pub fn label(&self) -> String {
        let mut out = String::from(self.family.label());
        if self.lane_tile != CANONICAL_LANE_TILE {
            out.push_str(&format!(".t{}", self.lane_tile));
        }
        if self.seg_len != CANONICAL_SEG_LEN {
            out.push_str(&format!(".s{}", self.seg_len));
        }
        if self.traversal == Traversal::MergePath {
            out.push_str(".mp");
        }
        out
    }

    /// Parse a label back into a variant of the given op. Inverse of
    /// [`KernelVariant::label`]; returns `None` for malformed labels or
    /// axis values outside the declared grids (profile loads use this, so
    /// unknown labels must degrade gracefully, never panic).
    pub fn from_label(op: SparseOp, label: &str) -> Option<Self> {
        let mut parts = label.split('.');
        let family = KernelKind::from_label(parts.next()?)?;
        let mut v = Self::canonical(op, family);
        for part in parts {
            if let Some(t) = part.strip_prefix('t') {
                let t: usize = t.parse().ok()?;
                if !LANE_TILES.contains(&t) {
                    return None;
                }
                v.lane_tile = t;
            } else if let Some(s) = part.strip_prefix('s') {
                let s: usize = s.parse().ok()?;
                if !SEG_LENS.contains(&s) {
                    return None;
                }
                v.seg_len = s;
            } else if part == "mp" {
                v.traversal = Traversal::MergePath;
            } else {
                return None;
            }
        }
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_labels_are_the_family_labels() {
        for op in [SparseOp::Spmm, SparseOp::Sddmm] {
            for family in KernelKind::ALL {
                let v = KernelVariant::canonical(op, family);
                assert!(v.is_canonical());
                assert_eq!(v.label(), family.label());
            }
        }
    }

    #[test]
    fn labels_encode_every_non_default_axis_in_fixed_order() {
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs).with_lane_tile(4);
        assert_eq!(v.label(), "sr_rs.t4");
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrWb).with_seg_len(64);
        assert_eq!(v.label(), "sr_wb.s64");
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs)
            .with_traversal(Traversal::MergePath);
        assert_eq!(v.label(), "sr_rs.mp");
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrWb)
            .with_lane_tile(1)
            .with_seg_len(16)
            .with_traversal(Traversal::MergePath);
        assert_eq!(v.label(), "sr_wb.t1.s16.mp");
    }

    #[test]
    fn labels_roundtrip_through_from_label() {
        let cases = [
            KernelVariant::canonical(SparseOp::Spmm, KernelKind::PrWb),
            KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs).with_lane_tile(1),
            KernelVariant::canonical(SparseOp::Sddmm, KernelKind::SrWb).with_seg_len(16),
            KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs)
                .with_traversal(Traversal::MergePath),
        ];
        for v in cases {
            assert_eq!(KernelVariant::from_label(v.op, &v.label()), Some(v));
        }
        assert_eq!(KernelVariant::from_label(SparseOp::Spmm, "nope"), None);
        assert_eq!(KernelVariant::from_label(SparseOp::Spmm, "sr_rs.t3"), None);
        assert_eq!(KernelVariant::from_label(SparseOp::Spmm, "sr_rs.s48"), None);
        assert_eq!(KernelVariant::from_label(SparseOp::Spmm, "sr_rs.x"), None);
    }
}
