//! Block-diagonal and bipartite-block generators — clustered sparsity.
//!
//! These model matrices with locally dense structure (circuit, FEM and
//! community-graph matrices in SuiteSparse): non-zeros cluster in blocks,
//! giving good dense-row locality — the regime where ASpT-style tiling
//! shines and where the paper's parallel-reduction keeps dense-matrix
//! loads local.

use crate::sparse::CooMatrix;
use crate::util::prng::Xoshiro256;

/// Block-diagonal matrix: `nblocks` square blocks of size `block`, each
/// filled with density `block_density`.
pub fn block_diagonal(
    nblocks: usize,
    block: usize,
    block_density: f64,
    rng: &mut Xoshiro256,
) -> CooMatrix {
    let n = nblocks * block;
    let mut coo = CooMatrix::new(n, n);
    for b in 0..nblocks {
        let base = b * block;
        for r in 0..block {
            for c in 0..block {
                if rng.chance(block_density) {
                    coo.push(base + r, base + c, rng.next_f32() * 2.0 - 1.0);
                }
            }
        }
    }
    coo.canonicalize();
    coo
}

/// Random block matrix: a `grid × grid` tiling where each tile is dense
/// with probability `tile_prob` (then filled at `tile_density`), else
/// empty. Produces the mixed dense/sparse tiles ASpT exploits.
pub fn block_random(
    grid: usize,
    tile: usize,
    tile_prob: f64,
    tile_density: f64,
    rng: &mut Xoshiro256,
) -> CooMatrix {
    let n = grid * tile;
    let mut coo = CooMatrix::new(n, n);
    for br in 0..grid {
        for bc in 0..grid {
            if rng.chance(tile_prob) {
                for r in 0..tile {
                    for c in 0..tile {
                        if rng.chance(tile_density) {
                            coo.push(br * tile + r, bc * tile + c, rng.next_f32() * 2.0 - 1.0);
                        }
                    }
                }
            }
        }
    }
    coo.canonicalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_diagonal_stays_in_blocks() {
        let mut rng = Xoshiro256::seeded(61);
        let m = block_diagonal(4, 8, 0.5, &mut rng);
        assert_eq!(m.rows, 32);
        for i in 0..m.nnz() {
            let r = m.row_idx[i] as usize;
            let c = m.col_idx[i] as usize;
            assert_eq!(r / 8, c / 8, "entry ({r},{c}) escapes its block");
        }
    }

    #[test]
    fn block_random_density_within_active_tiles() {
        let mut rng = Xoshiro256::seeded(62);
        let m = block_random(8, 16, 0.25, 0.5, &mut rng);
        let expected = (8.0 * 8.0 * 0.25) * (16.0 * 16.0 * 0.5);
        let got = m.nnz() as f64;
        assert!(
            (got - expected).abs() < expected * 0.5,
            "nnz {got} vs expected {expected}"
        );
    }
}
