//! Power-law row-length generator — the *skewed* end of the feature space.
//!
//! Directly parameterizes the row-length distribution: row lengths are
//! drawn from a discrete Pareto with exponent `alpha`, producing the
//! heavy-tailed degree profiles where the paper's workload-balancing is
//! essential (Insight 2). Unlike R-MAT, the skew is controlled exactly.

use crate::sparse::CooMatrix;
use crate::util::prng::Xoshiro256;

/// Parameters for the power-law generator.
#[derive(Clone, Copy, Debug)]
pub struct PowerLawConfig {
    pub rows: usize,
    pub cols: usize,
    /// Pareto exponent; smaller = heavier tail (1.5–3.5 realistic).
    pub alpha: f64,
    /// minimum row length.
    pub min_row: usize,
    /// cap on row length (also bounded by `cols`).
    pub max_row: usize,
}

impl PowerLawConfig {
    /// Generate: each row gets `len ~ Pareto(alpha)` distinct columns.
    pub fn generate(&self, rng: &mut Xoshiro256) -> CooMatrix {
        assert!(self.alpha > 1.0, "alpha must exceed 1 for a finite mean");
        assert!(self.min_row >= 1);
        let max_row = self.max_row.min(self.cols);
        let mut coo = CooMatrix::new(self.rows, self.cols);
        for r in 0..self.rows {
            // inverse-CDF sample of a bounded Pareto
            let u = rng.next_f64();
            let lo = self.min_row as f64;
            let hi = max_row as f64;
            let a = self.alpha - 1.0; // tail exponent of the CCDF
            let len = (lo.powf(-a) - u * (lo.powf(-a) - hi.powf(-a))).powf(-1.0 / a);
            let len = (len.round() as usize).clamp(self.min_row, max_row);
            for c in rng.sample_distinct(self.cols, len) {
                coo.push(r, c, rng.next_f32() * 2.0 - 1.0);
            }
        }
        coo.canonicalize();
        coo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMatrix;
    use crate::util::stats;

    #[test]
    fn row_lengths_within_bounds() {
        let mut rng = Xoshiro256::seeded(51);
        let cfg = PowerLawConfig {
            rows: 300,
            cols: 400,
            alpha: 2.0,
            min_row: 2,
            max_row: 64,
        };
        let csr = CsrMatrix::from_coo(&cfg.generate(&mut rng));
        for r in 0..csr.rows {
            let n = csr.row_nnz(r);
            assert!((2..=64).contains(&n), "row {r} has {n} nnz");
        }
    }

    #[test]
    fn smaller_alpha_is_more_skewed() {
        let mut rng = Xoshiro256::seeded(52);
        let make = |alpha, rng: &mut Xoshiro256| {
            let cfg = PowerLawConfig {
                rows: 2000,
                cols: 4000,
                alpha,
                min_row: 1,
                max_row: 1000,
            };
            stats::cv(&CsrMatrix::from_coo(&cfg.generate(rng)).row_lengths())
        };
        let heavy = make(1.6, &mut rng);
        let light = make(3.5, &mut rng);
        assert!(heavy > 2.0 * light, "cv heavy {heavy} vs light {light}");
    }

    #[test]
    fn no_duplicate_columns_within_row() {
        let mut rng = Xoshiro256::seeded(53);
        let cfg = PowerLawConfig {
            rows: 100,
            cols: 50,
            alpha: 2.5,
            min_row: 1,
            max_row: 50,
        };
        let csr = CsrMatrix::from_coo(&cfg.generate(&mut rng));
        for r in 0..csr.rows {
            let (cols, _) = csr.row(r);
            for k in 1..cols.len() {
                assert!(cols[k] > cols[k - 1], "row {r} has dup/unsorted cols");
            }
        }
    }
}
