//! R-MAT recursive matrix generator (Chakrabarti, Zhan & Faloutsos, 2004).
//!
//! The paper uses R-MAT for its VDL/CSC micro benchmarks ("27 matrices with
//! the R-MAT generator using various size, sparsity and distribution
//! parameters", §2.1.2). The generator drops each edge into one of four
//! quadrants with probabilities (a, b, c, d) recursively; skewed
//! probabilities yield power-law row lengths.

use crate::sparse::CooMatrix;
use crate::util::prng::Xoshiro256;

/// R-MAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatConfig {
    /// log2 of the (square) dimension.
    pub scale: u32,
    /// average non-zeros per row.
    pub edge_factor: f64,
    /// quadrant probabilities; must sum to 1.
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// perturbation of quadrant probabilities per level (standard R-MAT
    /// noise to avoid exact self-similarity).
    pub noise: f64,
}

impl RmatConfig {
    /// Default Graph500-style skew (a=0.57, b=0.19, c=0.19, d=0.05).
    pub fn new(scale: u32, edge_factor: f64) -> Self {
        Self {
            scale,
            edge_factor,
            a: 0.57,
            b: 0.19,
            c: 0.19,
            noise: 0.05,
        }
    }

    /// Uniform variant (a=b=c=d): Erdős–Rényi-like, balanced rows.
    pub fn uniform(scale: u32, edge_factor: f64) -> Self {
        Self {
            scale,
            edge_factor,
            a: 0.25,
            b: 0.25,
            c: 0.25,
            noise: 0.0,
        }
    }

    /// With explicit quadrant probabilities.
    pub fn with_probs(mut self, a: f64, b: f64, c: f64) -> Self {
        assert!(a + b + c < 1.0 + 1e-9, "quadrant probs exceed 1");
        self.a = a;
        self.b = b;
        self.c = c;
        self
    }

    /// Dimension `2^scale`.
    pub fn dim(&self) -> usize {
        1usize << self.scale
    }

    /// Generate a COO matrix (duplicates merged via canonicalize; values
    /// uniform in [-1, 1)).
    pub fn generate(&self, rng: &mut Xoshiro256) -> CooMatrix {
        let n = self.dim();
        let edges = (n as f64 * self.edge_factor) as usize;
        let mut coo = CooMatrix::new(n, n);
        for _ in 0..edges {
            let (r, c) = self.one_edge(rng);
            coo.push(r, c, rng.next_f32() * 2.0 - 1.0);
        }
        coo.canonicalize();
        coo
    }

    /// Sample one edge coordinate by the quadrant descent — the same
    /// distribution [`generate`](RmatConfig::generate) draws from, exposed
    /// so an edge-churn stream ([`super::churn`]) can insert new edges
    /// that preserve the base matrix's degree skew.
    pub fn sample_edge(&self, rng: &mut Xoshiro256) -> (usize, usize) {
        self.one_edge(rng)
    }

    fn one_edge(&self, rng: &mut Xoshiro256) -> (usize, usize) {
        let (mut a, mut b, mut c) = (self.a, self.b, self.c);
        let mut r = 0usize;
        let mut col = 0usize;
        for level in (0..self.scale).rev() {
            let d = 1.0 - a - b - c;
            let x = rng.next_f64();
            let (dr, dc) = if x < a {
                (0, 0)
            } else if x < a + b {
                (0, 1)
            } else if x < a + b + c {
                (1, 0)
            } else {
                let _ = d;
                (1, 1)
            };
            r |= dr << level;
            col |= dc << level;
            if self.noise > 0.0 {
                // multiplicative noise, renormalized
                let na = a * (1.0 - self.noise + 2.0 * self.noise * rng.next_f64());
                let nb = b * (1.0 - self.noise + 2.0 * self.noise * rng.next_f64());
                let nc = c * (1.0 - self.noise + 2.0 * self.noise * rng.next_f64());
                let nd = (1.0 - a - b - c) * (1.0 - self.noise + 2.0 * self.noise * rng.next_f64());
                let s = na + nb + nc + nd;
                a = na / s;
                b = nb / s;
                c = nc / s;
            }
        }
        (r, col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMatrix;
    use crate::util::stats;

    #[test]
    fn shape_and_rough_nnz() {
        let mut rng = Xoshiro256::seeded(31);
        let coo = RmatConfig::new(10, 8.0).generate(&mut rng);
        assert_eq!(coo.rows, 1024);
        assert_eq!(coo.cols, 1024);
        // duplicates merge, so nnz <= edges but should stay in the ballpark
        let nnz = coo.nnz() as f64;
        assert!(nnz > 0.7 * 8192.0 && nnz <= 8192.0, "nnz {nnz}");
    }

    #[test]
    fn skewed_probs_yield_higher_row_cv_than_uniform() {
        let mut rng = Xoshiro256::seeded(32);
        let skewed = RmatConfig::new(11, 8.0).generate(&mut rng);
        let uniform = RmatConfig::uniform(11, 8.0).generate(&mut rng);
        let cv_skew = stats::cv(&CsrMatrix::from_coo(&skewed).row_lengths());
        let cv_unif = stats::cv(&CsrMatrix::from_coo(&uniform).row_lengths());
        assert!(
            cv_skew > 1.5 * cv_unif,
            "skewed cv {cv_skew} vs uniform cv {cv_unif}"
        );
    }

    #[test]
    fn determinism() {
        let a = RmatConfig::new(8, 4.0).generate(&mut Xoshiro256::seeded(7));
        let b = RmatConfig::new(8, 4.0).generate(&mut Xoshiro256::seeded(7));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "exceed 1")]
    fn with_probs_validates() {
        RmatConfig::new(4, 2.0).with_probs(0.6, 0.4, 0.2);
    }
}
