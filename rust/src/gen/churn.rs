//! R-MAT edge-churn stream: the dynamic-graph workload generator.
//!
//! Dynamic GNN serving mutates its graphs between requests — edges
//! appear, expire, and re-weight while inference traffic keeps flowing.
//! [`ChurnStream`] models that: it seeds a base matrix from an
//! [`RmatConfig`] and then yields an endless sequence of [`EdgeDelta`]
//! batches. Inserts are drawn from the *same* R-MAT quadrant descent as
//! the base (churn preserves the degree skew instead of flattening it);
//! deletes and value updates are sampled uniformly from the edges
//! currently present. The stream applies every batch to its own copy of
//! the matrix, so [`ChurnStream::current`] is always the post-batch
//! ground truth a differential harness (`tests/delta_agreement.rs`) can
//! re-register from scratch and compare against a patched engine.

use super::rmat::RmatConfig;
use crate::sparse::{CsrMatrix, EdgeDelta};
use crate::util::prng::Xoshiro256;

/// Shape of one churn batch.
#[derive(Clone, Copy, Debug)]
pub struct ChurnConfig {
    /// Base-matrix generator; inserts reuse its quadrant descent.
    pub base: RmatConfig,
    /// New edges sampled per batch. Sampling a coordinate that already
    /// exists turns that insert into a value update — under heavy skew a
    /// hub edge is re-sampled often, exactly like repeated interactions
    /// on a social graph.
    pub inserts: usize,
    /// Existing edges deleted per batch (uniform over present edges).
    pub deletes: usize,
    /// Existing edges re-valued per batch (uniform over present edges).
    pub updates: usize,
}

impl ChurnConfig {
    /// Mixed-churn default: a few structural edges in and out plus twice
    /// as many weight updates per batch.
    pub fn new(base: RmatConfig) -> Self {
        Self {
            base,
            inserts: 8,
            deletes: 8,
            updates: 16,
        }
    }

    /// Value-only variant: weight updates without structural churn, the
    /// regime `SpmmBackend::prepare_delta` patches in place.
    pub fn value_only(mut self) -> Self {
        self.inserts = 0;
        self.deletes = 0;
        self
    }
}

/// Deterministic stream of churn batches over one evolving matrix.
pub struct ChurnStream {
    config: ChurnConfig,
    rng: Xoshiro256,
    current: CsrMatrix,
    batches: u64,
}

impl ChurnStream {
    /// Generate the base matrix and start the stream. Everything after
    /// is a pure function of `(config, seed)`.
    pub fn new(config: ChurnConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::seeded(seed);
        let current = CsrMatrix::from_coo(&config.base.generate(&mut rng));
        Self {
            config,
            rng,
            current,
            batches: 0,
        }
    }

    /// Ground truth after every batch produced so far. Its `epoch`
    /// counts the effective (touching) batches, so an engine that
    /// registered a pre-stream clone and replayed every batch holds a
    /// fingerprint-identical matrix.
    pub fn current(&self) -> &CsrMatrix {
        &self.current
    }

    /// Batches produced so far (effective or not).
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// One existing edge, uniform over the present non-zeros: a stream
    /// position in `[0, nnz)`, its row recovered from `indptr`.
    fn existing_edge(&mut self) -> (usize, usize) {
        let nnz = self.current.nnz();
        debug_assert!(nnz > 0);
        let p = (self.rng.next_u64() % nnz as u64) as usize;
        let r = self.current.indptr.partition_point(|&e| e as usize <= p) - 1;
        (r, self.current.indices[p] as usize)
    }

    /// Produce the next batch and fold it into the stream's own matrix.
    /// Samples refer to the *pre-batch* state; [`EdgeDelta::apply`]'s
    /// delete-before-insert composition resolves collisions (a deleted
    /// edge re-sampled by an update comes back with the new weight).
    pub fn next_batch(&mut self) -> EdgeDelta {
        let mut delta = EdgeDelta::new();
        let present = self.current.nnz();
        for _ in 0..self.config.deletes.min(present) {
            let (r, c) = self.existing_edge();
            delta.delete(r, c);
        }
        for _ in 0..self.config.updates.min(present) {
            let (r, c) = self.existing_edge();
            let v = self.rng.next_f32() * 2.0 - 1.0;
            delta.insert(r, c, v);
        }
        for _ in 0..self.config.inserts {
            let (r, c) = self.config.base.sample_edge(&mut self.rng);
            let v = self.rng.next_f32() * 2.0 - 1.0;
            delta.insert(r, c, v);
        }
        delta.apply(&mut self.current);
        self.batches += 1;
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(seed: u64) -> ChurnStream {
        ChurnStream::new(ChurnConfig::new(RmatConfig::new(6, 4.0)), seed)
    }

    #[test]
    fn stream_is_deterministic() {
        let mut a = stream(9);
        let mut b = stream(9);
        assert_eq!(a.current(), b.current());
        for _ in 0..5 {
            a.next_batch();
            b.next_batch();
            assert_eq!(a.current(), b.current());
        }
        assert_eq!(a.batches(), 5);
        assert_ne!(a.current(), stream(9).current(), "batches moved the matrix");
    }

    #[test]
    fn current_tracks_the_replayed_batches() {
        let mut s = stream(10);
        let mut replay = s.current().clone();
        for _ in 0..8 {
            let delta = s.next_batch();
            delta.apply(&mut replay);
            assert_eq!(&replay, s.current(), "stream state == replayed state");
        }
        assert_eq!(replay.epoch, s.current().epoch);
        assert!(replay.epoch > 0, "churn batches touch the matrix");
    }

    #[test]
    fn batches_stay_inside_the_base_dimensions() {
        let mut s = stream(11);
        let dim = s.current().rows;
        for _ in 0..10 {
            s.next_batch();
            let m = s.current();
            assert_eq!(m.rows, dim);
            assert_eq!(m.cols, dim);
            assert!(m.indices.iter().all(|&c| (c as usize) < dim));
        }
    }

    #[test]
    fn value_only_streams_never_churn_structure() {
        let config = ChurnConfig::new(RmatConfig::uniform(6, 4.0)).value_only();
        let mut s = ChurnStream::new(config, 12);
        let indptr = s.current().indptr.clone();
        let indices = s.current().indices.clone();
        for _ in 0..6 {
            let delta = s.next_batch();
            let mut probe = s.current().clone();
            let report = delta.apply(&mut probe);
            assert!(!report.structural, "updates only");
        }
        assert_eq!(s.current().indptr, indptr, "structure untouched");
        assert_eq!(s.current().indices, indices);
    }
}
