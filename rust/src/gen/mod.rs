//! Synthetic sparse-matrix generation.
//!
//! The paper evaluates on the SuiteSparse collection and synthesizes micro
//! benchmarks with R-MAT. SuiteSparse cannot be downloaded in this offline
//! environment, so [`collection`] builds a deterministic 180-matrix suite
//! that spans the same feature space (row-length mean 2–512, coefficient of
//! variation 0–30, dimension 1e3–2e5) using the generator families below;
//! see `DESIGN.md` §Substitutions.

pub mod banded;
pub mod blockdiag;
pub mod churn;
pub mod collection;
pub mod powerlaw;
pub mod rmat;

pub use churn::{ChurnConfig, ChurnStream};
pub use collection::{Collection, MatrixSpec};
