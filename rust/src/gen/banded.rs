//! Banded / stencil matrix generators — scientific-computing sparsity.
//!
//! SuiteSparse's scientific matrices (PDE discretizations) have narrow,
//! uniform rows — the *well-balanced* end of the paper's feature space,
//! where workload-balancing is pure overhead (Insight 2).

use crate::sparse::CooMatrix;
use crate::util::prng::Xoshiro256;

/// Square banded matrix: diagonals at the given `offsets` (e.g. `[-1,0,1]`
/// for tridiagonal). Values uniform in [-1, 1).
pub fn banded(n: usize, offsets: &[i64], rng: &mut Xoshiro256) -> CooMatrix {
    let mut coo = CooMatrix::new(n, n);
    for r in 0..n as i64 {
        for &off in offsets {
            let c = r + off;
            if c >= 0 && c < n as i64 {
                coo.push(r as usize, c as usize, rng.next_f32() * 2.0 - 1.0);
            }
        }
    }
    coo.canonicalize();
    coo
}

/// 5-point 2D Laplacian stencil on a `side × side` grid (classic SpMV
/// benchmark; n = side²).
pub fn laplacian_2d(side: usize) -> CooMatrix {
    let n = side * side;
    let mut coo = CooMatrix::new(n, n);
    for y in 0..side {
        for x in 0..side {
            let i = y * side + x;
            coo.push(i, i, 4.0);
            if x > 0 {
                coo.push(i, i - 1, -1.0);
            }
            if x + 1 < side {
                coo.push(i, i + 1, -1.0);
            }
            if y > 0 {
                coo.push(i, i - side, -1.0);
            }
            if y + 1 < side {
                coo.push(i, i + side, -1.0);
            }
        }
    }
    coo.canonicalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMatrix;
    use crate::util::stats;

    #[test]
    fn tridiagonal_counts() {
        let mut rng = Xoshiro256::seeded(41);
        let m = banded(10, &[-1, 0, 1], &mut rng);
        // 10 diag + 9 sub + 9 super
        assert_eq!(m.nnz(), 28);
    }

    #[test]
    fn banded_rows_are_balanced() {
        let mut rng = Xoshiro256::seeded(42);
        let m = banded(500, &[-2, -1, 0, 1, 2], &mut rng);
        let cv = stats::cv(&CsrMatrix::from_coo(&m).row_lengths());
        assert!(cv < 0.1, "banded cv should be tiny: {cv}");
    }

    #[test]
    fn laplacian_row_sums_are_nonnegative_and_interior_zero() {
        let m = laplacian_2d(8);
        let csr = CsrMatrix::from_coo(&m);
        assert_eq!(csr.rows, 64);
        // interior point row: 4 - 1*4 = 0
        let interior = 3 * 8 + 3;
        let (_, vals) = csr.row(interior);
        let s: f32 = vals.iter().sum();
        assert_eq!(s, 0.0);
        assert_eq!(csr.row_nnz(interior), 5);
        // corner: 4 - 1*2 = 2
        let (_, vals) = csr.row(0);
        assert_eq!(vals.iter().sum::<f32>(), 2.0);
        assert_eq!(csr.row_nnz(0), 3);
    }
}
