//! The synthetic benchmark collection — stand-in for SuiteSparse.
//!
//! Builds a deterministic suite of matrices spanning the feature axes the
//! paper's selection heuristics depend on:
//!
//! - `avg_row` (mean row length): 2 … 512
//! - `stdv_row/avg_row` (cv): ≈0 (banded) … >10 (heavy power-law)
//! - dimension: 1k … 131k rows
//!
//! Seven families × parameter grids ≈ 130 matrices. Each entry carries a
//! [`MatrixSpec`] so benches can report per-family breakdowns. Everything
//! is seeded from the matrix name, so any single matrix can be regenerated
//! in isolation.

use super::banded::{banded, laplacian_2d};
use super::blockdiag::{block_diagonal, block_random};
use super::powerlaw::PowerLawConfig;
use super::rmat::RmatConfig;
use crate::sparse::{CooMatrix, CsrMatrix};
use crate::util::prng::Xoshiro256;

/// Generator family of a collection entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    Rmat,
    Uniform,
    PowerLaw,
    Banded,
    Stencil,
    BlockDiag,
    BlockRandom,
    Spike,
}

impl Family {
    /// Short label used in bench output.
    pub fn label(&self) -> &'static str {
        match self {
            Family::Rmat => "rmat",
            Family::Uniform => "uniform",
            Family::PowerLaw => "powerlaw",
            Family::Banded => "banded",
            Family::Stencil => "stencil",
            Family::BlockDiag => "blockdiag",
            Family::BlockRandom => "blockrand",
            Family::Spike => "spike",
        }
    }
}

/// Description of one matrix in the collection: how to build it.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub name: String,
    pub family: Family,
    params: Params,
}

#[derive(Clone, Debug)]
enum Params {
    Rmat { scale: u32, ef: f64, a: f64, b: f64, c: f64 },
    Uniform { scale: u32, ef: f64 },
    PowerLaw { rows: usize, alpha: f64, avg: usize },
    Banded { n: usize, half_band: usize },
    Stencil { side: usize },
    BlockDiag { nblocks: usize, block: usize, density: f64 },
    /// short uniform rows plus a few fixed-length mega rows (circuit /
    /// power-grid style dense rows — the extreme-skew regime)
    Spike { rows: usize, avg: f64, spikes: usize, spike_len: usize },
    BlockRandom { grid: usize, tile: usize, tile_prob: f64 },
}

impl MatrixSpec {
    /// Deterministic per-matrix seed derived from the name.
    fn seed(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Materialize as COO.
    pub fn build_coo(&self) -> CooMatrix {
        let mut rng = Xoshiro256::seeded(self.seed());
        match &self.params {
            Params::Rmat { scale, ef, a, b, c } => RmatConfig::new(*scale, *ef)
                .with_probs(*a, *b, *c)
                .generate(&mut rng),
            Params::Uniform { scale, ef } => {
                RmatConfig::uniform(*scale, *ef).generate(&mut rng)
            }
            Params::PowerLaw { rows, alpha, avg } => {
                // choose max_row so the bounded-Pareto mean lands near avg
                let cfg = PowerLawConfig {
                    rows: *rows,
                    cols: *rows,
                    alpha: *alpha,
                    min_row: 1.max(avg / 4),
                    max_row: (avg * 40).min(*rows),
                };
                cfg.generate(&mut rng)
            }
            Params::Banded { n, half_band } => {
                let offsets: Vec<i64> =
                    (-(*half_band as i64)..=(*half_band as i64)).collect();
                banded(*n, &offsets, &mut rng)
            }
            Params::Stencil { side } => laplacian_2d(*side),
            Params::BlockDiag {
                nblocks,
                block,
                density,
            } => block_diagonal(*nblocks, *block, *density, &mut rng),
            Params::Spike {
                rows,
                avg,
                spikes,
                spike_len,
            } => {
                let mut coo =
                    CooMatrix::random_uniform(*rows, *rows, *avg / *rows as f64, &mut rng);
                let len = (*spike_len).min(*rows);
                for sp in 0..*spikes {
                    let r = sp * (*rows / (*spikes + 1));
                    for c in rng.sample_distinct(*rows, len) {
                        coo.push(r, c, rng.next_f32() * 2.0 - 1.0);
                    }
                }
                coo.canonicalize();
                coo
            }
            Params::BlockRandom {
                grid,
                tile,
                tile_prob,
            } => block_random(*grid, *tile, *tile_prob, 0.5, &mut rng),
        }
    }

    /// Materialize as CSR.
    pub fn build(&self) -> CsrMatrix {
        CsrMatrix::from_coo(&self.build_coo())
    }
}

/// The full synthetic collection.
pub struct Collection;

impl Collection {
    /// The standard suite (~130 matrices). Deterministic order and content.
    pub fn suite() -> Vec<MatrixSpec> {
        let mut out = Vec::new();
        // R-MAT skewed: scales 10..=14, edge factors {4, 8, 16, 32}, two skews
        for scale in [10u32, 11, 12, 13, 14] {
            for ef in [4.0, 8.0, 16.0, 32.0] {
                for (tag, a, b, c) in [("g500", 0.57, 0.19, 0.19), ("mild", 0.45, 0.22, 0.22)] {
                    out.push(MatrixSpec {
                        name: format!("rmat_s{scale}_e{ef:.0}_{tag}"),
                        family: Family::Rmat,
                        params: Params::Rmat {
                            scale,
                            ef,
                            a,
                            b,
                            c,
                        },
                    });
                }
            }
        }
        // Uniform: scales 10..=14 × edge factors {2, 8, 32, 128}
        for scale in [10u32, 11, 12, 13, 14] {
            for ef in [2.0, 8.0, 32.0, 128.0] {
                out.push(MatrixSpec {
                    name: format!("uniform_s{scale}_e{ef:.0}"),
                    family: Family::Uniform,
                    params: Params::Uniform { scale, ef },
                });
            }
        }
        // Power-law: rows {4k, 16k, 65k} × alpha {1.6, 2.0, 2.8} × avg {4, 16, 64}
        for rows in [4096usize, 16384, 65536] {
            for alpha in [1.6f64, 2.0, 2.8] {
                for avg in [4usize, 16, 64] {
                    out.push(MatrixSpec {
                        name: format!("plaw_n{rows}_a{alpha:.1}_d{avg}"),
                        family: Family::PowerLaw,
                        params: Params::PowerLaw { rows, alpha, avg },
                    });
                }
            }
        }
        // Banded: n {4k, 16k, 65k, 131k} × half-band {1, 2, 8, 32, 256}
        for n in [4096usize, 16384, 65536, 131072] {
            for hb in [1usize, 2, 8, 32, 256] {
                out.push(MatrixSpec {
                    name: format!("band_n{n}_b{hb}"),
                    family: Family::Banded,
                    params: Params::Banded { n, half_band: hb },
                });
            }
        }
        // Stencils: sides 64, 128, 256, 362 (n up to ~131k)
        for side in [64usize, 128, 256, 362] {
            out.push(MatrixSpec {
                name: format!("lap2d_{side}"),
                family: Family::Stencil,
                params: Params::Stencil { side },
            });
        }
        // Block-diagonal: blocks {64×64, 256×32, 1024×16} × density {0.3, 0.7}
        for (nblocks, block) in [(64usize, 64usize), (256, 32), (1024, 16)] {
            for density in [0.3f64, 0.7] {
                out.push(MatrixSpec {
                    name: format!("bdiag_{nblocks}x{block}_d{density:.1}"),
                    family: Family::BlockDiag,
                    params: Params::BlockDiag {
                        nblocks,
                        block,
                        density,
                    },
                });
            }
        }
        // Spike: extreme skew — short rows + a few fixed mega rows
        for (rows, avg, spikes, spike_len) in [
            (4096usize, 4.0, 3usize, 2048usize),
            (8192, 4.0, 4, 4096),
            (16384, 8.0, 4, 8192),
            (8192, 2.0, 8, 2048),
        ] {
            out.push(MatrixSpec {
                name: format!("spike_n{rows}_s{spikes}_l{spike_len}"),
                family: Family::Spike,
                params: Params::Spike {
                    rows,
                    avg,
                    spikes,
                    spike_len,
                },
            });
        }
        // Block-random: grid {32, 64} × tile {16, 32} × tile_prob {0.05, 0.15}
        for grid in [32usize, 64] {
            for tile in [16usize, 32] {
                for tile_prob in [0.05f64, 0.15] {
                    out.push(MatrixSpec {
                        name: format!("brand_g{grid}_t{tile}_p{tile_prob:.2}"),
                        family: Family::BlockRandom,
                        params: Params::BlockRandom {
                            grid,
                            tile,
                            tile_prob,
                        },
                    });
                }
            }
        }
        out
    }

    /// The benchmark subset: representative coverage of every family and
    /// feature regime, sized so a full `cargo bench` pass (all figures ×
    /// kernels × GPUs) completes in minutes. Selection is by name, so the
    /// subset is stable under suite extensions.
    pub fn bench_suite() -> Vec<MatrixSpec> {
        const KEEP: &[&str] = &[
            // R-MAT skewed, three scales × two edge factors
            "rmat_s10_e8_g500",
            "rmat_s11_e16_g500",
            "rmat_s12_e8_g500",
            "rmat_s12_e32_g500",
            "rmat_s13_e8_g500",
            "rmat_s11_e8_mild",
            "rmat_s12_e16_mild",
            // uniform, short and long rows
            "uniform_s10_e2",
            "uniform_s11_e8",
            "uniform_s12_e2",
            "uniform_s12_e32",
            "uniform_s13_e8",
            "uniform_s12_e128",
            // power-law, three skews × sizes
            "plaw_n4096_a1.6_d4",
            "plaw_n4096_a2.0_d16",
            "plaw_n16384_a1.6_d16",
            "plaw_n16384_a2.0_d4",
            "plaw_n16384_a2.8_d64",
            "plaw_n65536_a2.0_d16",
            // banded / stencil (balanced)
            "band_n4096_b1",
            "band_n4096_b32",
            "band_n16384_b2",
            "band_n16384_b8",
            "band_n65536_b8",
            "lap2d_64",
            "lap2d_128",
            "lap2d_256",
            // clustered
            "bdiag_64x64_d0.3",
            "bdiag_256x32_d0.7",
            "bdiag_1024x16_d0.3",
            "brand_g32_t16_p0.15",
            "brand_g64_t32_p0.05",
            // extreme skew
            "spike_n4096_s3_l2048",
            "spike_n8192_s4_l4096",
            "spike_n8192_s8_l2048",
        ];
        Self::suite()
            .into_iter()
            .filter(|s| KEEP.contains(&s.name.as_str()))
            .collect()
    }

    /// A small deterministic subset (for fast tests / CI): every family,
    /// small sizes.
    pub fn mini_suite() -> Vec<MatrixSpec> {
        Self::suite()
            .into_iter()
            .filter(|s| {
                matches!(
                    s.name.as_str(),
                    "rmat_s10_e8_g500"
                        | "uniform_s10_e8"
                        | "plaw_n4096_a2.0_d16"
                        | "band_n4096_b2"
                        | "band_n4096_b32"
                        | "lap2d_64"
                        | "bdiag_64x64_d0.3"
                        | "brand_g32_t16_p0.05"
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::MatrixFeatures;

    #[test]
    fn suite_size_and_unique_names() {
        let suite = Collection::suite();
        assert!(suite.len() >= 120, "suite has {} entries", suite.len());
        let names: std::collections::HashSet<_> = suite.iter().map(|s| &s.name).collect();
        assert_eq!(names.len(), suite.len(), "duplicate names");
    }

    #[test]
    fn mini_suite_builds_and_is_deterministic() {
        for spec in Collection::mini_suite() {
            let a = spec.build();
            let b = spec.build();
            assert_eq!(a, b, "{} not deterministic", spec.name);
            assert!(a.nnz() > 0, "{} is empty", spec.name);
        }
    }

    #[test]
    fn feature_space_is_spanned() {
        // The suite must contain both very balanced and very skewed
        // matrices, and both short and long average rows — otherwise the
        // selector calibration has nothing to learn from.
        let mut max_cv: f64 = 0.0;
        let mut min_cv = f64::INFINITY;
        let mut max_avg: f64 = 0.0;
        let mut min_avg = f64::INFINITY;
        for spec in Collection::mini_suite() {
            let f = MatrixFeatures::of(&spec.build());
            max_cv = max_cv.max(f.cv_row);
            min_cv = min_cv.min(f.cv_row);
            max_avg = max_avg.max(f.avg_row);
            min_avg = min_avg.min(f.avg_row);
        }
        assert!(min_cv < 0.2, "no balanced matrix (min cv {min_cv})");
        assert!(max_cv > 1.0, "no skewed matrix (max cv {max_cv})");
        assert!(min_avg < 10.0 && max_avg > 30.0, "avg_row span [{min_avg},{max_avg}]");
    }
}
