//! Synthetic citation-style graph for the E2E GCN training example.
//!
//! Cora is not downloadable in this environment (see DESIGN.md
//! §Substitutions); this generator reproduces the properties the workload
//! needs: Cora-scale size, power-law-ish degrees capped to the artifact's
//! ELL width, homophilous community structure, and labels planted by a
//! random 2-layer GCN so that training has signal to find.

use crate::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use crate::util::prng::Xoshiro256;

/// Graph/model dimensions; defaults mirror the `gcn_step` artifact bucket
/// (`python/compile/aot.py::GCN`).
#[derive(Clone, Copy, Debug)]
pub struct GraphConfig {
    /// true nodes (padded up to `nodes_padded` for the artifact)
    pub nodes: usize,
    pub nodes_padded: usize,
    pub feats: usize,
    pub classes: usize,
    /// ELL width budget (max degree + self-loop must fit)
    pub width: usize,
    /// number of communities (label homophily driver)
    pub communities: usize,
    /// average degree target
    pub avg_degree: f64,
    /// fraction of nodes with a training label
    pub label_frac: f64,
}

impl Default for GraphConfig {
    fn default() -> Self {
        Self {
            nodes: 2708, // Cora size
            nodes_padded: 2816,
            feats: 64,
            classes: 7,
            width: 32,
            communities: 7,
            avg_degree: 4.0,
            label_frac: 0.3,
        }
    }
}

/// The generated graph: normalized adjacency in ELL planes + features,
/// one-hot labels and the train mask, all padded to `nodes_padded`.
pub struct SyntheticGraph {
    pub config: GraphConfig,
    pub csr: CsrMatrix,
    /// Â in ELL planes (nodes_padded × width)
    pub a_values: Vec<f32>,
    pub a_col_idx: Vec<i32>,
    /// node features (nodes_padded × feats)
    pub features: Vec<f32>,
    /// one-hot labels (nodes_padded × classes)
    pub labels_onehot: Vec<f32>,
    /// training mask (nodes_padded)
    pub mask: Vec<f32>,
    /// integer labels (for accuracy checks)
    pub labels: Vec<usize>,
}

impl SyntheticGraph {
    /// Generate deterministically from a seed.
    pub fn generate(config: GraphConfig, seed: u64) -> SyntheticGraph {
        let mut rng = Xoshiro256::seeded(seed);
        let n = config.nodes;
        let deg_budget = config.width - 1; // leave room for the self loop

        // --- community-structured edges, degree-capped ---
        let community: Vec<usize> = (0..n).map(|_| rng.range(0, config.communities)).collect();
        let mut degree = vec![0usize; n];
        let mut coo = CooMatrix::new(n, n);
        let edges_target = (n as f64 * config.avg_degree / 2.0) as usize;
        let mut added = 0usize;
        let mut attempts = 0usize;
        while added < edges_target && attempts < edges_target * 20 {
            attempts += 1;
            let u = rng.range(0, n);
            // 80% intra-community edges (homophily)
            let v = if rng.chance(0.8) {
                // rejection-sample a same-community partner
                let mut v = rng.range(0, n);
                let mut tries = 0;
                while community[v] != community[u] && tries < 16 {
                    v = rng.range(0, n);
                    tries += 1;
                }
                v
            } else {
                rng.range(0, n)
            };
            if u == v || degree[u] >= deg_budget || degree[v] >= deg_budget {
                continue;
            }
            coo.push(u, v, 1.0);
            coo.push(v, u, 1.0);
            degree[u] += 1;
            degree[v] += 1;
            added += 1;
        }
        let csr = CsrMatrix::from_coo(&coo).gcn_normalized();

        // --- ELL planes padded to (nodes_padded, width) ---
        let np = config.nodes_padded;
        let w = config.width;
        let mut a_values = vec![0f32; np * w];
        let mut a_col_idx = vec![0i32; np * w];
        for r in 0..csr.rows {
            let (cols, vals) = csr.row(r);
            assert!(cols.len() <= w, "row {r} degree {} exceeds width {w}", cols.len());
            for k in 0..cols.len() {
                a_values[r * w + k] = vals[k];
                a_col_idx[r * w + k] = cols[k] as i32;
            }
        }

        // --- features: community signal + noise ---
        let mut features = vec![0f32; np * config.feats];
        for v in 0..n {
            for f in 0..config.feats {
                let signal = if f % config.communities == community[v] {
                    1.0
                } else {
                    0.0
                };
                features[v * config.feats + f] =
                    signal + 0.3 * (rng.next_f32() * 2.0 - 1.0);
            }
        }

        // --- plant labels with a random 2-layer GCN over Â and features ---
        let labels = plant_labels(&csr, &features, np, config, &mut rng);
        let mut labels_onehot = vec![0f32; np * config.classes];
        for v in 0..n {
            labels_onehot[v * config.classes + labels[v]] = 1.0;
        }
        let mut mask = vec![0f32; np];
        for m in mask.iter_mut().take(n) {
            if rng.chance(config.label_frac) {
                *m = 1.0;
            }
        }

        SyntheticGraph {
            config,
            csr,
            a_values,
            a_col_idx,
            features,
            labels_onehot,
            mask,
            labels,
        }
    }
}

/// Run a small random GCN forward in Rust to derive labels.
fn plant_labels(
    csr: &CsrMatrix,
    features: &[f32],
    np: usize,
    config: GraphConfig,
    rng: &mut Xoshiro256,
) -> Vec<usize> {
    use crate::kernels::sr_rs;
    use crate::util::threadpool::ThreadPool;
    let n = config.nodes;
    let f = config.feats;
    let hidden = 16;
    let pool = ThreadPool::default_parallel();
    let x = DenseMatrix::from_vec(np, f, features.to_vec());
    // Â·X  (csr is n×n; take the first n rows of x)
    let xn = DenseMatrix::from_vec(n, f, features[..n * f].to_vec());
    let mut agg = DenseMatrix::zeros(n, f);
    sr_rs::spmm(csr, &xn, &mut agg, &pool);
    // random W1 (f×hidden), relu, Â·H, random W2 (hidden×classes)
    let mut w1 = vec![0f32; f * hidden];
    rng.fill_uniform_f32(&mut w1, 0.5);
    let mut h = DenseMatrix::zeros(n, hidden);
    for r in 0..n {
        for j in 0..hidden {
            let mut acc = 0.0;
            for k in 0..f {
                acc += agg.at(r, k) * w1[k * hidden + j];
            }
            *h.at_mut(r, j) = acc.max(0.0);
        }
    }
    let mut agg2 = DenseMatrix::zeros(n, hidden);
    sr_rs::spmm(csr, &h, &mut agg2, &pool);
    let mut w2 = vec![0f32; hidden * config.classes];
    rng.fill_uniform_f32(&mut w2, 0.5);
    let mut labels = vec![0usize; n];
    for r in 0..n {
        let mut best = (0usize, f32::NEG_INFINITY);
        for c in 0..config.classes {
            let mut acc = 0.0;
            for k in 0..hidden {
                acc += agg2.at(r, k) * w2[k * config.classes + c];
            }
            if acc > best.1 {
                best = (c, acc);
            }
        }
        labels[r] = best.0;
    }
    let _ = x;
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config() -> GraphConfig {
        GraphConfig {
            nodes: 300,
            nodes_padded: 320,
            feats: 16,
            classes: 4,
            width: 16,
            communities: 4,
            avg_degree: 3.0,
            label_frac: 0.4,
        }
    }

    #[test]
    fn generates_valid_padded_planes() {
        let g = SyntheticGraph::generate(small_config(), 7);
        let c = g.config;
        assert_eq!(g.a_values.len(), c.nodes_padded * c.width);
        assert_eq!(g.features.len(), c.nodes_padded * c.feats);
        assert_eq!(g.labels_onehot.len(), c.nodes_padded * c.classes);
        // padding rows are zero
        assert!(g.a_values[c.nodes * c.width..].iter().all(|&v| v == 0.0));
        assert!(g.mask[c.nodes..].iter().all(|&m| m == 0.0));
        // degrees respect the width budget (incl. self loop)
        for r in 0..c.nodes {
            assert!(g.csr.row_nnz(r) <= c.width);
            assert!(g.csr.row_nnz(r) >= 1, "row {r} lost its self loop");
        }
    }

    #[test]
    fn labels_cover_multiple_classes_and_mask_nonempty() {
        let g = SyntheticGraph::generate(small_config(), 8);
        let distinct: std::collections::HashSet<_> = g.labels.iter().collect();
        assert!(distinct.len() >= 2, "degenerate labels");
        let masked = g.mask.iter().filter(|&&m| m > 0.0).count();
        assert!(masked > 50, "mask too small: {masked}");
    }

    #[test]
    fn determinism() {
        let a = SyntheticGraph::generate(small_config(), 9);
        let b = SyntheticGraph::generate(small_config(), 9);
        assert_eq!(a.a_values, b.a_values);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn adjacency_is_symmetric_normalized() {
        let g = SyntheticGraph::generate(small_config(), 10);
        let d = g.csr.to_dense();
        let n = g.config.nodes;
        for r in 0..n {
            for c in 0..n {
                assert!((d[r * n + c] - d[c * n + r]).abs() < 1e-5);
            }
        }
    }
}
