//! Native GNN training — end-to-end GCN steps through the engine with no
//! `pjrt` feature, no artifacts, no libxla.
//!
//! [`super::trainer`] drives the AOT `gcn_step` artifact and is gated on
//! `pjrt`; this module is the always-available counterpart: a 2-layer
//! GCN with manual backprop whose **sparse aggregations — forward and
//! backward — run through a [`SpmmEngine`]**. The backward pass is where
//! [`CsrMatrix::transposed`](crate::sparse::CsrMatrix::transposed)
//! earns its keep: the gradient of `Â·H` with
//! respect to `H` is `Âᵀ·G`, so the trainer registers both `Â` and `Âᵀ`
//! and routes three engine SpMMs per step (two forward, one backward).
//! `cargo test -q` exercises a full training run by default.
//!
//! ```text
//! forward:   Z₁ = Â·X          (engine SpMM)
//!            H₁ = relu(Z₁·W₁)
//!            Z₂ = Â·H₁         (engine SpMM)
//!            logits = Z₂·W₂
//! loss:      masked mean cross-entropy
//! backward:  dW₂ = Z₂ᵀ·dlogits
//!            dH₁ = Âᵀ·(dlogits·W₂ᵀ)   (engine SpMM on the transpose)
//!            dW₁ = Z₁ᵀ·(dH₁ ⊙ relu′)
//! ```

use super::graph::SyntheticGraph;
use crate::coordinator::{MatrixHandle, SpmmEngine};
use crate::sparse::DenseMatrix;
use crate::util::prng::Xoshiro256;
use anyhow::Result;
use std::time::Instant;

/// Report of one native training run.
#[derive(Clone, Debug)]
pub struct NativeTrainReport {
    /// Per-step losses.
    pub losses: Vec<f32>,
    /// Steps taken.
    pub steps: usize,
    /// Wallclock seconds of the run.
    pub seconds: f64,
    /// Masked train accuracy at the final weights.
    pub train_accuracy: f64,
}

/// 2-layer GCN trainer over a [`SpmmEngine`] and a synthetic graph.
pub struct NativeGcnTrainer {
    engine: SpmmEngine,
    h_a: MatrixHandle,
    h_at: MatrixHandle,
    x: DenseMatrix,
    labels: Vec<usize>,
    labels_onehot: DenseMatrix,
    mask: Vec<f32>,
    w1: DenseMatrix,
    w2: DenseMatrix,
    lr: f32,
}

impl NativeGcnTrainer {
    /// Trainer over a 2-way sharded native engine (per-shard adaptive
    /// selection on every aggregation).
    pub fn new(graph: &SyntheticGraph, hidden: usize, lr: f32, seed: u64) -> Result<Self> {
        Self::with_engine(SpmmEngine::sharded(2), graph, hidden, lr, seed)
    }

    /// Trainer over an explicit engine (e.g. [`SpmmEngine::serving`] to
    /// exercise the cached/routed path, or [`SpmmEngine::native`]).
    pub fn with_engine(
        engine: SpmmEngine,
        graph: &SyntheticGraph,
        hidden: usize,
        lr: f32,
        seed: u64,
    ) -> Result<Self> {
        let n = graph.config.nodes;
        let f = graph.config.feats;
        let c = graph.config.classes;
        let h_a = engine.register(graph.csr.clone())?;
        let h_at = engine.register(graph.csr.transposed())?;
        let x = DenseMatrix::from_vec(n, f, graph.features[..n * f].to_vec());
        let mut onehot = vec![0f32; n * c];
        for (node, &label) in graph.labels.iter().enumerate() {
            onehot[node * c + label] = 1.0;
        }
        let mut rng = Xoshiro256::seeded(seed);
        let s1 = (2.0 / (f + hidden) as f32).sqrt();
        let s2 = (2.0 / (hidden + c) as f32).sqrt();
        let mut w1 = vec![0f32; f * hidden];
        let mut w2 = vec![0f32; hidden * c];
        rng.fill_uniform_f32(&mut w1, s1);
        rng.fill_uniform_f32(&mut w2, s2);
        Ok(Self {
            engine,
            h_a,
            h_at,
            x,
            labels: graph.labels.clone(),
            labels_onehot: DenseMatrix::from_vec(n, c, onehot),
            mask: graph.mask[..n].to_vec(),
            w1: DenseMatrix::from_vec(f, hidden, w1),
            w2: DenseMatrix::from_vec(hidden, c, w2),
            lr,
        })
    }

    /// The engine aggregations run through (metrics inspection).
    pub fn engine(&self) -> &SpmmEngine {
        &self.engine
    }

    /// Forward pass; returns `(Z₁, pre₁, Z₂, logits)`.
    fn forward(&self) -> Result<(DenseMatrix, DenseMatrix, DenseMatrix, DenseMatrix)> {
        let z1 = self.engine.spmm(self.h_a, &self.x)?.y;
        let pre1 = z1.matmul(&self.w1);
        let mut h1 = pre1.clone();
        for v in &mut h1.data {
            *v = v.max(0.0);
        }
        let z2 = self.engine.spmm(self.h_a, &h1)?.y;
        let logits = z2.matmul(&self.w2);
        Ok((z1, pre1, z2, logits))
    }

    /// Masked mean cross-entropy and its logit gradient.
    fn loss_and_grad(&self, logits: &DenseMatrix) -> (f32, DenseMatrix) {
        let n = logits.rows;
        let c = logits.cols;
        let m: f32 = self.mask.iter().sum::<f32>().max(1.0);
        let mut grad = DenseMatrix::zeros(n, c);
        let mut loss = 0.0f32;
        for r in 0..n {
            let row = logits.row(r);
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let w = self.mask[r] / m;
            for j in 0..c {
                let p = exps[j] / sum;
                let y = self.labels_onehot.at(r, j);
                grad.data[r * c + j] = w * (p - y);
                if y > 0.0 && w > 0.0 {
                    loss -= w * p.max(1e-12).ln();
                }
            }
        }
        (loss, grad)
    }

    /// One SGD step; returns the loss before the update.
    pub fn step(&mut self) -> Result<f32> {
        let (z1, pre1, z2, logits) = self.forward()?;
        let (loss, dlogits) = self.loss_and_grad(&logits);
        // dW2 = Z2ᵀ·dlogits ; dZ2 = dlogits·W2ᵀ
        let dw2 = z2.transposed().matmul(&dlogits);
        let dz2 = dlogits.matmul(&self.w2.transposed());
        // aggregation backward through the transpose handle: dH1 = Âᵀ·dZ2
        let dh1 = self.engine.spmm(self.h_at, &dz2)?.y;
        // relu backward, then dW1 = Z1ᵀ·dpre1
        let mut dpre1 = dh1;
        for (g, &p) in dpre1.data.iter_mut().zip(&pre1.data) {
            if p <= 0.0 {
                *g = 0.0;
            }
        }
        let dw1 = z1.transposed().matmul(&dpre1);
        for (w, g) in self.w1.data.iter_mut().zip(&dw1.data) {
            *w -= self.lr * g;
        }
        for (w, g) in self.w2.data.iter_mut().zip(&dw2.data) {
            *w -= self.lr * g;
        }
        Ok(loss)
    }

    /// Masked train accuracy at the current weights.
    pub fn train_accuracy(&self) -> Result<f64> {
        let (_, _, _, logits) = self.forward()?;
        let c = logits.cols;
        let mut hit = 0usize;
        let mut total = 0usize;
        for r in 0..logits.rows {
            if self.mask[r] > 0.0 {
                let row = logits.row(r);
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                total += 1;
                if pred == self.labels[r] {
                    hit += 1;
                }
            }
        }
        Ok(hit as f64 / total.max(1) as f64)
    }

    /// Train for `steps` steps.
    pub fn train(&mut self, steps: usize) -> Result<NativeTrainReport> {
        let start = Instant::now();
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            losses.push(self.step()?);
        }
        Ok(NativeTrainReport {
            steps,
            seconds: start.elapsed().as_secs_f64(),
            train_accuracy: self.train_accuracy()?,
            losses,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::graph::{GraphConfig, SyntheticGraph};

    fn small_graph() -> SyntheticGraph {
        SyntheticGraph::generate(
            GraphConfig {
                nodes: 220,
                nodes_padded: 256,
                feats: 12,
                classes: 4,
                width: 16,
                communities: 4,
                avg_degree: 3.0,
                label_frac: 0.5,
            },
            17,
        )
    }

    #[test]
    fn training_reduces_the_loss_through_the_engine() {
        let graph = small_graph();
        let mut trainer = NativeGcnTrainer::new(&graph, 16, 0.2, 18).unwrap();
        let report = trainer.train(30).unwrap();
        assert_eq!(report.steps, 30);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        let first = report.losses[0];
        let last = *report.losses.last().unwrap();
        assert!(
            last < first,
            "training must reduce the loss: {first} -> {last}"
        );
        assert!(report.train_accuracy > 0.0);
        // every aggregation went through the engine: 3 SpMMs per step
        // plus 2 for the accuracy forward
        let requests = trainer.engine().metrics.requests();
        assert_eq!(requests, 30 * 3 + 2);
        // ... and the sharded engine fanned them out
        assert!(trainer.engine().metrics.shard_executions() >= requests);
    }

    #[test]
    fn backward_through_the_transpose_matches_symmetric_shortcut() {
        // Â from gcn normalization of a symmetric graph is symmetric, so
        // Âᵀ·G must equal Â·G — pin the transpose-handle plumbing.
        let graph = small_graph();
        let trainer = NativeGcnTrainer::new(&graph, 8, 0.1, 19).unwrap();
        let mut rng = Xoshiro256::seeded(20);
        let g = DenseMatrix::random(graph.config.nodes, 8, 1.0, &mut rng);
        let via_t = trainer.engine.spmm(trainer.h_at, &g).unwrap().y;
        let via_a = trainer.engine.spmm(trainer.h_a, &g).unwrap().y;
        crate::util::proptest::assert_close(&via_t.data, &via_a.data, 1e-4, 1e-4).unwrap();
    }
}
