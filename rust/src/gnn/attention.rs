//! Graph attention on the engine — the fused SDDMM→softmax→SpMM
//! dataflow, native build, no artifacts.
//!
//! Dot-product graph attention (GAT-style single head, transformer
//! scoring) over a graph with adjacency pattern `A` and node features
//! `X`:
//!
//! ```text
//! Q = X·Wq   K = X·Wk   V = X·Wv                    (dense projections)
//! S = sample(A, Q·Kᵀ) / √d                          (SDDMM: edge scores)
//! P = row-softmax(S)  on A's pattern                (host, O(nnz))
//! Y = P · V                                         (SpMM: aggregation)
//! ```
//!
//! Both sparse stages run through one [`SpmmEngine`] with adaptive
//! per-op kernel selection (and per-shard selection on sharded/serving
//! engines), which is the point: SDDMM and SpMM are the FusedMM pair of
//! attention GNN workloads, and the engine serves both over one
//! registered graph. The sampled scores inherit `A`'s stored values as
//! multiplicative edge priors — register a unit-valued pattern
//! ([`CsrMatrix::with_values`]) for pure dot-product attention.
//!
//! See `DESIGN.md` §SDDMM for the fusion dataflow and
//! `examples/gat_train.rs` for the end-to-end driver.

use crate::coordinator::{MatrixHandle, SpmmEngine};
use crate::kernels::KernelKind;
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::prng::Xoshiro256;
use anyhow::Result;

/// Row-softmax over a sparsity pattern: `scores` holds one value per
/// non-zero of `pattern` (CSR stream order); each row's entries are
/// softmax-normalized independently (max-subtracted for stability).
/// Empty rows stay empty.
pub fn row_softmax(pattern: &CsrMatrix, scores: &[f32]) -> Vec<f32> {
    assert_eq!(scores.len(), pattern.nnz(), "one score per non-zero");
    let mut out = vec![0f32; scores.len()];
    for r in 0..pattern.rows {
        let lo = pattern.indptr[r] as usize;
        let hi = pattern.indptr[r + 1] as usize;
        if lo == hi {
            continue;
        }
        let m = scores[lo..hi].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for i in lo..hi {
            let e = (scores[i] - m).exp();
            out[i] = e;
            sum += e;
        }
        for o in &mut out[lo..hi] {
            *o /= sum;
        }
    }
    out
}

/// One dot-product graph-attention head: the three dense projections and
/// the `1/√d` score scale.
pub struct AttentionLayer {
    /// Query projection (feats × head_dim).
    pub wq: DenseMatrix,
    /// Key projection (feats × head_dim).
    pub wk: DenseMatrix,
    /// Value projection (feats × head_dim).
    pub wv: DenseMatrix,
    scale: f32,
}

/// Everything one fused forward produces.
pub struct AttentionForward {
    /// Aggregated node representations `P · (X·Wv)` (nodes × head_dim).
    pub y: DenseMatrix,
    /// The row-softmaxed attention matrix on `A`'s pattern.
    pub attention: CsrMatrix,
    /// The engine's kernel choice for the SDDMM score stage.
    pub scores_kernel: KernelKind,
    /// The engine's kernel choice for the SpMM aggregation stage.
    pub agg_kernel: KernelKind,
}

impl AttentionLayer {
    /// Glorot-ish random init of the three projections.
    pub fn new(feats: usize, head_dim: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seeded(seed);
        let s = (2.0 / (feats + head_dim).max(1) as f32).sqrt();
        let proj = |rng: &mut Xoshiro256| {
            let mut w = vec![0f32; feats * head_dim];
            rng.fill_uniform_f32(&mut w, s);
            DenseMatrix::from_vec(feats, head_dim, w)
        };
        let wq = proj(&mut rng);
        let wk = proj(&mut rng);
        let wv = proj(&mut rng);
        Self {
            wq,
            wk,
            wv,
            scale: 1.0 / (head_dim.max(1) as f32).sqrt(),
        }
    }

    /// Attention width `d`.
    pub fn head_dim(&self) -> usize {
        self.wq.cols
    }

    /// Run the fused forward through `engine`. `h_adj` must be `adj`'s
    /// registration on that engine (the caller keeps the CSR because the
    /// softmax needs the row pattern). The intermediate attention matrix
    /// is registered for the aggregation SpMM — sharing the engine's
    /// prepared-matrix cache and routing — and unregistered before
    /// returning, so repeated forwards don't grow the handle map.
    pub fn forward(
        &self,
        engine: &SpmmEngine,
        adj: &CsrMatrix,
        h_adj: MatrixHandle,
        x: &DenseMatrix,
    ) -> Result<AttentionForward> {
        let q = x.matmul(&self.wq);
        let k = x.matmul(&self.wk);
        let vproj = x.matmul(&self.wv);
        // 1. SDDMM: edge scores, sampled on the adjacency pattern
        let scores = engine.sddmm(h_adj, &q, &k)?;
        // 2. scale + row-softmax on the pattern (host-side, O(nnz))
        let mut vals = scores.values;
        for s in &mut vals {
            *s *= self.scale;
        }
        let attention = adj.with_values(row_softmax(adj, &vals));
        // 3. SpMM: aggregate values under the attention weights
        let h_attn = engine.register(attention.clone())?;
        let agg = engine.spmm(h_attn, &vproj);
        engine.unregister(h_attn);
        let agg = agg?;
        Ok(AttentionForward {
            y: agg.y,
            attention,
            scores_kernel: scores.kernel,
            agg_kernel: agg.kernel,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use crate::util::proptest::assert_close;

    /// Unit-valued ring + chords pattern (every row non-empty except 7).
    fn pattern() -> CsrMatrix {
        let n = 12;
        let mut coo = CooMatrix::new(n, n);
        for r in 0..n {
            if r == 7 {
                continue; // isolated node: empty attention row
            }
            coo.push(r, (r + 1) % n, 1.0);
            coo.push(r, (r + 5) % n, 1.0);
            coo.push(r, r, 1.0);
        }
        CsrMatrix::from_coo(&coo)
    }

    /// Independent dense attention reference.
    fn dense_attention(adj: &CsrMatrix, x: &DenseMatrix, layer: &AttentionLayer) -> DenseMatrix {
        let q = x.matmul(&layer.wq);
        let k = x.matmul(&layer.wk);
        let v = x.matmul(&layer.wv);
        let n = adj.rows;
        let d = layer.head_dim();
        let scale = 1.0 / (d.max(1) as f32).sqrt();
        let mut y = DenseMatrix::zeros(n, d);
        for r in 0..n {
            let (cols, vals) = adj.row(r);
            if cols.is_empty() {
                continue;
            }
            let scores: Vec<f32> = cols
                .iter()
                .zip(vals)
                .map(|(&c, &a)| {
                    let mut dot = 0.0f32;
                    for j in 0..d {
                        dot += q.at(r, j) * k.at(c as usize, j);
                    }
                    a * dot * scale
                })
                .collect();
            let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for (i, &c) in cols.iter().enumerate() {
                let w = exps[i] / sum;
                for j in 0..d {
                    *y.at_mut(r, j) += w * v.at(c as usize, j);
                }
            }
        }
        y
    }

    #[test]
    fn row_softmax_normalizes_each_pattern_row() {
        let p = pattern();
        let scores: Vec<f32> = (0..p.nnz()).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let soft = row_softmax(&p, &scores);
        for r in 0..p.rows {
            let lo = p.indptr[r] as usize;
            let hi = p.indptr[r + 1] as usize;
            if lo == hi {
                continue;
            }
            let sum: f32 = soft[lo..hi].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
            assert!(soft[lo..hi].iter().all(|&w| w > 0.0));
        }
    }

    #[test]
    fn fused_forward_matches_the_dense_reference() {
        let adj = pattern();
        let mut rng = Xoshiro256::seeded(91);
        let x = DenseMatrix::random(12, 10, 1.0, &mut rng);
        let layer = AttentionLayer::new(10, 6, 92);
        let engine = SpmmEngine::native();
        let h = engine.register(adj.clone()).unwrap();
        let fwd = layer.forward(&engine, &adj, h, &x).unwrap();
        let want = dense_attention(&adj, &x, &layer);
        assert_close(&fwd.y.data, &want.data, 1e-5, 1e-4).unwrap();
        // the isolated node keeps a zero output row and an empty
        // attention row
        assert_eq!(fwd.attention.row_nnz(7), 0);
        assert!(fwd.y.row(7).iter().all(|&v| v == 0.0));
        // attention rows are distributions
        for r in 0..adj.rows {
            let (_, vals) = fwd.attention.row(r);
            if !vals.is_empty() {
                let sum: f32 = vals.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row {r}");
            }
        }
        // both ops were counted, op-tagged
        assert_eq!(engine.metrics.sddmm_requests(), 1);
        assert_eq!(engine.metrics.requests(), 1);
        assert!(KernelKind::ALL.contains(&fwd.scores_kernel));
        assert!(KernelKind::ALL.contains(&fwd.agg_kernel));
    }

    #[test]
    fn forward_releases_the_intermediate_handle() {
        let adj = pattern();
        let mut rng = Xoshiro256::seeded(93);
        let x = DenseMatrix::random(12, 8, 1.0, &mut rng);
        let layer = AttentionLayer::new(8, 4, 94);
        let engine = SpmmEngine::native().with_prepared_cache(16 << 20);
        let h = engine.register(adj.clone()).unwrap();
        for _ in 0..3 {
            layer.forward(&engine, &adj, h, &x).unwrap();
        }
        // identical weights → identical attention content → the cache
        // dedupes the intermediate registrations after the first
        assert_eq!(engine.metrics.cache_hits(), 2);
    }
}
