//! GNN training driver — the end-to-end workload (paper's headline
//! application: GNN training through these kernels).
//!
//! [`graph`] synthesizes a Cora-scale citation-style graph with a planted
//! 2-layer-GCN labeling (so the loss curve is meaningfully learnable);
//! [`trainer`] drives the AOT `gcn_step` artifact from Rust — weights
//! live in Rust between steps, Python never runs.

pub mod graph;
pub mod trainer;

pub use graph::{GraphConfig, SyntheticGraph};
pub use trainer::{GcnTrainer, TrainReport};
