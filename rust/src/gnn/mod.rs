//! GNN workloads — the end-to-end applications (paper's headline
//! application: GNN training through these kernels).
//!
//! [`graph`] synthesizes a Cora-scale citation-style graph with a planted
//! 2-layer-GCN labeling (so the loss curve is meaningfully learnable);
//! `trainer` (feature `pjrt`) drives the AOT `gcn_step` artifact from Rust — weights
//! live in Rust between steps, Python never runs. The trainer needs the
//! PJRT runtime and is gated on the `pjrt` feature; the graph synthesis
//! is backend-independent and always available.
//!
//! The native (default-build) counterparts run entirely through the
//! [`crate::coordinator::SpmmEngine`]:
//!
//! - [`native_trainer`] — 2-layer GCN training with manual backprop;
//!   forward and backward aggregations are engine SpMMs (the backward
//!   through a registered `Âᵀ`), so `cargo test -q` exercises end-to-end
//!   training by default;
//! - [`attention`] — GAT-style dot-product attention as the fused
//!   SDDMM→softmax→SpMM dataflow (`DESIGN.md` §SDDMM), driven by
//!   `examples/gat_train.rs` and the `ge-spmm sddmm` CLI.

pub mod attention;
pub mod graph;
pub mod native_trainer;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use attention::{AttentionForward, AttentionLayer};
pub use graph::{GraphConfig, SyntheticGraph};
pub use native_trainer::{NativeGcnTrainer, NativeTrainReport};
#[cfg(feature = "pjrt")]
pub use trainer::{GcnTrainer, TrainReport};
