//! GNN training driver — the end-to-end workload (paper's headline
//! application: GNN training through these kernels).
//!
//! [`graph`] synthesizes a Cora-scale citation-style graph with a planted
//! 2-layer-GCN labeling (so the loss curve is meaningfully learnable);
//! `trainer` (feature `pjrt`) drives the AOT `gcn_step` artifact from Rust — weights
//! live in Rust between steps, Python never runs. The trainer needs the
//! PJRT runtime and is gated on the `pjrt` feature; the graph synthesis
//! is backend-independent and always available.

pub mod graph;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use graph::{GraphConfig, SyntheticGraph};
#[cfg(feature = "pjrt")]
pub use trainer::{GcnTrainer, TrainReport};
