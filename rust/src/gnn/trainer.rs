//! GCN trainer: drive the AOT `gcn_step` artifact from Rust.
//!
//! Weights are Rust-owned tensors threaded through the step artifact;
//! the loss comes back as the third output. This is the end-to-end proof
//! that all three layers compose: Pallas kernel (L1) inside the JAX GCN
//! (L2) executed by the Rust coordinator (L3).

use crate::gnn::graph::SyntheticGraph;
use crate::runtime::tensor::Tensor;
use crate::runtime::Engine;
use crate::util::prng::Xoshiro256;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Training run report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub seconds: f64,
    pub train_accuracy: f64,
}

/// Trainer over a PJRT engine and a synthetic graph.
pub struct GcnTrainer<'e> {
    engine: &'e Engine,
    graph: &'e SyntheticGraph,
    w1: Tensor,
    w2: Tensor,
    hidden: usize,
}

impl<'e> GcnTrainer<'e> {
    /// Initialize weights (Glorot-ish) to match the `gcn_step` artifact.
    pub fn new(engine: &'e Engine, graph: &'e SyntheticGraph, seed: u64) -> Result<Self> {
        let spec = engine
            .manifest
            .by_name("gcn_step")
            .ok_or_else(|| anyhow!("gcn_step artifact missing — run `make artifacts`"))?;
        let feats = spec.param("feats").ok_or_else(|| anyhow!("missing feats"))?;
        let hidden = spec.param("hidden").ok_or_else(|| anyhow!("missing hidden"))?;
        let classes = spec.param("classes").ok_or_else(|| anyhow!("missing classes"))?;
        if feats != graph.config.feats || classes != graph.config.classes {
            return Err(anyhow!(
                "graph dims ({}, {}) do not match artifact ({feats}, {classes})",
                graph.config.feats,
                graph.config.classes
            ));
        }
        let mut rng = Xoshiro256::seeded(seed);
        let s1 = (2.0 / (feats + hidden) as f32).sqrt();
        let s2 = (2.0 / (hidden + classes) as f32).sqrt();
        let mut w1 = vec![0f32; feats * hidden];
        let mut w2 = vec![0f32; hidden * classes];
        rng.fill_uniform_f32(&mut w1, s1);
        rng.fill_uniform_f32(&mut w2, s2);
        Ok(Self {
            engine,
            graph,
            w1: Tensor::f32(vec![feats, hidden], w1),
            w2: Tensor::f32(vec![hidden, classes], w2),
            hidden,
        })
    }

    fn graph_inputs(&self) -> Vec<Tensor> {
        let c = &self.graph.config;
        vec![
            Tensor::f32(vec![c.nodes_padded, c.width], self.graph.a_values.clone()),
            Tensor::i32(
                vec![c.nodes_padded, c.width],
                self.graph.a_col_idx.clone(),
            ),
            Tensor::f32(vec![c.nodes_padded, c.feats], self.graph.features.clone()),
        ]
    }

    /// Run one SGD step; returns the loss.
    pub fn step(&mut self) -> Result<f32> {
        let c = &self.graph.config;
        let mut inputs = vec![self.w1.clone(), self.w2.clone()];
        inputs.extend(self.graph_inputs());
        inputs.push(Tensor::f32(
            vec![c.nodes_padded, c.classes],
            self.graph.labels_onehot.clone(),
        ));
        inputs.push(Tensor::f32(vec![c.nodes_padded], self.graph.mask.clone()));
        let out = self.engine.run("gcn_step", &inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("gcn_step returned {} outputs", out.len()));
        }
        let loss = out[2].as_f32()?[0];
        self.w1 = out[0].clone();
        self.w2 = out[1].clone();
        Ok(loss)
    }

    /// Inference pass via `gcn_fwd`; returns logits (nodes_padded × C).
    pub fn forward(&self) -> Result<Vec<f32>> {
        let mut inputs = vec![self.w1.clone(), self.w2.clone()];
        inputs.extend(self.graph_inputs());
        // gcn_fwd takes (w1, w2, a_vals, a_cols, feats)
        let out = self.engine.run("gcn_fwd", &inputs[..5])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Masked train accuracy from current weights.
    pub fn train_accuracy(&self) -> Result<f64> {
        let logits = self.forward()?;
        let c = self.graph.config.classes;
        let mut hit = 0usize;
        let mut total = 0usize;
        for v in 0..self.graph.config.nodes {
            if self.graph.mask[v] > 0.0 {
                let row = &logits[v * c..(v + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                total += 1;
                if pred == self.graph.labels[v] {
                    hit += 1;
                }
            }
        }
        Ok(hit as f64 / total.max(1) as f64)
    }

    /// Train for `steps` steps, logging every `log_every`.
    pub fn train(&mut self, steps: usize, log_every: usize) -> Result<TrainReport> {
        let start = Instant::now();
        let mut losses = Vec::with_capacity(steps);
        for s in 0..steps {
            let loss = self.step()?;
            losses.push(loss);
            if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
                eprintln!("step {s:4}  loss {loss:.4}");
            }
        }
        let train_accuracy = self.train_accuracy()?;
        Ok(TrainReport {
            steps,
            seconds: start.elapsed().as_secs_f64(),
            losses,
            train_accuracy,
        })
    }

    /// Hidden width (diagnostics).
    pub fn hidden(&self) -> usize {
        self.hidden
    }
}

// Tests requiring artifacts live in rust/tests/integration_gcn.rs.
