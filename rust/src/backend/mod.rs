//! Execution backends — the seam between the coordinator and *how an SpMM
//! actually runs*.
//!
//! The paper's contribution is the **adaptive use** of workload-balancing
//! and parallel-reduction, not any single kernel implementation. The
//! [`SpmmBackend`] trait keeps that separation explicit: everything above
//! it (registration, feature extraction, the Fig.-4 selector, batching,
//! serving, metrics) is backend-agnostic, and a backend only has to answer
//! two questions —
//!
//! 1. [`SpmmBackend::prepare`]: convert a CSR matrix once into whatever
//!    operand layout the backend executes from (segments/ELL planes,
//!    packed device literals, ...), paid off the request path;
//! 2. [`SpmmBackend::execute`]: run `Y = A · X` through one of the four
//!    [`KernelKind`] designs against that prepared operand.
//!
//! Three implementations exist:
//!
//! - [`NativeBackend`] — the faithful CPU ports in [`crate::kernels`] over
//!   the scoped [`crate::util::threadpool::ThreadPool`]. Always available;
//!   the default.
//! - [`ShardedBackend`] (in [`crate::shard`]) — nnz-balanced row
//!   partitioning with per-shard adaptive selection, fanning out over any
//!   inner backend. Composes: it is both an `SpmmBackend` and a consumer
//!   of one.
//! - [`RoutedBackend`] — a registration-time nnz router over two inner
//!   backends; the serving layer's large-matrix policy (small matrices
//!   stay unsharded, big ones take the per-shard-adaptive path).
//! - `PjrtBackend` (`pjrt` cargo feature) — routes to the AOT-compiled
//!   Pallas artifacts through the PJRT runtime in `crate::runtime`.
//!
//! See `DESIGN.md` §Execution backends for the backend feature matrix and
//! `DESIGN.md` §Serving layer for how the router and the prepared-matrix
//! cache compose in front of these.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod routed;

pub use crate::shard::ShardedBackend;
pub use native::{NativeBackend, TraversalMode};
pub use routed::RoutedBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::kernels::{KernelKind, VariantEntry};
use crate::sparse::{CsrMatrix, DenseMatrix};
use anyhow::{anyhow, Result};
use std::any::Any;

/// A matrix prepared for repeated execution by one backend.
///
/// The shape metadata is backend-independent (the engine validates request
/// dimensions against it); the `state` payload is the backend's own
/// prepared representation, recovered via [`PreparedOperand::state`].
pub struct PreparedOperand {
    rows: usize,
    cols: usize,
    nnz: usize,
    state: Box<dyn Any + Send + Sync>,
}

impl PreparedOperand {
    /// Wrap a backend-specific prepared representation.
    pub fn new(rows: usize, cols: usize, nnz: usize, state: Box<dyn Any + Send + Sync>) -> Self {
        Self {
            rows,
            cols,
            nnz,
            state,
        }
    }

    /// Row count of the prepared matrix.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count of the prepared matrix.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Non-zero count of the prepared matrix.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Downcast to a backend's prepared state. Errors if the operand was
    /// prepared by a different backend (a coordinator wiring bug).
    pub fn state<T: Any>(&self) -> Result<&T> {
        self.state
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("prepared operand belongs to a different backend"))
    }

    /// Validate a dense operand's inner dimension against this matrix —
    /// the one shared check the engine and every backend perform.
    pub fn check_operand(&self, x: &DenseMatrix) -> Result<()> {
        if x.rows != self.cols {
            return Err(anyhow!(
                "inner dimension mismatch: A is {}x{}, X is {}x{}",
                self.rows,
                self.cols,
                x.rows,
                x.cols
            ));
        }
        Ok(())
    }

    /// Validate SDDMM dense operands against this matrix: `U` row-aligns
    /// with `A`'s rows, `V` with `A`'s columns, and both share one dot
    /// width. The SDDMM counterpart of [`PreparedOperand::check_operand`].
    pub fn check_sddmm_operands(&self, u: &DenseMatrix, v: &DenseMatrix) -> Result<()> {
        if u.rows != self.rows || v.rows != self.cols || u.cols != v.cols {
            return Err(anyhow!(
                "sddmm operand mismatch: A is {}x{}, U is {}x{}, V is {}x{} \
                 (need U rows = A rows, V rows = A cols, U cols = V cols)",
                self.rows,
                self.cols,
                u.rows,
                u.cols,
                v.rows,
                v.cols
            ));
        }
        Ok(())
    }
}

/// Result of one backend execution.
#[derive(Clone, Debug)]
pub struct Execution {
    /// The dense result `Y` (rows × x.cols).
    pub y: DenseMatrix,
    /// The executed unit: an artifact name for `PjrtBackend`, a
    /// `native/<kernel>` label for [`NativeBackend`].
    pub artifact: String,
}

/// Result of one backend SDDMM execution: one sampled value per non-zero
/// of `A`, in CSR stream order (the pattern itself lives with the caller,
/// who registered the matrix).
#[derive(Clone, Debug)]
pub struct SddmmExecution {
    /// `values[k] = A.values[k] * (U[r_k] · V[c_k])`.
    pub values: Vec<f32>,
    /// The executed unit, `native/sddmm/<kernel>`-style.
    pub artifact: String,
}

/// A sparse-op execution backend: prepare once, execute many.
///
/// One prepared operand serves **both ops** — SpMM (`Y = A·X`, the
/// paper's op) via [`SpmmBackend::execute`], and SDDMM
/// (`S = sample(A, U·Vᵀ)`, its FusedMM companion) via
/// [`SpmmBackend::execute_sddmm`] — so the serving layer's
/// prepared-matrix cache amortizes preparation across op-mixed traffic
/// on the same graph. SDDMM has a default error implementation because
/// not every backend grows the second op at once (the PJRT artifact
/// library is SpMM-only); the native compositions all override it.
///
/// `Send + Sync` so one engine can be shared across a server thread and
/// request producers (the deployment topology in `coordinator::server`).
pub trait SpmmBackend: Send + Sync {
    /// Short backend label for logs and responses.
    fn name(&self) -> &'static str;

    /// Convert a CSR matrix into this backend's execution layout. Called
    /// once per registered matrix, off the request path.
    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand>;

    /// Execute `Y = A · X` with the given kernel design. `x.rows` has been
    /// validated against [`PreparedOperand::cols`] by the caller, but a
    /// backend is free to re-check.
    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution>;

    /// Execute `S = sample(A, U·Vᵀ)` with the given kernel design.
    /// Operand shapes have been validated via
    /// [`PreparedOperand::check_sddmm_operands`] by the caller, but a
    /// backend is free to re-check. Backends without an SDDMM path keep
    /// this default and report themselves unsupported.
    fn execute_sddmm(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmExecution> {
        let _ = (operand, u, v, kernel);
        Err(anyhow!("backend '{}' does not implement SDDMM", self.name()))
    }

    /// Execute `Y = A · X` through one specific **registry variant**
    /// ([`crate::kernels::generator::registry`]). The default collapses
    /// to the variant's family via [`SpmmBackend::execute`], so backends
    /// without per-variant dispatch stay correct automatically (they run
    /// the family's canonical behavior); [`NativeBackend`] overrides this
    /// with true variant dispatch, including non-canonical segment
    /// layouts resolved from the prepared operand.
    fn execute_variant(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<Execution> {
        self.execute(operand, x, entry.variant.family)
    }

    /// SDDMM counterpart of [`SpmmBackend::execute_variant`]; same
    /// collapse-to-family default.
    fn execute_sddmm_variant(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<SddmmExecution> {
        self.execute_sddmm(operand, u, v, entry.variant.family)
    }

    /// Incrementally re-derive prepared state after an
    /// [`crate::sparse::EdgeDelta`] batch landed on `csr`. `prev` is the
    /// operand prepared from the pre-mutation content; `structural` says
    /// whether the batch changed the sparsity pattern
    /// ([`crate::sparse::DeltaReport::structural`]).
    ///
    /// `Some(Ok(op))` — the backend patched its layout in place (cheap:
    /// value-only batches copy the new value stream into the existing
    /// segment/ELL planes without re-cutting). `None` — the backend
    /// declines and the caller must fall back to a full
    /// [`SpmmBackend::prepare`]; the default declines everything, so
    /// backends without a patch path stay correct for free.
    fn prepare_delta(
        &self,
        prev: &PreparedOperand,
        csr: &CsrMatrix,
        structural: bool,
    ) -> Option<Result<PreparedOperand>> {
        let _ = (prev, csr, structural);
        None
    }

    /// Dense widths this backend routes natively, ascending, or `None` if
    /// any width is accepted (no fixed-shape artifact library).
    fn available_n(&self) -> Option<Vec<usize>> {
        None
    }
}

/// Run [`SpmmBackend::execute`] inside a `kernel` trace span carrying
/// the backend name, kernel label, dense width and executed artifact
/// (inert when no trace is installed on this thread). Every dispatch
/// path — engine, shard fan-out, router — funnels kernel calls through
/// here so the span taxonomy stays uniform.
pub fn execute_traced(
    backend: &dyn SpmmBackend,
    operand: &PreparedOperand,
    x: &DenseMatrix,
    kernel: KernelKind,
) -> Result<Execution> {
    let mut span = crate::obs::trace::span("kernel");
    span.set_attr("backend", backend.name());
    span.set_attr("kernel", kernel.label());
    span.set_attr("n", x.cols);
    let out = backend.execute(operand, x, kernel);
    match &out {
        Ok(ex) => span.set_attr("artifact", &ex.artifact),
        Err(e) => span.set_attr("error", e),
    }
    out
}

/// SDDMM counterpart of [`execute_traced`]: wraps
/// [`SpmmBackend::execute_sddmm`] in a `kernel` span with an `op=sddmm`
/// attribute.
pub fn execute_sddmm_traced(
    backend: &dyn SpmmBackend,
    operand: &PreparedOperand,
    u: &DenseMatrix,
    v: &DenseMatrix,
    kernel: KernelKind,
) -> Result<SddmmExecution> {
    let mut span = crate::obs::trace::span("kernel");
    span.set_attr("backend", backend.name());
    span.set_attr("op", "sddmm");
    span.set_attr("kernel", kernel.label());
    span.set_attr("d", u.cols);
    let out = backend.execute_sddmm(operand, u, v, kernel);
    match &out {
        Ok(ex) => span.set_attr("artifact", &ex.artifact),
        Err(e) => span.set_attr("error", e),
    }
    out
}

/// Variant-precise sibling of [`execute_traced`]: wraps
/// [`SpmmBackend::execute_variant`] in the same `kernel` span taxonomy,
/// with the family under `kernel` and the full variant label under
/// `variant` so traces stay greppable by either.
pub fn execute_variant_traced(
    backend: &dyn SpmmBackend,
    operand: &PreparedOperand,
    x: &DenseMatrix,
    entry: &VariantEntry,
) -> Result<Execution> {
    let mut span = crate::obs::trace::span("kernel");
    span.set_attr("backend", backend.name());
    span.set_attr("kernel", entry.variant.family.label());
    span.set_attr("variant", entry.label);
    span.set_attr("n", x.cols);
    let out = backend.execute_variant(operand, x, entry);
    match &out {
        Ok(ex) => span.set_attr("artifact", &ex.artifact),
        Err(e) => span.set_attr("error", e),
    }
    out
}

/// SDDMM counterpart of [`execute_variant_traced`].
pub fn execute_sddmm_variant_traced(
    backend: &dyn SpmmBackend,
    operand: &PreparedOperand,
    u: &DenseMatrix,
    v: &DenseMatrix,
    entry: &VariantEntry,
) -> Result<SddmmExecution> {
    let mut span = crate::obs::trace::span("kernel");
    span.set_attr("backend", backend.name());
    span.set_attr("op", "sddmm");
    span.set_attr("kernel", entry.variant.family.label());
    span.set_attr("variant", entry.label);
    span.set_attr("d", u.cols);
    let out = backend.execute_sddmm_variant(operand, u, v, entry);
    match &out {
        Ok(ex) => span.set_attr("artifact", &ex.artifact),
        Err(e) => span.set_attr("error", e),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepared_operand_downcast_guards_backend_identity() {
        let op = PreparedOperand::new(2, 3, 1, Box::new(42usize));
        assert_eq!(op.rows(), 2);
        assert_eq!(op.cols(), 3);
        assert_eq!(op.nnz(), 1);
        assert_eq!(*op.state::<usize>().unwrap(), 42);
        assert!(op.state::<String>().is_err());
    }

    #[test]
    fn check_operand_validates_inner_dimension() {
        let op = PreparedOperand::new(2, 3, 1, Box::new(()));
        assert!(op.check_operand(&DenseMatrix::zeros(3, 5)).is_ok());
        let err = op.check_operand(&DenseMatrix::zeros(2, 5)).unwrap_err();
        assert!(err.to_string().contains("dimension mismatch"));
    }

    #[test]
    fn check_sddmm_operands_validates_all_three_constraints() {
        let op = PreparedOperand::new(2, 3, 1, Box::new(()));
        let ok_u = DenseMatrix::zeros(2, 4);
        let ok_v = DenseMatrix::zeros(3, 4);
        assert!(op.check_sddmm_operands(&ok_u, &ok_v).is_ok());
        // U rows must match A rows
        assert!(op
            .check_sddmm_operands(&DenseMatrix::zeros(3, 4), &ok_v)
            .is_err());
        // V rows must match A cols
        assert!(op
            .check_sddmm_operands(&ok_u, &DenseMatrix::zeros(2, 4))
            .is_err());
        // U and V must share the dot width
        assert!(op
            .check_sddmm_operands(&ok_u, &DenseMatrix::zeros(3, 5))
            .is_err());
    }

    #[test]
    fn sddmm_default_implementation_reports_unsupported() {
        struct NoSddmm;
        impl SpmmBackend for NoSddmm {
            fn name(&self) -> &'static str {
                "nosddmm"
            }
            fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
                Ok(PreparedOperand::new(csr.rows, csr.cols, csr.nnz(), Box::new(())))
            }
            fn execute(
                &self,
                _operand: &PreparedOperand,
                _x: &DenseMatrix,
                _kernel: KernelKind,
            ) -> Result<Execution> {
                unreachable!()
            }
        }
        let backend = NoSddmm;
        let op = PreparedOperand::new(0, 0, 0, Box::new(()));
        let u = DenseMatrix::zeros(0, 1);
        let v = DenseMatrix::zeros(0, 1);
        let err = backend
            .execute_sddmm(&op, &u, &v, KernelKind::SrRs)
            .unwrap_err();
        assert!(err.to_string().contains("does not implement SDDMM"), "{err}");
        // ... and declines delta patching, forcing a full re-prepare
        let csr = CsrMatrix::from_parts(0, 0, vec![0], vec![], vec![]);
        assert!(backend.prepare_delta(&op, &csr, false).is_none());
    }

    #[test]
    fn default_variant_dispatch_collapses_to_the_family() {
        use crate::kernels::{registry, SparseOp};
        // A backend that never overrides the variant methods executes the
        // variant's family — the closed-enum behavior, preserved.
        struct FamilyOnly;
        impl SpmmBackend for FamilyOnly {
            fn name(&self) -> &'static str {
                "familyonly"
            }
            fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
                Ok(PreparedOperand::new(csr.rows, csr.cols, csr.nnz(), Box::new(())))
            }
            fn execute(
                &self,
                _operand: &PreparedOperand,
                x: &DenseMatrix,
                kernel: KernelKind,
            ) -> Result<Execution> {
                Ok(Execution {
                    y: DenseMatrix::zeros(0, x.cols),
                    artifact: format!("family/{}", kernel.label()),
                })
            }
        }
        let backend = FamilyOnly;
        let op = PreparedOperand::new(0, 0, 0, Box::new(()));
        let x = DenseMatrix::zeros(0, 2);
        let entry = registry().by_label(SparseOp::Spmm, "sr_wb.s64").unwrap();
        let exec = backend.execute_variant(&op, &x, entry).unwrap();
        assert_eq!(exec.artifact, "family/sr_wb");
        // ... and the SDDMM default inherits the unsupported report
        let u = DenseMatrix::zeros(0, 1);
        let v = DenseMatrix::zeros(0, 1);
        let entry = registry().by_label(SparseOp::Sddmm, "pr_wb").unwrap();
        let err = backend.execute_sddmm_variant(&op, &u, &v, entry).unwrap_err();
        assert!(err.to_string().contains("does not implement SDDMM"));
    }
}
