//! Size-routed execution: small matrices execute unsharded, large ones
//! fan out through the sharded path.
//!
//! Sharding only pays when a matrix is large enough for the fan-out
//! overhead (scoped threads + gather copy) to amortize against per-shard
//! parallelism and per-shard adaptive selection; on a small matrix it is
//! pure overhead. [`RoutedBackend`] makes that decision once, at
//! registration: `prepare` compares the matrix's nnz against a threshold
//! and builds the prepared state through the matching inner backend, and
//! every later `execute` follows the side recorded in the operand — the
//! request path pays nothing for the routing. This is the serving
//! layer's large-matrix routing policy (see `DESIGN.md` §Serving layer).

use super::{Execution, NativeBackend, PreparedOperand, SddmmExecution, SpmmBackend};
use crate::kernels::{KernelKind, VariantEntry};
use crate::selector::AdaptiveSelector;
use crate::shard::ShardedBackend;
use crate::sparse::{CsrMatrix, DenseMatrix};
use anyhow::Result;

/// Routed prepared state: the side chosen at registration plus the inner
/// backend's operand.
struct RoutedPrepared {
    large: bool,
    operand: PreparedOperand,
}

/// Registration-time nnz router over two inner backends.
pub struct RoutedBackend {
    small: Box<dyn SpmmBackend>,
    large: Box<dyn SpmmBackend>,
    threshold_nnz: usize,
}

impl RoutedBackend {
    /// Default serving composition: an unsharded [`NativeBackend`] below
    /// `threshold_nnz`, a `shards`-way per-shard-adaptive
    /// [`ShardedBackend`] at or above it.
    pub fn new(threshold_nnz: usize, shards: usize) -> Self {
        Self::over(
            Box::new(NativeBackend::default()),
            Box::new(ShardedBackend::new(shards.max(1)).adaptive(AdaptiveSelector::default())),
            threshold_nnz,
        )
    }

    /// Serving composition with online refinement: like
    /// [`RoutedBackend::new`], but the large side's per-shard choices
    /// come from (and report back to) a shared
    /// [`OnlineSelector`](crate::selector::OnlineSelector) instead of
    /// fixed thresholds. Shard telemetry is recorded into the selector's
    /// own [`Metrics`](crate::coordinator::metrics::Metrics) instance,
    /// so counters and cost EWMAs stay in one place (the engine shares
    /// that same instance in `SpmmEngine::serving_online`). The small
    /// side stays an unsharded [`NativeBackend`]; its request-level
    /// choices are the engine's to make (and observe).
    pub fn online(
        threshold_nnz: usize,
        shards: usize,
        selector: std::sync::Arc<crate::selector::OnlineSelector>,
    ) -> Self {
        let metrics = selector.metrics();
        Self::over(
            Box::new(NativeBackend::default()),
            Box::new(ShardedBackend::new(shards.max(1)).online(selector).with_metrics(metrics)),
            threshold_nnz,
        )
    }

    /// Route between two explicit backends: matrices with
    /// `nnz >= threshold_nnz` prepare and execute through `large`, the
    /// rest through `small`.
    pub fn over(
        small: Box<dyn SpmmBackend>,
        large: Box<dyn SpmmBackend>,
        threshold_nnz: usize,
    ) -> Self {
        Self {
            small,
            large,
            threshold_nnz,
        }
    }

    /// The nnz count at or above which matrices take the large path.
    pub fn threshold_nnz(&self) -> usize {
        self.threshold_nnz
    }
}

impl SpmmBackend for RoutedBackend {
    fn name(&self) -> &'static str {
        "routed"
    }

    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
        let large = csr.nnz() >= self.threshold_nnz;
        let inner = if large {
            self.large.prepare(csr)?
        } else {
            self.small.prepare(csr)?
        };
        Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(RoutedPrepared {
                large,
                operand: inner,
            }),
        ))
    }

    fn prepare_delta(
        &self,
        prev: &PreparedOperand,
        csr: &CsrMatrix,
        structural: bool,
    ) -> Option<Result<PreparedOperand>> {
        let prep: &RoutedPrepared = match prev.state() {
            Ok(p) => p,
            Err(e) => return Some(Err(e)),
        };
        // Re-evaluate the routing decision against the mutated nnz: if
        // the matrix crossed the threshold, the prepared side is the
        // wrong backend entirely — decline so the caller re-prepares
        // (and re-routes) from scratch.
        let large = csr.nnz() >= self.threshold_nnz;
        if large != prep.large {
            return None;
        }
        let side = if large { &self.large } else { &self.small };
        let inner = side.prepare_delta(&prep.operand, csr, structural)?;
        Some(inner.map(|operand| {
            PreparedOperand::new(
                csr.rows,
                csr.cols,
                csr.nnz(),
                Box::new(RoutedPrepared { large, operand }),
            )
        }))
    }

    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution> {
        let prep: &RoutedPrepared = operand.state()?;
        let mut span = crate::obs::trace::span("route");
        span.set_attr("side", if prep.large { "large" } else { "small" });
        if prep.large {
            self.large.execute(&prep.operand, x, kernel)
        } else {
            self.small.execute(&prep.operand, x, kernel)
        }
    }

    fn execute_sddmm(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmExecution> {
        let prep: &RoutedPrepared = operand.state()?;
        let mut span = crate::obs::trace::span("route");
        span.set_attr("side", if prep.large { "large" } else { "small" });
        if prep.large {
            self.large.execute_sddmm(&prep.operand, u, v, kernel)
        } else {
            self.small.execute_sddmm(&prep.operand, u, v, kernel)
        }
    }

    fn execute_variant(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<Execution> {
        let prep: &RoutedPrepared = operand.state()?;
        let mut span = crate::obs::trace::span("route");
        span.set_attr("side", if prep.large { "large" } else { "small" });
        if prep.large {
            self.large.execute_variant(&prep.operand, x, entry)
        } else {
            self.small.execute_variant(&prep.operand, x, entry)
        }
    }

    fn execute_sddmm_variant(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<SddmmExecution> {
        let prep: &RoutedPrepared = operand.state()?;
        let mut span = crate::obs::trace::span("route");
        span.set_attr("side", if prep.large { "large" } else { "small" });
        if prep.large {
            self.large.execute_sddmm_variant(&prep.operand, u, v, entry)
        } else {
            self.small.execute_sddmm_variant(&prep.operand, u, v, entry)
        }
    }

    fn available_n(&self) -> Option<Vec<usize>> {
        // Diagnostic only: the default serving composition is
        // width-agnostic on both sides. With a fixed-width inner, the
        // small side's buckets are reported when it has any, else the
        // large side's — a per-matrix answer would need the operand.
        self.small.available_n().or_else(|| self.large.available_n())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::assert_close;

    fn check_routed(csr: &CsrMatrix, backend: &RoutedBackend, want_prefix: &str) {
        let mut rng = Xoshiro256::seeded(csr.nnz() as u64 + 901);
        let op = backend.prepare(csr).unwrap();
        let x = DenseMatrix::random(csr.cols, 5, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(csr.rows, 5);
        spmm_reference(csr, &x, &mut want);
        let exec = backend.execute(&op, &x, KernelKind::SrRs).unwrap();
        assert!(
            exec.artifact.starts_with(want_prefix),
            "expected {want_prefix}, got {}",
            exec.artifact
        );
        assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn routes_by_nnz_threshold_at_registration() {
        let mut rng = Xoshiro256::seeded(902);
        let small = CsrMatrix::from_coo(&CooMatrix::random_uniform(40, 30, 0.05, &mut rng));
        let large = CsrMatrix::from_coo(&CooMatrix::random_uniform(200, 150, 0.2, &mut rng));
        let backend = RoutedBackend::new(small.nnz() + 1, 3);
        assert_eq!(backend.name(), "routed");
        assert_eq!(backend.threshold_nnz(), small.nnz() + 1);
        assert_eq!(backend.available_n(), None);
        check_routed(&small, &backend, "native/");
        check_routed(&large, &backend, "sharded(k=");
    }

    #[test]
    fn threshold_is_inclusive_on_the_large_side() {
        let mut rng = Xoshiro256::seeded(903);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 60, 0.1, &mut rng));
        check_routed(&csr, &RoutedBackend::new(csr.nnz(), 2), "sharded(k=");
        check_routed(&csr, &RoutedBackend::new(csr.nnz() + 1, 2), "native/");
    }

    #[test]
    fn online_composition_shares_the_selector_metrics() {
        use crate::coordinator::metrics::Metrics;
        use crate::selector::{OnlineConfig, OnlineSelector};
        use std::sync::Arc;
        let metrics = Arc::new(Metrics::default());
        let online = Arc::new(OnlineSelector::new(
            AdaptiveSelector::default(),
            metrics.clone(),
            OnlineConfig {
                explore_every: 0,
                refit_every: 0,
                min_observations: 1,
            },
        ));
        let mut rng = Xoshiro256::seeded(905);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 60, 0.1, &mut rng));
        let backend = RoutedBackend::online(1, 2, online.clone());
        check_routed(&csr, &backend, "sharded(k=");
        // shard telemetry and the selector's observations land in the
        // one Metrics instance the selector was built over
        assert!(metrics.shard_executions() >= 2);
        assert_eq!(online.observations(), metrics.shard_executions());
        assert!(metrics.total_cost_observations() >= 2);
        // the small side stays unsharded and records nothing here
        let small = RoutedBackend::online(usize::MAX, 2, online.clone());
        check_routed(&csr, &small, "native/");
    }

    #[test]
    fn sddmm_follows_the_recorded_route() {
        use crate::kernels::dense::sddmm_reference;
        let mut rng = Xoshiro256::seeded(906);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 40, 0.1, &mut rng));
        let d = 6;
        let u = DenseMatrix::random(60, d, 1.0, &mut rng);
        let v = DenseMatrix::random(40, d, 1.0, &mut rng);
        let mut want = vec![0f32; csr.nnz()];
        sddmm_reference(&csr, &u, &v, &mut want);
        for (backend, prefix) in [
            (RoutedBackend::new(usize::MAX, 2), "native/sddmm/"),
            (RoutedBackend::new(1, 2), "sharded(k="),
        ] {
            let op = backend.prepare(&csr).unwrap();
            let exec = backend.execute_sddmm(&op, &u, &v, KernelKind::SrRs).unwrap();
            assert!(exec.artifact.starts_with(prefix), "{}", exec.artifact);
            assert_eq!(exec.values, want, "{prefix}");
        }
    }

    #[test]
    fn prepare_delta_patches_on_the_recorded_side() {
        use crate::sparse::EdgeDelta;
        let mut rng = Xoshiro256::seeded(907);
        let mut csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 60, 0.1, &mut rng));
        let x = DenseMatrix::random(60, 5, 1.0, &mut rng);
        for (backend, prefix) in [
            (RoutedBackend::new(usize::MAX, 2), "native/"),
            (RoutedBackend::new(1, 2), "sharded(k="),
        ] {
            let prev = backend.prepare(&csr).unwrap();
            let mut local = csr.clone();
            let mut delta = EdgeDelta::new();
            let r0 = (0..local.rows).find(|&r| local.row_nnz(r) > 0).unwrap();
            let c0 = local.row(r0).0[0] as usize;
            delta.insert(r0, c0, 42.0);
            let rep = delta.apply(&mut local);
            assert!(!rep.structural);
            let patched = backend.prepare_delta(&prev, &local, false).unwrap().unwrap();
            let fresh = backend.prepare(&local).unwrap();
            let a = backend.execute(&patched, &x, KernelKind::SrWb).unwrap();
            let b = backend.execute(&fresh, &x, KernelKind::SrWb).unwrap();
            assert!(a.artifact.starts_with(prefix), "{}", a.artifact);
            assert_eq!(a.y.data, b.y.data, "{prefix}");
        }
        // a mutation that flips the route declines the patch
        let backend = RoutedBackend::new(csr.nnz(), 2);
        let prev = backend.prepare(&csr).unwrap();
        let mut delta = EdgeDelta::new();
        let r0 = (0..csr.rows).find(|&r| csr.row_nnz(r) > 0).unwrap();
        delta.delete(r0, csr.row(r0).0[0] as usize);
        let rep = delta.apply(&mut csr);
        assert!(rep.structural);
        assert!(backend.prepare_delta(&prev, &csr, rep.structural).is_none());
    }

    #[test]
    fn variant_execution_follows_the_recorded_route() {
        use crate::kernels::{registry, SparseOp};
        let mut rng = Xoshiro256::seeded(908);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 40, 0.1, &mut rng));
        let x = DenseMatrix::random(40, 5, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(60, 5);
        spmm_reference(&csr, &x, &mut want);
        let entry = registry().by_label(SparseOp::Spmm, "sr_rs.t4").unwrap();
        // small side: the native backend honors the exact variant
        let backend = RoutedBackend::new(usize::MAX, 2);
        let op = backend.prepare(&csr).unwrap();
        let exec = backend.execute_variant(&op, &x, entry).unwrap();
        assert_eq!(exec.artifact, "native/sr_rs.t4");
        assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
        // large side: forwarded to the sharded backend (which may
        // collapse to the family), still numerically right
        let backend = RoutedBackend::new(1, 2);
        let op = backend.prepare(&csr).unwrap();
        let exec = backend.execute_variant(&op, &x, entry).unwrap();
        assert!(exec.artifact.starts_with("sharded(k="), "{}", exec.artifact);
        assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn foreign_operands_are_rejected() {
        let mut rng = Xoshiro256::seeded(904);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(30, 20, 0.2, &mut rng));
        let backend = RoutedBackend::new(usize::MAX, 2);
        let foreign = NativeBackend::serial().prepare(&csr).unwrap();
        assert!(backend
            .execute(&foreign, &DenseMatrix::zeros(20, 2), KernelKind::SrRs)
            .is_err());
    }
}
