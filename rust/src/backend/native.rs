//! The native CPU backend: the four faithful kernel ports executed on the
//! scoped thread pool.
//!
//! This is the always-available default backend — it is what makes the
//! full coordinator stack (selector → batcher → server) runnable on any
//! machine with no artifacts and no libxla. It absorbs the former
//! free-function `kernels::run_kernel` / `PreparedMatrix` dispatch path so
//! the crate has exactly one prepare-once/execute-many pipeline.

use super::{Execution, PreparedOperand, SddmmExecution, SpmmBackend};
use crate::kernels::{pr_rs, pr_wb, sr_rs, sr_wb, KernelKind, WARP};
use crate::sparse::{CsrMatrix, DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;

/// Native prepared operand: CSR for the row-split kernels plus the
/// `WARP`-length segmented layout for the workload-balanced kernels, both
/// built once at registration (mirrors how the GPU kernels take
/// preprocessed buffers).
struct NativePrepared {
    csr: CsrMatrix,
    segments: SegmentedMatrix,
}

/// CPU execution backend over [`crate::kernels`].
#[derive(Clone, Copy, Debug)]
pub struct NativeBackend {
    pool: ThreadPool,
}

impl NativeBackend {
    /// Backend over an explicit pool (worker-count policy).
    pub fn new(pool: ThreadPool) -> Self {
        Self { pool }
    }

    /// Single-worker backend (deterministic scheduling; A/B baseline).
    pub fn serial() -> Self {
        Self::new(ThreadPool::serial())
    }

    /// The pool kernels execute on.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

impl Default for NativeBackend {
    /// Backend sized to available parallelism.
    fn default() -> Self {
        Self::new(ThreadPool::default_parallel())
    }
}

impl SpmmBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
        let segments = SegmentedMatrix::from_csr(csr, WARP);
        Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(NativePrepared {
                csr: csr.clone(),
                segments,
            }),
        ))
    }

    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_operand(x)?;
        let mut y = DenseMatrix::zeros(prep.csr.rows, x.cols);
        // Degenerate shapes (no output rows / zero-width X) have nothing to
        // compute; skip the kernels, which assume at least one output row.
        if prep.csr.rows > 0 && x.cols > 0 {
            match kernel {
                KernelKind::SrRs => sr_rs::spmm(&prep.csr, x, &mut y, &self.pool),
                KernelKind::SrWb => sr_wb::spmm(&prep.segments, x, &mut y, &self.pool),
                KernelKind::PrRs => pr_rs::spmm(&prep.csr, x, &mut y, &self.pool),
                KernelKind::PrWb => pr_wb::spmm(&prep.segments, x, &mut y, &self.pool),
            }
        }
        Ok(Execution {
            y,
            artifact: format!("native/{}", kernel.label()),
        })
    }

    fn execute_sddmm(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmExecution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_sddmm_operands(u, v)?;
        let mut values = vec![0f32; prep.csr.nnz()];
        // The same prepared state serves both ops: CSR feeds the
        // row-split designs, the segment layout the nnz-split ones.
        crate::sddmm::run(kernel, &prep.csr, &prep.segments, u, v, &mut values, &self.pool);
        Ok(SddmmExecution {
            values,
            artifact: format!("native/sddmm/{}", kernel.label()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::assert_close;

    #[test]
    fn all_kernels_match_reference_through_the_trait() {
        let mut rng = Xoshiro256::seeded(31);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(90, 70, 0.08, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(3));
        let op = backend.prepare(&csr).unwrap();
        assert_eq!((op.rows(), op.cols(), op.nnz()), (90, 70, csr.nnz()));
        let x = DenseMatrix::random(70, 5, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(90, 5);
        spmm_reference(&csr, &x, &mut want);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.artifact, format!("native/{}", kind.label()));
            assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn dimension_mismatch_is_rejected() {
        let csr = CsrMatrix::from_coo(&CooMatrix::new(4, 6));
        let backend = NativeBackend::serial();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::zeros(5, 2); // should be 6 rows
        assert!(backend.execute(&op, &x, KernelKind::SrRs).is_err());
    }

    #[test]
    fn empty_matrix_yields_zeros() {
        let csr = CsrMatrix::from_coo(&CooMatrix::new(5, 5));
        let backend = NativeBackend::default();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::from_vec(5, 3, vec![1.0; 15]);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.y.data, vec![0.0; 15]);
        }
    }

    #[test]
    fn sddmm_through_the_trait_is_bit_identical_to_reference() {
        use crate::kernels::dense::sddmm_reference;
        let mut rng = Xoshiro256::seeded(37);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(70, 50, 0.1, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(3));
        let op = backend.prepare(&csr).unwrap();
        for d in [1usize, 8, 33] {
            let u = DenseMatrix::random(70, d, 1.0, &mut rng);
            let v = DenseMatrix::random(50, d, 1.0, &mut rng);
            let mut want = vec![0f32; csr.nnz()];
            sddmm_reference(&csr, &u, &v, &mut want);
            for kind in KernelKind::ALL {
                let exec = backend.execute_sddmm(&op, &u, &v, kind).unwrap();
                assert_eq!(exec.artifact, format!("native/sddmm/{}", kind.label()));
                assert_eq!(exec.values, want, "{kind:?} d={d}");
            }
        }
        // shape mismatches are rejected
        let bad_u = DenseMatrix::zeros(69, 4);
        let v = DenseMatrix::zeros(50, 4);
        assert!(backend.execute_sddmm(&op, &bad_u, &v, KernelKind::SrRs).is_err());
    }

    #[test]
    fn zero_width_x_is_handled() {
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 0, 1.0);
        let csr = CsrMatrix::from_coo(&coo);
        let backend = NativeBackend::default();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::zeros(3, 0);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!((exec.y.rows, exec.y.cols), (3, 0));
        }
    }
}
