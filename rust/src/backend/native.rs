//! The native CPU backend: the four faithful kernel ports executed on the
//! scoped thread pool.
//!
//! This is the always-available default backend — it is what makes the
//! full coordinator stack (selector → batcher → server) runnable on any
//! machine with no artifacts and no libxla. It absorbs the former
//! free-function `kernels::run_kernel` / `PreparedMatrix` dispatch path so
//! the crate has exactly one prepare-once/execute-many pipeline.
//!
//! [`TraversalMode`] adds an orthogonal policy axis for the SR kernels:
//! blocked rows (default), merge-path, or per-operand adaptive on the
//! features computed at prepare time (`DESIGN.md` §Vectorization).

use super::{Execution, PreparedOperand, SddmmExecution, SpmmBackend};
use crate::features::MatrixFeatures;
use crate::kernels::{
    merge_path, pr_rs, pr_wb, sr_rs, sr_wb, KernelKind, Traversal, VariantEntry, WARP,
};
use crate::selector::AdaptiveSelector;
use crate::sparse::{CsrMatrix, DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// How the backend walks rows for the sequential-reduction kernels
/// (`DESIGN.md` §Vectorization). Orthogonal to [`KernelKind`]: results
/// are numerically interchangeable, only worker partitioning differs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraversalMode {
    /// Always the kernels' native blocked traversal (the default —
    /// matches pre-traversal behavior exactly).
    Blocked,
    /// Always merge-path ([`crate::kernels::merge_path`]) for SR kernels.
    MergePath,
    /// Decide per operand from its features via
    /// [`AdaptiveSelector::sr_traversal`]. Because sharded execution
    /// prepares each shard through its own inner backend, this yields
    /// per-shard traversal decisions for free.
    Adaptive(AdaptiveSelector),
}

impl TraversalMode {
    /// Resolve the mode against a prepared operand's features.
    fn resolve(&self, f: &MatrixFeatures) -> Traversal {
        match self {
            TraversalMode::Blocked => Traversal::Blocked,
            TraversalMode::MergePath => Traversal::MergePath,
            TraversalMode::Adaptive(sel) => sel.sr_traversal(f),
        }
    }
}

/// Native prepared operand: CSR for the row-split kernels plus the
/// `WARP`-length segmented layout for the workload-balanced kernels, both
/// built once at registration (mirrors how the GPU kernels take
/// preprocessed buffers). Features are computed here too, so adaptive
/// traversal costs nothing at execute time.
struct NativePrepared {
    csr: CsrMatrix,
    segments: SegmentedMatrix,
    features: MatrixFeatures,
    /// Non-canonical segment layouts (variant seg lengths ≠ `WARP`),
    /// built lazily on first use and cached for the operand's lifetime —
    /// a variant sweep pays each re-cut once, plain family traffic pays
    /// nothing. The mutex guards only the map; kernels run on `Arc`
    /// clones outside the lock.
    alt_segments: Mutex<HashMap<usize, Arc<SegmentedMatrix>>>,
}

impl NativePrepared {
    /// Run `f` against the segmented layout of the given length, using
    /// the eagerly-prepared canonical layout when it matches.
    fn with_segments<R>(&self, seg_len: usize, f: impl FnOnce(&SegmentedMatrix) -> R) -> R {
        if seg_len == self.segments.seg_len {
            return f(&self.segments);
        }
        let seg = {
            let mut map = self.alt_segments.lock().unwrap_or_else(|e| e.into_inner());
            map.entry(seg_len)
                .or_insert_with(|| Arc::new(SegmentedMatrix::from_csr(&self.csr, seg_len)))
                .clone()
        };
        f(&seg)
    }
}

/// CPU execution backend over [`crate::kernels`].
#[derive(Clone, Copy, Debug)]
pub struct NativeBackend {
    pool: ThreadPool,
    traversal: TraversalMode,
}

impl NativeBackend {
    /// Backend over an explicit pool (worker-count policy). Traversal
    /// defaults to [`TraversalMode::Blocked`].
    pub fn new(pool: ThreadPool) -> Self {
        Self {
            pool,
            traversal: TraversalMode::Blocked,
        }
    }

    /// Single-worker backend (deterministic scheduling; A/B baseline).
    pub fn serial() -> Self {
        Self::new(ThreadPool::serial())
    }

    /// Same backend with an explicit SR row-traversal policy.
    pub fn with_traversal(mut self, traversal: TraversalMode) -> Self {
        self.traversal = traversal;
        self
    }

    /// The SR row-traversal policy in effect.
    pub fn traversal(&self) -> TraversalMode {
        self.traversal
    }

    /// The pool kernels execute on.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

impl Default for NativeBackend {
    /// Backend sized to available parallelism.
    fn default() -> Self {
        Self::new(ThreadPool::default_parallel())
    }
}

impl SpmmBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
        let segments = SegmentedMatrix::from_csr(csr, WARP);
        let features = MatrixFeatures::of(csr);
        Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(NativePrepared {
                csr: csr.clone(),
                segments,
                features,
                alt_segments: Mutex::new(HashMap::new()),
            }),
        ))
    }

    fn prepare_delta(
        &self,
        prev: &PreparedOperand,
        csr: &CsrMatrix,
        structural: bool,
    ) -> Option<Result<PreparedOperand>> {
        // Structural batches re-cut segments from scratch: a changed
        // sparsity pattern moves segment boundaries, row indices and
        // the padding tail, so there is nothing cheap to keep.
        if structural {
            return None;
        }
        let prep: &NativePrepared = match prev.state() {
            Ok(p) => p,
            Err(e) => return Some(Err(e)),
        };
        if prep.csr.rows != csr.rows || prep.csr.cols != csr.cols || prep.csr.nnz() != csr.nnz() {
            return Some(Err(anyhow::anyhow!(
                "value-only delta changed the matrix shape: prepared {}x{} nnz {}, got {}x{} nnz {}",
                prep.csr.rows,
                prep.csr.cols,
                prep.csr.nnz(),
                csr.rows,
                csr.cols,
                csr.nnz()
            )));
        }
        // Value-only: the CSR value stream maps 1:1 onto the segment
        // slots, so patch values into the existing cut instead of
        // re-running O(nnz) preparation. Row-length features are a
        // function of the unchanged pattern, so they carry over.
        let mut segments = prep.segments.clone();
        segments.patch_values(&csr.values);
        Some(Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(NativePrepared {
                csr: csr.clone(),
                segments,
                features: prep.features,
                alt_segments: Mutex::new(HashMap::new()),
            }),
        )))
    }

    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_operand(x)?;
        let mut y = DenseMatrix::zeros(prep.csr.rows, x.cols);
        // Degenerate shapes (no output rows / zero-width X) have nothing to
        // compute; skip the kernels, which assume at least one output row.
        let mut merge_pathed = false;
        if prep.csr.rows > 0 && x.cols > 0 {
            // The traversal policy only applies to sequential reduction:
            // merge-path preserves per-row ascending-k order, which is the
            // SR contract; the PR designs reduce within lane bundles.
            let sr_mp = !kernel.is_parallel_reduction()
                && self.traversal.resolve(&prep.features) == Traversal::MergePath;
            if !kernel.is_parallel_reduction() {
                let mut span = crate::obs::trace::span("traversal");
                span.set_attr(
                    "traversal",
                    if sr_mp {
                        Traversal::MergePath.label()
                    } else {
                        Traversal::Blocked.label()
                    },
                );
                span.set_attr("cv_row", format!("{:.3}", prep.features.cv_row));
            }
            match kernel {
                _ if sr_mp => {
                    merge_path::spmm(&prep.csr, x, &mut y, &self.pool);
                    merge_pathed = true;
                }
                KernelKind::SrRs => sr_rs::spmm(&prep.csr, x, &mut y, &self.pool),
                KernelKind::SrWb => sr_wb::spmm(&prep.segments, x, &mut y, &self.pool),
                KernelKind::PrRs => pr_rs::spmm(&prep.csr, x, &mut y, &self.pool),
                KernelKind::PrWb => pr_wb::spmm(&prep.segments, x, &mut y, &self.pool),
            }
        }
        Ok(Execution {
            y,
            artifact: if merge_pathed {
                format!("native/{}+mp", kernel.label())
            } else {
                format!("native/{}", kernel.label())
            },
        })
    }

    fn execute_sddmm(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmExecution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_sddmm_operands(u, v)?;
        let mut values = vec![0f32; prep.csr.nnz()];
        // The same prepared state serves both ops: CSR feeds the
        // row-split designs, the segment layout the nnz-split ones.
        crate::sddmm::run(kernel, &prep.csr, &prep.segments, u, v, &mut values, &self.pool);
        Ok(SddmmExecution {
            values,
            artifact: format!("native/sddmm/{}", kernel.label()),
        })
    }

    fn execute_variant(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<Execution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_operand(x)?;
        let mut y = DenseMatrix::zeros(prep.csr.rows, x.cols);
        if prep.csr.rows > 0 && x.cols > 0 {
            // A variant fixes its own traversal axis (`sr_rs.mp` *is* the
            // merge-path entry), so the backend-level TraversalMode policy
            // does not apply on this path — the selector that picked the
            // variant already owns that decision.
            prep.with_segments(entry.variant.seg_len, |seg| {
                entry.run_spmm(&prep.csr, seg, x, &mut y, &self.pool)
            })?;
        }
        // Canonical variants carry the family label, so this collapses to
        // the classic `native/<kernel>` artifact for the four canonical
        // points and extends it (`native/sr_wb.s64`, ...) for the rest.
        Ok(Execution {
            y,
            artifact: format!("native/{}", entry.label),
        })
    }

    fn execute_sddmm_variant(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        entry: &VariantEntry,
    ) -> Result<SddmmExecution> {
        let prep: &NativePrepared = operand.state()?;
        operand.check_sddmm_operands(u, v)?;
        let mut values = vec![0f32; prep.csr.nnz()];
        prep.with_segments(entry.variant.seg_len, |seg| {
            entry.run_sddmm(&prep.csr, seg, u, v, &mut values, &self.pool)
        })?;
        Ok(SddmmExecution {
            values,
            artifact: format!("native/sddmm/{}", entry.label),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::assert_close;

    #[test]
    fn all_kernels_match_reference_through_the_trait() {
        let mut rng = Xoshiro256::seeded(31);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(90, 70, 0.08, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(3));
        let op = backend.prepare(&csr).unwrap();
        assert_eq!((op.rows(), op.cols(), op.nnz()), (90, 70, csr.nnz()));
        let x = DenseMatrix::random(70, 5, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(90, 5);
        spmm_reference(&csr, &x, &mut want);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.artifact, format!("native/{}", kind.label()));
            assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
        }
    }

    #[test]
    fn dimension_mismatch_is_rejected() {
        let csr = CsrMatrix::from_coo(&CooMatrix::new(4, 6));
        let backend = NativeBackend::serial();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::zeros(5, 2); // should be 6 rows
        assert!(backend.execute(&op, &x, KernelKind::SrRs).is_err());
    }

    #[test]
    fn empty_matrix_yields_zeros() {
        let csr = CsrMatrix::from_coo(&CooMatrix::new(5, 5));
        let backend = NativeBackend::default();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::from_vec(5, 3, vec![1.0; 15]);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.y.data, vec![0.0; 15]);
        }
    }

    #[test]
    fn sddmm_through_the_trait_is_bit_identical_to_reference() {
        use crate::kernels::dense::sddmm_reference;
        let mut rng = Xoshiro256::seeded(37);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(70, 50, 0.1, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(3));
        let op = backend.prepare(&csr).unwrap();
        for d in [1usize, 8, 33] {
            let u = DenseMatrix::random(70, d, 1.0, &mut rng);
            let v = DenseMatrix::random(50, d, 1.0, &mut rng);
            let mut want = vec![0f32; csr.nnz()];
            sddmm_reference(&csr, &u, &v, &mut want);
            for kind in KernelKind::ALL {
                let exec = backend.execute_sddmm(&op, &u, &v, kind).unwrap();
                assert_eq!(exec.artifact, format!("native/sddmm/{}", kind.label()));
                assert_eq!(exec.values, want, "{kind:?} d={d}");
            }
        }
        // shape mismatches are rejected
        let bad_u = DenseMatrix::zeros(69, 4);
        let v = DenseMatrix::zeros(50, 4);
        assert!(backend.execute_sddmm(&op, &bad_u, &v, KernelKind::SrRs).is_err());
    }

    #[test]
    fn merge_path_traversal_matches_blocked_and_tags_the_artifact() {
        let mut rng = Xoshiro256::seeded(41);
        // heavy-tailed: one row dominates, so adaptive mode flips too
        let mut coo = CooMatrix::new(400, 200);
        for c in 0..200 {
            coo.push(3, c, 0.01 * c as f32);
        }
        for r in 0..60 {
            coo.push(r + 100, r, 1.0);
        }
        let csr = CsrMatrix::from_coo(&coo);
        let x = DenseMatrix::random(200, 9, 1.0, &mut rng);
        let pool = ThreadPool::new(3);

        let blocked = NativeBackend::new(pool);
        let op = blocked.prepare(&csr).unwrap();
        let base = blocked.execute(&op, &x, KernelKind::SrRs).unwrap();
        assert_eq!(base.artifact, "native/sr_rs");

        let mp = NativeBackend::new(pool).with_traversal(TraversalMode::MergePath);
        for kind in [KernelKind::SrRs, KernelKind::SrWb] {
            let exec = mp.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.artifact, format!("native/{}+mp", kind.label()));
            assert_close(&exec.y.data, &base.y.data, 1e-4, 1e-4).unwrap();
        }
        // PR kernels are untouched by the policy
        let pr = mp.execute(&op, &x, KernelKind::PrRs).unwrap();
        assert_eq!(pr.artifact, "native/pr_rs");

        // adaptive: this operand's cv_row exceeds the default t_mp
        let adaptive = NativeBackend::new(pool)
            .with_traversal(TraversalMode::Adaptive(AdaptiveSelector::default()));
        let exec = adaptive.execute(&op, &x, KernelKind::SrRs).unwrap();
        assert_eq!(exec.artifact, "native/sr_rs+mp");
        assert_close(&exec.y.data, &base.y.data, 1e-4, 1e-4).unwrap();

        // ... but a flat matrix stays blocked under the same backend
        let flat = CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 80, 0.1, &mut rng));
        let flat_op = adaptive.prepare(&flat).unwrap();
        let xf = DenseMatrix::random(80, 4, 1.0, &mut rng);
        let exec = adaptive.execute(&flat_op, &xf, KernelKind::SrRs).unwrap();
        assert_eq!(exec.artifact, "native/sr_rs");
    }

    #[test]
    fn value_only_prepare_delta_matches_full_prepare_bit_for_bit() {
        use crate::sparse::EdgeDelta;
        let mut rng = Xoshiro256::seeded(53);
        let mut csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 40, 0.1, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(2));
        let prev = backend.prepare(&csr).unwrap();

        // value-only batch: rewrite a handful of existing edges
        let mut delta = EdgeDelta::new();
        for r in 0..csr.rows {
            let (cols, vals) = csr.row(r);
            if let (Some(&c), Some(&v)) = (cols.first(), vals.first()) {
                delta.insert(r, c as usize, v * 3.0 - 1.0);
            }
        }
        let rep = delta.apply(&mut csr);
        assert!(!rep.structural);
        let patched = backend.prepare_delta(&prev, &csr, rep.structural).unwrap().unwrap();
        let fresh = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::random(40, 7, 1.0, &mut rng);
        for kind in KernelKind::ALL {
            let a = backend.execute(&patched, &x, kind).unwrap();
            let b = backend.execute(&fresh, &x, kind).unwrap();
            assert_eq!(a.y.data, b.y.data, "{kind:?}");
        }

        // structural batches decline (the caller re-prepares)
        let mut grow = EdgeDelta::new();
        let r0 = (0..csr.rows).find(|&r| csr.row_nnz(r) < csr.cols).unwrap();
        let c0 = (0..csr.cols as u32)
            .find(|c| csr.row(r0).0.binary_search(c).is_err())
            .unwrap();
        grow.insert(r0, c0 as usize, 1.0);
        let rep = grow.apply(&mut csr);
        assert!(rep.structural);
        assert!(backend.prepare_delta(&patched, &csr, rep.structural).is_none());

        // a shape-inconsistent "value-only" claim is an error, not a
        // silent mispatch
        assert!(backend.prepare_delta(&prev, &csr, false).unwrap().is_err());
    }

    #[test]
    fn variant_dispatch_matches_reference_and_labels_artifacts() {
        use crate::kernels::{registry, SparseOp};
        let mut rng = Xoshiro256::seeded(61);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(85, 65, 0.09, &mut rng));
        let backend = NativeBackend::new(ThreadPool::new(3));
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::random(65, 6, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(85, 6);
        spmm_reference(&csr, &x, &mut want);
        for e in registry().op_variants(SparseOp::Spmm) {
            let exec = backend.execute_variant(&op, &x, e).unwrap();
            assert_eq!(exec.artifact, format!("native/{}", e.label));
            assert_close(&exec.y.data, &want.data, 1e-5, 1e-5)
                .unwrap_or_else(|err| panic!("{}: {err}", e.label));
        }
        // canonical variants produce the classic family artifact strings
        let canon = registry().canonical(SparseOp::Spmm, KernelKind::PrWb);
        let exec = backend.execute_variant(&op, &x, canon).unwrap();
        assert_eq!(exec.artifact, "native/pr_wb");

        // SDDMM variants stay bit-identical to the reference
        use crate::kernels::dense::sddmm_reference;
        let u = DenseMatrix::random(85, 8, 1.0, &mut rng);
        let v = DenseMatrix::random(65, 8, 1.0, &mut rng);
        let mut svals = vec![0f32; csr.nnz()];
        sddmm_reference(&csr, &u, &v, &mut svals);
        for e in registry().op_variants(SparseOp::Sddmm) {
            let exec = backend.execute_sddmm_variant(&op, &u, &v, e).unwrap();
            assert_eq!(exec.artifact, format!("native/sddmm/{}", e.label));
            assert_eq!(exec.values, svals, "{}", e.label);
        }
    }

    #[test]
    fn zero_width_x_is_handled() {
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 0, 1.0);
        let csr = CsrMatrix::from_coo(&coo);
        let backend = NativeBackend::default();
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::zeros(3, 0);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!((exec.y.rows, exec.y.cols), (3, 0));
        }
    }
}
