//! The PJRT backend: route requests to AOT-compiled Pallas artifacts.
//!
//! Wraps [`crate::runtime::Engine`] (manifest + compile cache + execute)
//! behind [`SpmmBackend`]: `prepare` extracts the bucket-routing metadata
//! once per matrix, `execute` routes `(kernel, n, shape)` to the smallest
//! fitting artifact bucket, packs operands, and runs.
//!
//! Per-matrix packed operands are cached as PJRT literals keyed by
//! artifact name: packing AND host→literal conversion are O(bucket), so
//! they are paid once per (matrix, artifact) and reused across requests —
//! this is what keeps repeat traffic cheap (§Perf in DESIGN.md).

use super::{Execution, PreparedOperand, SpmmBackend};
use crate::coordinator::pack;
use crate::kernels::{KernelKind, WARP};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::Engine;
use crate::sparse::{CsrMatrix, DenseMatrix, EllMatrix, SegmentedMatrix};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// PJRT prepared operand: the CSR source (packed lazily per artifact) plus
/// the routing metadata, and the packed-literal cache.
struct PjrtPrepared {
    csr: CsrMatrix,
    /// padded ELL width — the row-split bucket-fit criterion
    ell_width: usize,
    /// `WARP`-length segment count — the workload-balanced fit criterion
    num_segments: usize,
    /// packed + literal-converted operand cache keyed by artifact name
    packed: Mutex<HashMap<String, Arc<Vec<xla::Literal>>>>,
}

/// Artifact execution backend over the PJRT runtime.
pub struct PjrtBackend {
    runtime: Engine,
}

impl PjrtBackend {
    /// Build over an artifact directory (see `make artifacts`).
    pub fn new(artifact_dir: &std::path::Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            runtime: Engine::new(artifact_dir)?,
        })
    }

    /// Direct access to the PJRT runtime (GCN trainer, diagnostics).
    pub fn runtime(&self) -> &Engine {
        &self.runtime
    }

    /// Smallest artifact width ≥ n.
    fn route_n(&self, n: usize) -> Result<usize> {
        self.available_n()
            .unwrap_or_default()
            .into_iter()
            .find(|&a| a >= n)
            .ok_or_else(|| anyhow!("no artifact bucket for n={n}"))
    }

    /// Packed sparse operands for (matrix, artifact), cached as literals.
    fn packed_operands(
        &self,
        prep: &PjrtPrepared,
        spec: &ArtifactSpec,
    ) -> Result<Arc<Vec<xla::Literal>>> {
        if let Some(hit) = prep.packed.lock().unwrap().get(&spec.name) {
            return Ok(hit.clone());
        }
        let variant = spec
            .variant
            .as_deref()
            .ok_or_else(|| anyhow!("artifact {} has no variant", spec.name))?;
        let tensors = if variant.ends_with("_rs") {
            let (v, c) = pack::ell_tensors(&prep.csr, spec)?;
            vec![v, c]
        } else {
            let (v, c, r) = pack::segment_tensors(&prep.csr, spec)?;
            vec![v, c, r]
        };
        let literals = tensors
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let arc = Arc::new(literals);
        prep.packed
            .lock()
            .unwrap()
            .insert(spec.name.clone(), arc.clone());
        Ok(arc)
    }
}

impl SpmmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
        let ell_width = EllMatrix::from_csr(csr, 1, 1).width;
        let num_segments = SegmentedMatrix::from_csr(csr, WARP).num_segments;
        Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(PjrtPrepared {
                csr: csr.clone(),
                ell_width,
                num_segments,
                packed: Mutex::new(HashMap::new()),
            }),
        ))
    }

    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution> {
        let prep: &PjrtPrepared = operand.state()?;
        operand.check_operand(x)?;
        let n_bucket = self.route_n(x.cols.max(1))?;
        let spec = self
            .runtime
            .manifest
            .route_spmm(
                kernel.label(),
                n_bucket,
                prep.csr.rows,
                prep.csr.cols,
                prep.ell_width,
                prep.num_segments,
            )
            .ok_or_else(|| {
                anyhow!(
                    "no {} bucket fits matrix {}x{} (width {}, {} segments) at n={}",
                    kernel.label(),
                    prep.csr.rows,
                    prep.csr.cols,
                    prep.ell_width,
                    prep.num_segments,
                    n_bucket
                )
            })?
            .clone();

        let sparse_inputs = self.packed_operands(prep, &spec)?;
        let k_bucket = spec.param("k").ok_or_else(|| anyhow!("bucket missing k"))?;
        let x_lit = pack::dense_tensor(x, k_bucket, n_bucket)?.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = sparse_inputs.iter().collect();
        inputs.push(&x_lit);
        let outputs = self.runtime.load(&spec.name)?.run_literals(&inputs)?;
        let y = pack::unpack_output(&outputs[0], prep.csr.rows, x.cols)?;
        Ok(Execution {
            y,
            artifact: spec.name,
        })
    }

    /// The artifact dense widths available for routing, ascending.
    fn available_n(&self) -> Option<Vec<usize>> {
        let mut ns: Vec<usize> = self
            .runtime
            .manifest
            .artifacts
            .iter()
            .filter_map(|a| a.n)
            .collect();
        ns.sort_unstable();
        ns.dedup();
        Some(ns)
    }
}

// Execution tests requiring real artifacts (and a real xla binding) live
// in rust/tests/ behind the `pjrt` feature.
