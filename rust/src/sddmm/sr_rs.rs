//! SR-RS SDDMM — sequential dot products, row split.
//!
//! Each pool worker owns a block of rows and computes its rows' sampled
//! dot products with a scalar accumulator marching over `d` — the
//! CSR-scalar shape. Cost per row is `row_nnz · d`, so a skewed
//! row-length distribution imbalances workers: exactly the regime the
//! workload-balanced [`super::sr_wb`] exists for.

use super::{dot_sr, SharedValues, ROW_CHUNK};
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::threadpool::ThreadPool;

/// SR-RS SDDMM: `out[k] = a.values[k] * (U[r_k] · V[c_k])` in CSR stream
/// order. `out.len()` must equal `a.nnz()`.
pub fn sddmm(a: &CsrMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], pool: &ThreadPool) {
    assert_eq!(u.rows, a.rows, "U rows mismatch");
    assert_eq!(v.rows, a.cols, "V rows mismatch");
    assert_eq!(u.cols, v.cols, "U/V width mismatch");
    assert_eq!(out.len(), a.nnz(), "output length mismatch");
    if a.nnz() == 0 {
        return;
    }
    let d = u.cols;
    let pool = &pool.for_work(a.nnz() * d.max(1));
    let shared = SharedValues::new(out);
    pool.scope_chunks(a.rows, ROW_CHUNK, |rows| {
        let lo = a.indptr[rows.start] as usize;
        let hi = a.indptr[rows.end] as usize;
        if lo == hi {
            return;
        }
        // SAFETY: row blocks have disjoint nnz spans (indptr is monotone).
        let out = unsafe { shared.slice_mut(lo, hi) };
        for r in rows {
            let (cols, vals) = a.row(r);
            let base = a.indptr[r] as usize - lo;
            let urow = u.row(r);
            for k in 0..cols.len() {
                let vrow = v.row(cols[k] as usize);
                out[base + k] = vals[k] * dot_sr(urow, vrow);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::sddmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::proptest::run_prop;

    #[test]
    fn matches_reference_bitwise_property() {
        run_prop("sddmm sr_rs vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let d = *g.choose(&[0usize, 1, 3, 8, 33]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let u = DenseMatrix::from_vec(rows, d, g.vec_f32(rows * d));
            let v = DenseMatrix::from_vec(cols, d, g.vec_f32(cols * d));
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            let workers = *g.choose(&[1usize, 2, 5]);
            let mut got = vec![0f32; a.nnz()];
            sddmm(&a, &u, &v, &mut got, &ThreadPool::new(workers));
            if got == want {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} d={d} workers={workers}"))
            }
        });
    }

    #[test]
    fn empty_matrix_is_a_noop() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        let u = DenseMatrix::zeros(4, 3);
        let v = DenseMatrix::zeros(4, 3);
        let mut out: Vec<f32> = Vec::new();
        sddmm(&a, &u, &v, &mut out, &ThreadPool::new(2));
        assert!(out.is_empty());
    }
}
