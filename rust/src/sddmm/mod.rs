//! Native SDDMM kernels — the paper's 2×2 design space instantiated for
//! the **sampled dense-dense matmul** `S = sample(A, U·Vᵀ)`.
//!
//! SDDMM is SpMM's companion op in attention-style GNN workloads (the
//! FusedMM pair of Bharadwaj et al., "Distributed-Memory Sparse Kernels
//! for Machine Learning"): graph attention computes edge scores with an
//! SDDMM, row-softmaxes them on the sparsity pattern, and aggregates with
//! an SpMM. For `A: M×K` sparse, `U: M×d` and `V: K×d` dense row-major,
//! the output is one value per non-zero, in CSR stream order:
//!
//! ```text
//! out[k] = A.values[k] * Σ_j U[r_k][j] · V[c_k][j]
//! ```
//!
//! The design axes map onto SDDMM as follows (see `DESIGN.md` §SDDMM):
//!
//! |                    | row-split (RS)   | workload-balanced (WB) |
//! |--------------------|------------------|-------------------------|
//! | sequential dot (SR)| [`sr_rs`]        | [`sr_wb`]               |
//! | lane-parallel (PR) | [`pr_rs`]        | [`pr_wb`]               |
//!
//! - **RS vs WB** is the same partitioning question as in SpMM: RS hands
//!   each worker a block of rows (cost per row ∝ row nnz, so skew
//!   imbalances workers), WB hands each worker fixed-nnz segments of the
//!   stream ([`crate::sparse::SegmentedMatrix`] — per-nnz cost is uniform
//!   in SDDMM, so nnz-splitting balances it *exactly*). Unlike SpMM, WB
//!   needs no carries: every non-zero owns its own output slot.
//! - **SR vs PR** picks the *dot-product* structure — the reduction axis
//!   of SDDMM is `d`, not the dense width N. SR marches a scalar
//!   accumulator over `d`; PR stages `WARP`-wide windows of products into
//!   a lane array first (the CUDA kernels' vectorized load + multiply)
//!   and then merges. The merge is performed **in lane order** rather
//!   than as a `__shfl` log-tree: a tree regroups float summation, and
//!   this module's acceptance bar is *bit-for-bit* equality of all four
//!   designs against [`crate::kernels::dense::sddmm_reference`] (the
//!   property fuzzer in `tests/sddmm_agreement.rs` pins exact equality,
//!   not tolerance). The lane structure, windowing and load pattern are
//!   preserved; only the merge order is canonicalized.
//!
//! **Canonical dot under `simd`**: the reduction axis `d` is where SDDMM
//! vectorizes, and a blocked dot reassociates the float sum. To keep the
//! bit-for-bit invariant, the `simd` feature switches the *canonical*
//! summation order itself: all four kernels ([`dot_sr`]/[`dot_pr`]) and
//! [`crate::kernels::dense::sddmm_reference`] move together to the same
//! 8-accumulator blocked order ([`crate::kernels::vec8::dot_blocked`]).
//! Within either feature configuration all five implementations remain
//! bit-identical; *across* configurations results differ by ordinary
//! rounding (≤ 4 ULPs for the sizes tested).
//!
//! Callers never dispatch these directly: execution goes through
//! [`crate::backend::SpmmBackend::execute_sddmm`], with kernel choice
//! from [`crate::selector::SddmmSelector`].

pub mod pr_rs;
pub mod pr_wb;
pub mod sr_rs;
pub mod sr_wb;

use crate::kernels::{KernelKind, WARP};
use crate::sparse::{CsrMatrix, DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;
use std::cell::UnsafeCell;

/// Rows per parallel work item on the row-split kernels.
const ROW_CHUNK: usize = 64;

/// Shared mutable output values. SAFETY contract: concurrent writers must
/// touch disjoint index ranges — guaranteed by construction here: the
/// row-split kernels hand each worker the nnz range of its own rows
/// (CSR `indptr` is monotone, so row blocks have disjoint nnz spans) and
/// the workload-balanced kernels hand each worker its own segment range.
pub(crate) struct SharedValues<'a> {
    data: &'a UnsafeCell<[f32]>,
}

unsafe impl Sync for SharedValues<'_> {}

impl<'a> SharedValues<'a> {
    pub fn new(data: &'a mut [f32]) -> Self {
        // SAFETY: &mut guarantees exclusivity; UnsafeCell re-shares it
        // under the disjoint-ranges contract documented above.
        let cell = unsafe { &*(data as *mut [f32] as *const UnsafeCell<[f32]>) };
        Self { data: cell }
    }

    /// Mutable view of `lo..hi`. SAFETY: caller must ensure no other
    /// thread accesses any index in `lo..hi` concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [f32] {
        let base = (*self.data.get()).as_mut_ptr();
        std::slice::from_raw_parts_mut(base.add(lo), hi - lo)
    }
}

/// Sequential dot product in ascending-`j` order — the canonical
/// summation order every SDDMM kernel (and the dense reference) uses.
#[inline]
pub(crate) fn dot_sequential(u: &[f32], v: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for j in 0..u.len() {
        acc += u[j] * v[j];
    }
    acc
}

/// Lane-parallel dot product: `WARP`-wide windows of products are staged
/// into a lane array (one multiply per lane — where the CUDA kernels
/// issue their vectorized loads), then merged in lane order. The merge
/// order makes the result bit-identical to [`dot_sequential`]; see the
/// module docs for why the `__shfl` tree is not reproduced here.
#[inline]
pub(crate) fn dot_lanes(u: &[f32], v: &[f32]) -> f32 {
    let d = u.len();
    let mut lanes = [0f32; WARP];
    let mut acc = 0.0f32;
    let mut j = 0;
    while j < d {
        let w = (d - j).min(WARP);
        // parallel elementwise multiply (lanes beyond w idle)
        for l in 0..w {
            lanes[l] = u[j + l] * v[j + l];
        }
        // ordered merge of the window
        for &p in &lanes[..w] {
            acc += p;
        }
        j += w;
    }
    acc
}

/// Canonical dot for the sequential-reduction (SR) SDDMM kernels: plain
/// ascending-`j` order, or the blocked order when the `simd` feature
/// changes the canonical summation (module docs, "Canonical dot under
/// `simd`").
#[inline]
pub(crate) fn dot_sr(u: &[f32], v: &[f32]) -> f32 {
    if cfg!(feature = "simd") {
        crate::kernels::vec8::dot_blocked(u, v)
    } else {
        dot_sequential(u, v)
    }
}

/// Canonical dot for the lane-parallel (PR) SDDMM kernels: the
/// lane-staged [`dot_lanes`] (bit-identical to [`dot_sequential`]), or
/// the blocked order under `simd` — same value as [`dot_sr`] in every
/// configuration.
#[inline]
pub(crate) fn dot_pr(u: &[f32], v: &[f32]) -> f32 {
    if cfg!(feature = "simd") {
        crate::kernels::vec8::dot_blocked(u, v)
    } else {
        dot_lanes(u, v)
    }
}

/// Run one SDDMM design against the prepared layouts. `out.len()` must be
/// `csr.nnz()` (== `seg.nnz`); degenerate shapes (`nnz == 0`) are a no-op.
/// The shared prepare-once dispatcher used by the native backend, the
/// bench harness and the agreement tests.
pub fn run(
    kind: KernelKind,
    csr: &CsrMatrix,
    seg: &SegmentedMatrix,
    u: &DenseMatrix,
    v: &DenseMatrix,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    match kind {
        KernelKind::SrRs => sr_rs::sddmm(csr, u, v, out, pool),
        KernelKind::SrWb => sr_wb::sddmm(seg, u, v, out, pool),
        KernelKind::PrRs => pr_rs::sddmm(csr, u, v, out, pool),
        KernelKind::PrWb => pr_wb::sddmm(seg, u, v, out, pool),
    }
}

/// One-call convenience for direct library use: run one design end to
/// end (building the prepared layouts itself) and return the sampled
/// output as a [`CsrMatrix`] sharing `a`'s pattern. The engine path
/// ([`crate::coordinator::SpmmEngine::sddmm`]) returns raw values
/// instead, so callers that post-process per-nnz (e.g. the softmax in
/// [`crate::gnn::attention`]) avoid an intermediate matrix.
pub fn sddmm_csr(
    kind: KernelKind,
    a: &CsrMatrix,
    u: &DenseMatrix,
    v: &DenseMatrix,
    pool: &ThreadPool,
) -> CsrMatrix {
    let seg = SegmentedMatrix::from_csr(a, WARP);
    let mut values = vec![0f32; a.nnz()];
    run(kind, a, &seg, u, v, &mut values, pool);
    a.with_values(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::sddmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn dot_orders_agree_bitwise() {
        let mut rng = Xoshiro256::seeded(77);
        for d in [0usize, 1, 5, 31, 32, 33, 64, 100] {
            let mut u = vec![0f32; d];
            let mut v = vec![0f32; d];
            rng.fill_uniform_f32(&mut u, 1.0);
            rng.fill_uniform_f32(&mut v, 1.0);
            let a = dot_sequential(&u, &v);
            let b = dot_lanes(&u, &v);
            assert_eq!(a.to_bits(), b.to_bits(), "d={d}: {a} vs {b}");
        }
    }

    #[test]
    fn canonical_dots_agree_bitwise_in_every_config() {
        // dot_sr == dot_pr whatever features are on: both resolve to the
        // same canonical summation order, so SR and PR designs can never
        // drift apart.
        let mut rng = Xoshiro256::seeded(80);
        for d in [0usize, 1, 7, 8, 9, 32, 33, 100] {
            let mut u = vec![0f32; d];
            let mut v = vec![0f32; d];
            rng.fill_uniform_f32(&mut u, 1.0);
            rng.fill_uniform_f32(&mut v, 1.0);
            let a = dot_sr(&u, &v);
            let b = dot_pr(&u, &v);
            assert_eq!(a.to_bits(), b.to_bits(), "d={d}: {a} vs {b}");
        }
    }

    #[test]
    fn all_designs_match_reference_bitwise() {
        let mut rng = Xoshiro256::seeded(78);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 45, 0.12, &mut rng));
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        for d in [1usize, 4, 33, 64] {
            let u = DenseMatrix::random(60, d, 1.0, &mut rng);
            let v = DenseMatrix::random(45, d, 1.0, &mut rng);
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            for kind in KernelKind::ALL {
                let mut got = vec![0f32; a.nnz()];
                run(kind, &a, &seg, &u, &v, &mut got, &ThreadPool::new(3));
                assert_eq!(got, want, "{kind:?} d={d}");
            }
        }
    }

    #[test]
    fn sddmm_csr_shares_the_pattern() {
        let mut rng = Xoshiro256::seeded(79);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(20, 20, 0.2, &mut rng));
        let u = DenseMatrix::random(20, 8, 1.0, &mut rng);
        let v = DenseMatrix::random(20, 8, 1.0, &mut rng);
        let s = sddmm_csr(KernelKind::SrRs, &a, &u, &v, &ThreadPool::serial());
        assert_eq!(s.indptr, a.indptr);
        assert_eq!(s.indices, a.indices);
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        assert_eq!(s.values, want);
    }
}
