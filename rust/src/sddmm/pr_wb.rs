//! PR-WB SDDMM — lane-parallel dot products over fixed-nnz segments.
//!
//! The full combination: nnz-split segments balance workers exactly
//! (as in [`super::sr_wb`]) *and* each sampled dot runs lane-parallel
//! over `d`-windows (as in [`super::pr_rs`]). This is the SDDMM analogue
//! of the paper's VSR: since SDDMM's reduction axis is the dot length
//! `d` — shared by every non-zero — no segmented-scan network is needed;
//! the segment structure only carries the balanced work assignment.

use super::{dot_pr, SharedValues};
use crate::sparse::{DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;

/// PR-WB SDDMM over the segmented layout. `out.len()` must equal `a.nnz`.
pub fn sddmm(
    a: &SegmentedMatrix,
    u: &DenseMatrix,
    v: &DenseMatrix,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    assert_eq!(u.rows, a.rows, "U rows mismatch");
    assert_eq!(v.rows, a.cols, "V rows mismatch");
    assert_eq!(u.cols, v.cols, "U/V width mismatch");
    assert_eq!(out.len(), a.nnz, "output length mismatch");
    if a.nnz == 0 {
        return;
    }
    let d = u.cols;
    let pool = &pool.for_work(a.nnz * d.max(1));
    let workers = pool.workers().min(a.num_segments).max(1);
    let per = a.num_segments.div_ceil(workers);
    let shared = SharedValues::new(out);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let shared = &shared;
            let seg_lo = w * per;
            let seg_hi = ((w + 1) * per).min(a.num_segments);
            scope.spawn(move || {
                if seg_lo >= seg_hi {
                    return;
                }
                let lo = seg_lo * a.seg_len;
                let hi = (seg_hi * a.seg_len).min(a.nnz);
                if lo >= hi {
                    return;
                }
                // SAFETY: workers own disjoint segment (hence nnz) ranges.
                let out = unsafe { shared.slice_mut(lo, hi) };
                for i in lo..hi {
                    let r = a.row_idx[i] as usize;
                    let c = a.col_idx[i] as usize;
                    out[i - lo] = a.values[i] * dot_pr(u.row(r), v.row(c));
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::sddmm_reference;
    use crate::kernels::WARP;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::proptest::run_prop;

    #[test]
    fn matches_reference_bitwise_property() {
        run_prop("sddmm pr_wb vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let d = *g.choose(&[1usize, 8, 32, 50]);
            let seg_len = *g.choose(&[2usize, 8, WARP]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let seg = SegmentedMatrix::from_csr(&a, seg_len);
            let u = DenseMatrix::from_vec(rows, d, g.vec_f32(rows * d));
            let v = DenseMatrix::from_vec(cols, d, g.vec_f32(cols * d));
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            let workers = *g.choose(&[1usize, 4, 7]);
            let mut got = vec![0f32; a.nnz()];
            sddmm(&seg, &u, &v, &mut got, &ThreadPool::new(workers));
            if got == want {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} d={d} seg_len={seg_len}"))
            }
        });
    }
}
