//! SR-WB SDDMM — sequential dot products over fixed-nnz segments.
//!
//! Workers own equal contiguous segment ranges of the non-zero stream
//! ([`crate::sparse::SegmentedMatrix`]), so every worker handles the same
//! number of sampled dot products regardless of row skew. SDDMM's
//! per-nnz cost is uniform (`d` multiply-adds each), so nnz-splitting
//! balances the op *exactly* — and since each non-zero owns its own
//! output slot, no cross-worker carries are needed (unlike SpMM's SR-WB).

use super::{dot_sr, SharedValues};
use crate::sparse::{DenseMatrix, SegmentedMatrix};
use crate::util::threadpool::ThreadPool;

/// SR-WB SDDMM over the segmented layout. `out.len()` must equal `a.nnz`
/// (padding slots past the true nnz are never touched).
pub fn sddmm(
    a: &SegmentedMatrix,
    u: &DenseMatrix,
    v: &DenseMatrix,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    assert_eq!(u.rows, a.rows, "U rows mismatch");
    assert_eq!(v.rows, a.cols, "V rows mismatch");
    assert_eq!(u.cols, v.cols, "U/V width mismatch");
    assert_eq!(out.len(), a.nnz, "output length mismatch");
    if a.nnz == 0 {
        return;
    }
    let d = u.cols;
    let pool = &pool.for_work(a.nnz * d.max(1));
    let workers = pool.workers().min(a.num_segments).max(1);
    let per = a.num_segments.div_ceil(workers);
    let shared = SharedValues::new(out);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let shared = &shared;
            let seg_lo = w * per;
            let seg_hi = ((w + 1) * per).min(a.num_segments);
            scope.spawn(move || {
                if seg_lo >= seg_hi {
                    return;
                }
                let lo = seg_lo * a.seg_len;
                // bound by the true nnz: padding slots have no output
                let hi = (seg_hi * a.seg_len).min(a.nnz);
                if lo >= hi {
                    return;
                }
                // SAFETY: workers own disjoint segment (hence nnz) ranges.
                let out = unsafe { shared.slice_mut(lo, hi) };
                for i in lo..hi {
                    let r = a.row_idx[i] as usize;
                    let c = a.col_idx[i] as usize;
                    out[i - lo] = a.values[i] * dot_sr(u.row(r), v.row(c));
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::sddmm_reference;
    use crate::kernels::WARP;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::proptest::run_prop;

    #[test]
    fn matches_reference_bitwise_property() {
        run_prop("sddmm sr_wb vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let d = *g.choose(&[0usize, 1, 4, 17, 32]);
            let seg_len = *g.choose(&[1usize, 4, WARP]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let seg = SegmentedMatrix::from_csr(&a, seg_len);
            let u = DenseMatrix::from_vec(rows, d, g.vec_f32(rows * d));
            let v = DenseMatrix::from_vec(cols, d, g.vec_f32(cols * d));
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            let workers = *g.choose(&[1usize, 3, 6]);
            let mut got = vec![0f32; a.nnz()];
            sddmm(&seg, &u, &v, &mut got, &ThreadPool::new(workers));
            if got == want {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} d={d} seg_len={seg_len}"))
            }
        });
    }

    #[test]
    fn skewed_stream_is_balanced_across_workers() {
        // one huge row: RS would serialize it, WB splits it mid-row
        let mut coo = CooMatrix::new(10, 64);
        for c in 0..64 {
            coo.push(3, c, 0.5 + c as f32);
        }
        let a = CsrMatrix::from_coo(&coo);
        let seg = SegmentedMatrix::from_csr(&a, 8);
        let mut rng = crate::util::prng::Xoshiro256::seeded(31);
        let u = DenseMatrix::random(10, 6, 1.0, &mut rng);
        let v = DenseMatrix::random(64, 6, 1.0, &mut rng);
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        let mut got = vec![0f32; a.nnz()];
        sddmm(&seg, &u, &v, &mut got, &ThreadPool::new(4));
        assert_eq!(got, want);
    }
}
