//! PR-RS SDDMM — lane-parallel dot products, row split.
//!
//! Same row partitioning as [`super::sr_rs`], but each sampled dot is
//! computed by a `WARP`-lane bundle: lanes multiply `U[r][j] · V[c][j]`
//! in parallel over `d`-windows ([`super::dot_lanes`], via the canonical
//! [`super::dot_pr`] — the CUDA kernel's vectorized load + multiply
//! stage), then merge. Pays off when `d` is
//! large enough to fill the lanes; short dots idle them — the SDDMM
//! analogue of the paper's short-row insight, with `d` in the role of
//! the reduction-axis length.

use super::{dot_pr, SharedValues, ROW_CHUNK};
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::threadpool::ThreadPool;

/// PR-RS SDDMM: row-split partitioning, lane-windowed dots. Bit-identical
/// to the dense reference (ordered lane merge; see `crate::sddmm` docs).
pub fn sddmm(a: &CsrMatrix, u: &DenseMatrix, v: &DenseMatrix, out: &mut [f32], pool: &ThreadPool) {
    assert_eq!(u.rows, a.rows, "U rows mismatch");
    assert_eq!(v.rows, a.cols, "V rows mismatch");
    assert_eq!(u.cols, v.cols, "U/V width mismatch");
    assert_eq!(out.len(), a.nnz(), "output length mismatch");
    if a.nnz() == 0 {
        return;
    }
    let d = u.cols;
    let pool = &pool.for_work(a.nnz() * d.max(1));
    let shared = SharedValues::new(out);
    pool.scope_chunks(a.rows, ROW_CHUNK, |rows| {
        let lo = a.indptr[rows.start] as usize;
        let hi = a.indptr[rows.end] as usize;
        if lo == hi {
            return;
        }
        // SAFETY: row blocks have disjoint nnz spans (indptr is monotone).
        let out = unsafe { shared.slice_mut(lo, hi) };
        for r in rows {
            let (cols, vals) = a.row(r);
            let base = a.indptr[r] as usize - lo;
            let urow = u.row(r);
            for k in 0..cols.len() {
                let vrow = v.row(cols[k] as usize);
                out[base + k] = vals[k] * dot_pr(urow, vrow);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::sddmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::proptest::run_prop;

    #[test]
    fn matches_reference_bitwise_property() {
        run_prop("sddmm pr_rs vs reference", 25, |g| {
            let rows = g.dim();
            let cols = g.dim();
            // window edges: below, at, and above WARP
            let d = *g.choose(&[1usize, 31, 32, 33, 80]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.25, g.rng());
            let a = CsrMatrix::from_coo(&coo);
            let u = DenseMatrix::from_vec(rows, d, g.vec_f32(rows * d));
            let v = DenseMatrix::from_vec(cols, d, g.vec_f32(cols * d));
            let mut want = vec![0f32; a.nnz()];
            sddmm_reference(&a, &u, &v, &mut want);
            let mut got = vec![0f32; a.nnz()];
            sddmm(&a, &u, &v, &mut got, &ThreadPool::new(2));
            if got == want {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} d={d}"))
            }
        });
    }
}
