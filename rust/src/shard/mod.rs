//! Sharded execution — the paper's adaptivity applied one grain up.
//!
//! The kernels balance work *within* one SpMM call (fixed-nnz segments
//! per warp); this subsystem balances work *across* calls: a matrix is
//! cut into K row-contiguous shards of near-equal non-zero count
//! ([`partition`], the 1D nnz-balanced layout that distributed-memory
//! SpMM work treats as the workhorse), each shard's own row-length
//! statistics are extracted ([`features`]), the Fig.-4 rules run per
//! shard, and a fan-out/gather executor ([`ShardedBackend`]) runs the
//! shards concurrently over any inner [`crate::backend::SpmmBackend`].
//!
//! The payoff mirrors DA-SpMM's observation that selection should track
//! input dynamics: a power-law matrix is not one regime but several, and
//! per-shard selection lets its hub-heavy head run a workload-balanced
//! kernel while its uniform tail runs row-split — within a single
//! request. Shard boundaries are row-aligned, so every output row is
//! produced by exactly one shard and the gather is a plain row-block
//! copy (no atomics, no reduction).
//!
//! Entry points: [`crate::coordinator::SpmmEngine::sharded`] for the full
//! coordinator stack, [`ShardedBackend`] directly, or — in the serving
//! composition — behind [`crate::backend::RoutedBackend`], which sends
//! only sufficiently large matrices down this path
//! ([`crate::coordinator::SpmmEngine::serving`]). See `DESIGN.md`
//! §Sharded execution for the partitioning/numerics contract and
//! `DESIGN.md` §Serving layer for the routing policy.

pub mod backend;
pub mod features;
pub mod partition;

pub use backend::ShardedBackend;
pub use features::ShardFeatures;
pub use partition::{PartitionConfig, RowPartition, ShardSpan, DEFAULT_MAX_IMBALANCE};
