//! nnz-balanced row partitioning — the 1D layout that scales SpMM out.
//!
//! A [`RowPartition`] cuts a CSR matrix into K row-contiguous shards whose
//! non-zero counts are as equal as row granularity permits. Because CSR's
//! `indptr` *is* the prefix sum of row lengths, each greedy cut is a
//! binary search for the row boundary nearest the ideal prefix
//! `i·nnz/K` — O(K log rows) total, free next to any SpMM.
//!
//! Row granularity bounds what balancing can achieve: a single huge row
//! cannot be split (rows are the unit the kernels consume), so
//! `max_shard_nnz ≤ ⌈nnz/K⌉ + max_row_nnz` is the guarantee, not perfect
//! K-way equality. [`RowPartition::balanced`] makes the residual skew
//! explicit: it shrinks K until the measured [`RowPartition::imbalance`]
//! fits the configured bound — fewer, fatter shards instead of a fan-out
//! whose wallclock one straggler shard dominates.

use crate::sparse::CsrMatrix;
use std::ops::Range;

/// Default imbalance bound: no shard may carry more than 2× the ideal
/// `nnz/K` share. Loose enough that realistic power-law matrices keep
/// their requested K; tight enough that a spike row collapses the fan-out
/// instead of wasting K−1 idle shards.
pub const DEFAULT_MAX_IMBALANCE: f64 = 2.0;

/// How to partition: requested shard count plus the imbalance bound
/// [`RowPartition::balanced`] enforces by shrinking K.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PartitionConfig {
    /// Requested shard count (clamped to `1..=rows`).
    pub shards: usize,
    /// Largest tolerated `max_shard_nnz / (nnz/K)`, at least 1.
    pub max_imbalance: f64,
}

impl PartitionConfig {
    /// Config with the default imbalance bound.
    pub fn new(shards: usize) -> Self {
        Self {
            shards,
            max_imbalance: DEFAULT_MAX_IMBALANCE,
        }
    }
}

/// One shard: a contiguous row range and its non-zero count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpan {
    pub rows: Range<usize>,
    pub nnz: usize,
}

/// A complete row partition: consecutive [`ShardSpan`]s covering
/// `0..rows` exactly once, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct RowPartition {
    spans: Vec<ShardSpan>,
    total_nnz: usize,
}

impl RowPartition {
    /// Greedy prefix-sum split into (up to) `k` shards. `k` is clamped to
    /// `1..=rows` so every shard holds at least one row (K > rows
    /// degenerates to one shard per row); an empty matrix yields a single
    /// empty shard.
    pub fn split(csr: &CsrMatrix, k: usize) -> RowPartition {
        Self::split_clamped(csr, k.clamp(1, csr.rows.max(1)))
    }

    /// Split honoring `cfg.max_imbalance`: retry with K−1 shards until the
    /// measured imbalance fits the bound (K = 1 always does — a single
    /// shard is perfectly "balanced").
    pub fn balanced(csr: &CsrMatrix, cfg: &PartitionConfig) -> RowPartition {
        let bound = cfg.max_imbalance.max(1.0);
        let mut k = cfg.shards.clamp(1, csr.rows.max(1));
        loop {
            let p = Self::split_clamped(csr, k);
            if k == 1 || p.imbalance() <= bound {
                return p;
            }
            k -= 1;
        }
    }

    fn split_clamped(csr: &CsrMatrix, k: usize) -> RowPartition {
        debug_assert!(k >= 1 && k <= csr.rows.max(1));
        let rows = csr.rows;
        let total = csr.indptr[rows] as u64;
        let mut cuts = Vec::with_capacity(k + 1);
        cuts.push(0usize);
        for i in 1..k {
            let ideal = (total * i as u64 / k as u64) as u32;
            // `indptr` is the row-length prefix sum: binary-search the two
            // row boundaries straddling the ideal cut and keep the nearer.
            let hi = csr.indptr.partition_point(|&p| p < ideal);
            let pick = if hi == 0 {
                0
            } else {
                let lo = hi - 1;
                if ideal - csr.indptr[lo] <= csr.indptr[hi] - ideal {
                    lo
                } else {
                    hi
                }
            };
            // Keep cuts strictly increasing and leave ≥1 row for each
            // remaining shard (safe: k ≤ rows).
            let prev = *cuts.last().unwrap();
            cuts.push(pick.clamp(prev + 1, rows - (k - i)));
        }
        cuts.push(rows);
        let spans = cuts
            .windows(2)
            .map(|w| ShardSpan {
                rows: w[0]..w[1],
                nnz: (csr.indptr[w[1]] - csr.indptr[w[0]]) as usize,
            })
            .collect();
        RowPartition {
            spans,
            total_nnz: total as usize,
        }
    }

    /// Shard count (≥ 1).
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// Never true — a partition always holds at least one span.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// The shards, in row order.
    pub fn spans(&self) -> &[ShardSpan] {
        &self.spans
    }

    /// Total non-zeros across all shards.
    pub fn total_nnz(&self) -> usize {
        self.total_nnz
    }

    /// Largest single-shard non-zero count.
    pub fn max_shard_nnz(&self) -> usize {
        self.spans.iter().map(|s| s.nnz).max().unwrap_or(0)
    }

    /// `max_shard_nnz / (nnz/K)` — 1.0 is perfect balance; 1.0 for an
    /// empty matrix.
    pub fn imbalance(&self) -> f64 {
        if self.total_nnz == 0 {
            return 1.0;
        }
        self.max_shard_nnz() as f64 * self.len() as f64 / self.total_nnz as f64
    }

    /// Re-measure this partition against (possibly delta-mutated) matrix
    /// content and re-cut only the degraded neighborhoods.
    ///
    /// Each span's nnz is re-read from the mutated `indptr`. A shard is
    /// **degraded** when its share exceeds `cfg.max_imbalance` times the
    /// ideal `nnz/K`. Degraded runs are widened by one donor shard on
    /// each side (an overloaded shard can only shed rows across its
    /// boundaries) and each window is re-split locally with the same
    /// shard count; every cut outside the windows is kept verbatim, so
    /// prepared per-shard state for balanced regions stays addressable
    /// by span. Cost is O(K) measurement plus O(window nnz) re-cutting —
    /// a churn stream that degrades one shard of a large partition pays
    /// for three shards, not the whole matrix.
    ///
    /// The matrix must keep the row count the partition was built for
    /// (deltas mutate edges, not dimensions).
    pub fn recut_degraded(&self, csr: &CsrMatrix, cfg: &PartitionConfig) -> RowPartition {
        assert_eq!(
            self.spans.last().map(|s| s.rows.end).unwrap_or(0),
            csr.rows,
            "partition row coverage must match the matrix"
        );
        let k = self.spans.len();
        let total = csr.nnz();
        let measured: Vec<ShardSpan> = self
            .spans
            .iter()
            .map(|s| ShardSpan {
                rows: s.rows.clone(),
                nnz: (csr.indptr[s.rows.end] - csr.indptr[s.rows.start]) as usize,
            })
            .collect();
        let bound = cfg.max_imbalance.max(1.0);
        let degraded: Vec<bool> = measured
            .iter()
            .map(|s| total > 0 && s.nnz as f64 * k as f64 / total as f64 > bound)
            .collect();
        if k == 1 || !degraded.iter().any(|&d| d) {
            return RowPartition {
                spans: measured,
                total_nnz: total,
            };
        }
        let mut window = vec![false; k];
        for i in 0..k {
            if degraded[i] {
                window[i] = true;
                if i > 0 {
                    window[i - 1] = true;
                }
                if i + 1 < k {
                    window[i + 1] = true;
                }
            }
        }
        let mut spans = Vec::with_capacity(k);
        let mut i = 0;
        while i < k {
            if !window[i] {
                spans.push(measured[i].clone());
                i += 1;
                continue;
            }
            let start = i;
            while i < k && window[i] {
                i += 1;
            }
            let rows = measured[start].rows.start..measured[i - 1].rows.end;
            let local = Self::split(&csr.row_slice(rows.clone()), i - start);
            for s in local.spans() {
                spans.push(ShardSpan {
                    rows: rows.start + s.rows.start..rows.start + s.rows.end,
                    nnz: s.nnz,
                });
            }
        }
        RowPartition {
            spans,
            total_nnz: total,
        }
    }

    /// One-line log summary.
    pub fn summary(&self) -> String {
        let nnzs: Vec<String> = self.spans.iter().map(|s| s.nnz.to_string()).collect();
        format!(
            "k={} nnz=[{}] imbalance={:.2}",
            self.len(),
            nnzs.join(","),
            self.imbalance()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::powerlaw::PowerLawConfig;
    use crate::gen::rmat::RmatConfig;
    use crate::sparse::CooMatrix;
    use crate::util::proptest::run_prop;

    /// Coverage invariants shared by every partition test: consecutive
    /// spans, full row coverage in order, per-span nnz consistent with
    /// `indptr`, non-empty spans whenever the matrix has rows.
    fn assert_covers(p: &RowPartition, csr: &CsrMatrix) -> Result<(), String> {
        let spans = p.spans();
        if spans.first().map(|s| s.rows.start) != Some(0) {
            return Err("first span does not start at row 0".into());
        }
        if spans.last().map(|s| s.rows.end) != Some(csr.rows) {
            return Err("last span does not end at the last row".into());
        }
        for w in spans.windows(2) {
            if w[0].rows.end != w[1].rows.start {
                return Err(format!("gap/overlap at {:?} -> {:?}", w[0].rows, w[1].rows));
            }
        }
        for s in spans {
            let want = (csr.indptr[s.rows.end] - csr.indptr[s.rows.start]) as usize;
            if s.nnz != want {
                return Err(format!("span {:?} nnz {} != {}", s.rows, s.nnz, want));
            }
            if csr.rows > 0 && s.rows.is_empty() {
                return Err(format!("empty span {:?}", s.rows));
            }
        }
        if spans.iter().map(|s| s.nnz).sum::<usize>() != p.total_nnz() {
            return Err("span nnz does not sum to total".into());
        }
        Ok(())
    }

    #[test]
    fn known_cuts_on_uniform_rows() {
        // 8 rows × 4 nnz: K=4 must cut exactly every 2 rows.
        let mut coo = CooMatrix::new(8, 16);
        for r in 0..8 {
            for c in 0..4 {
                coo.push(r, c * 3, 1.0);
            }
        }
        let csr = CsrMatrix::from_coo(&coo);
        let p = RowPartition::split(&csr, 4);
        let rows: Vec<Range<usize>> = p.spans().iter().map(|s| s.rows.clone()).collect();
        assert_eq!(rows, vec![0..2, 2..4, 4..6, 6..8]);
        assert!(p.spans().iter().all(|s| s.nnz == 8));
        assert!((p.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_shapes() {
        // empty matrix: one empty shard
        let empty = CsrMatrix::from_coo(&CooMatrix::new(0, 4));
        let p = RowPartition::split(&empty, 5);
        assert_eq!(p.len(), 1);
        assert_eq!(p.spans()[0], ShardSpan { rows: 0..0, nnz: 0 });
        assert_eq!(p.imbalance(), 1.0);
        // K > rows clamps to one row per shard
        let mut coo = CooMatrix::new(3, 3);
        coo.push(1, 1, 1.0);
        let csr = CsrMatrix::from_coo(&coo);
        let p = RowPartition::split(&csr, 10);
        assert_eq!(p.len(), 3);
        assert_covers(&p, &csr).unwrap();
        // all-empty rows still cover
        let hollow = CsrMatrix::from_coo(&CooMatrix::new(6, 6));
        let p = RowPartition::split(&hollow, 4);
        assert_eq!(p.len(), 4);
        assert_covers(&p, &hollow).unwrap();
        assert_eq!(p.total_nnz(), 0);
    }

    #[test]
    fn balanced_shrinks_k_under_a_spike() {
        // One row holds ~all nnz: no multi-shard split can balance, so
        // balanced() must fall back to fewer shards within the bound.
        let mut coo = CooMatrix::new(40, 600);
        for c in 0..600 {
            coo.push(20, c, 1.0);
        }
        for r in 0..40 {
            coo.push(r, r, 1.0);
        }
        let csr = CsrMatrix::from_coo(&coo);
        let raw = RowPartition::split(&csr, 8);
        assert!(raw.imbalance() > 2.0, "spike should defeat an 8-way split");
        let cfg = PartitionConfig {
            shards: 8,
            max_imbalance: 2.0,
        };
        let p = RowPartition::balanced(&csr, &cfg);
        assert!(p.len() < 8, "k should shrink, got {}", p.len());
        assert!(p.imbalance() <= 2.0, "imbalance {}", p.imbalance());
        assert_covers(&p, &csr).unwrap();
    }

    #[test]
    fn recut_degraded_moves_only_the_overloaded_neighborhood() {
        // 16 uniform rows (4 nnz each): K=4 cuts every 4 rows.
        let uniform = {
            let mut coo = CooMatrix::new(16, 20);
            for r in 0..16 {
                for c in 0..4 {
                    coo.push(r, c * 5, 1.0);
                }
            }
            CsrMatrix::from_coo(&coo)
        };
        let cfg = PartitionConfig::new(4);
        let p = RowPartition::balanced(&uniform, &cfg);
        assert_eq!(p.len(), 4);
        assert_eq!(p.spans()[2].rows, 8..12);

        // churn grows rows 8..12 to 16 nnz each: shard 2 now carries
        // 64 of 112 nnz (local imbalance 2.29 > 2.0)
        let mutated = {
            let mut coo = CooMatrix::new(16, 20);
            for r in 0..16 {
                let nnz = if (8..12).contains(&r) { 16 } else { 4 };
                for c in 0..nnz {
                    coo.push(r, c, 1.0);
                }
            }
            CsrMatrix::from_coo(&coo)
        };
        let recut = p.recut_degraded(&mutated, &cfg);
        assert_eq!(recut.len(), 4);
        assert_covers(&recut, &mutated).unwrap();
        // the balanced shard far from the overload keeps its cut verbatim
        assert_eq!(recut.spans()[0].rows, 0..4);
        assert_eq!(recut.spans()[0].nnz, 16);
        // the degraded neighborhood (shards 1..4) was re-split evenly
        assert_eq!(recut.spans()[1].rows, 4..9);
        assert_eq!(recut.spans()[2].rows, 9..11);
        assert_eq!(recut.spans()[3].rows, 11..16);
        assert!(recut.imbalance() <= cfg.max_imbalance, "{}", recut.summary());

        // value-only mutation degrades nothing: cuts are kept verbatim,
        // nnz re-measured (here: unchanged)
        let same = p.recut_degraded(&uniform, &cfg);
        assert_eq!(same.spans(), p.spans());
    }

    #[test]
    fn recut_degraded_covers_any_same_row_content_property() {
        run_prop("recut covers mutated content", 40, |g| {
            let rows = g.dim() * 4;
            let cols = g.dim() * 4;
            let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(
                rows,
                cols,
                g.f64_in(0.02, 0.3),
                g.rng(),
            ));
            // arbitrary same-row-count mutation target (worst case: the
            // content has nothing in common with what was partitioned)
            let b = CsrMatrix::from_coo(&CooMatrix::random_uniform(
                rows,
                cols.max(1),
                g.f64_in(0.02, 0.3),
                g.rng(),
            ));
            let cfg = PartitionConfig {
                shards: *g.choose(&[1usize, 2, 3, 5]),
                max_imbalance: *g.choose(&[1.2f64, 2.0, 4.0]),
            };
            let p = RowPartition::balanced(&a, &cfg);
            let recut = p.recut_degraded(&b, &cfg);
            assert_covers(&recut, &b)?;
            if recut.len() != p.len() {
                return Err(format!("shard count moved {} -> {}", p.len(), recut.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn coverage_and_bound_property() {
        run_prop("partition coverage + imbalance bound", 60, |g| {
            let csr = match g.usize_in(0, 3) {
                0 => {
                    let rows = g.dim() * 4;
                    let cols = g.dim() * 4;
                    let density = g.f64_in(0.01, 0.3);
                    CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, g.rng()))
                }
                1 => {
                    let scale = g.usize_in(4, 8) as u32;
                    CsrMatrix::from_coo(&RmatConfig::new(scale, 4.0).generate(g.rng()))
                }
                _ => {
                    let cfg = PowerLawConfig {
                        rows: g.dim() * 8,
                        cols: g.dim() * 8,
                        alpha: g.f64_in(1.5, 2.8),
                        min_row: 1,
                        max_row: g.dim() * 8,
                    };
                    CsrMatrix::from_coo(&cfg.generate(g.rng()))
                }
            };
            let k = *g.choose(&[1usize, 2, 3, 7, csr.rows + 1]);
            let p = RowPartition::split(&csr, k);
            assert_covers(&p, &csr)?;
            if p.len() != k.clamp(1, csr.rows.max(1)) {
                return Err(format!("k {} -> {} shards", k, p.len()));
            }
            // greedy guarantee: ideal share + one row of slack
            let max_row = (0..csr.rows).map(|r| csr.row_nnz(r)).max().unwrap_or(0);
            let bound = p.total_nnz() / p.len() + max_row + 1;
            if p.max_shard_nnz() > bound {
                return Err(format!(
                    "max shard {} exceeds {} ({})",
                    p.max_shard_nnz(),
                    bound,
                    p.summary()
                ));
            }
            // balanced() honors its configured bound
            let cfg = PartitionConfig {
                shards: k,
                max_imbalance: *g.choose(&[1.1f64, 1.5, 2.0, 4.0]),
            };
            let b = RowPartition::balanced(&csr, &cfg);
            assert_covers(&b, &csr)?;
            if b.len() > 1 && b.imbalance() > cfg.max_imbalance {
                return Err(format!(
                    "balanced imbalance {} > {} ({})",
                    b.imbalance(),
                    cfg.max_imbalance,
                    b.summary()
                ));
            }
            Ok(())
        });
    }
}
