//! Per-shard feature extraction — the paper's input-dynamics statistics
//! applied at the partition grain.
//!
//! The Fig.-4 selector reacts to row-length statistics of *whatever it is
//! about to execute on*. Globally those statistics blur: a power-law
//! matrix whose head rows are thousand-nnz hubs and whose tail is nearly
//! uniform averages out to "moderately skewed", and one kernel serves
//! both regimes badly. Extracting [`MatrixFeatures`] per [`ShardSpan`]
//! un-blurs them — the head shard sees its own high CV and long rows, the
//! tail shard its own short uniform rows, and each gets the kernel its
//! regime wants. Extraction reads the parent CSR's `indptr` directly
//! ([`MatrixFeatures::of_row_range`]), so the whole pass is O(rows).

use super::partition::{RowPartition, ShardSpan};
use crate::features::MatrixFeatures;
use crate::sparse::CsrMatrix;

/// One shard's span together with its locally-extracted features.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardFeatures {
    pub span: ShardSpan,
    pub features: MatrixFeatures,
}

/// Extract features for every shard of `partition`, in shard order.
pub fn extract(csr: &CsrMatrix, partition: &RowPartition) -> Vec<ShardFeatures> {
    partition
        .spans()
        .iter()
        .map(|span| ShardFeatures {
            span: span.clone(),
            features: MatrixFeatures::of_row_range(csr, span.rows.clone()),
        })
        .collect()
}

/// Test fixture shared across the shard/engine test suites: head shard of
/// 32 long rows (64 nnz each), tail shard of 1024 short rows (2 nnz each)
/// — equal nnz halves, so a 2-way nnz-balanced cut lands at (or within a
/// row or two of) the regime boundary at row 32.
#[cfg(test)]
pub(crate) fn two_regime_matrix() -> CsrMatrix {
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    let mut coo = CooMatrix::new(32 + 1024, 2048);
    for r in 0..32 {
        for c in 0..64 {
            coo.push(r, c * 16, 1.0);
        }
    }
    let mut rng = Xoshiro256::seeded(91);
    for r in 0..1024 {
        for _ in 0..2 {
            coo.push(32 + r, rng.below(2048) as usize, 1.0);
        }
    }
    CsrMatrix::from_coo(&coo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::selector::AdaptiveSelector;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn per_shard_features_see_local_regimes() {
        let csr = two_regime_matrix();
        let p = RowPartition::split(&csr, 2);
        // the nnz-balanced cut lands at (or within a row or two of) the
        // regime boundary at row 32
        let cut = p.spans()[0].rows.end;
        assert!((30..=34).contains(&cut), "cut {cut} ({})", p.summary());
        let feats = extract(&csr, &p);
        assert_eq!(feats.len(), 2);
        assert!(
            feats[0].features.avg_row > 12.0,
            "head avg {}",
            feats[0].features.avg_row
        );
        assert!(
            feats[1].features.avg_row < 3.0,
            "tail avg {}",
            feats[1].features.avg_row
        );
        // global features blur the two regimes into one middling average
        let global = MatrixFeatures::of(&csr);
        assert!(global.avg_row < feats[0].features.avg_row);
        assert!(global.avg_row > feats[1].features.avg_row);
    }

    #[test]
    fn selection_diverges_across_shards() {
        let csr = two_regime_matrix();
        let p = RowPartition::split(&csr, 2);
        let feats: Vec<MatrixFeatures> =
            extract(&csr, &p).iter().map(|sf| sf.features).collect();
        let sel = AdaptiveSelector::default();
        // SpMV regime (N ≤ 4): long head rows -> PR-RS, short tail -> PR-WB
        assert_eq!(
            sel.select_shards(&feats, 1),
            vec![KernelKind::PrRs, KernelKind::PrWb]
        );
    }

    #[test]
    fn extract_matches_standalone_slices() {
        let mut rng = Xoshiro256::seeded(92);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(200, 150, 0.05, &mut rng));
        let p = RowPartition::split(&csr, 3);
        for sf in extract(&csr, &p) {
            let sub = csr.row_slice(sf.span.rows.clone());
            assert_eq!(sf.features, MatrixFeatures::of(&sub));
            assert_eq!(sf.features.nnz, sf.span.nnz);
        }
    }
}
