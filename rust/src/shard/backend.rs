//! `ShardedBackend` — fan-out/gather SpMM execution over a row partition.
//!
//! Implements [`SpmmBackend`] by delegation: `prepare` splits the matrix
//! with [`RowPartition::balanced`], extracts per-shard features, and
//! prepares each row slice through a shared inner backend
//! ([`NativeBackend`] by default — any `Box<dyn SpmmBackend>` works);
//! `execute` runs the shards concurrently and reassembles their outputs,
//! which are disjoint contiguous row blocks of `Y`, so the gather is a
//! copy with no reduction step.
//!
//! Kernel choice has three modes:
//!
//! - **fixed** (default): every shard runs the caller's `KernelKind` —
//!   what ablations and cross-backend agreement tests need;
//! - **adaptive** ([`ShardedBackend::adaptive`]): each shard re-runs the
//!   Fig.-4 rules on its *own* features, so a skewed head shard and a
//!   uniform tail shard of one matrix execute different kernels in the
//!   same request. The caller's kernel becomes a hint that per-shard
//!   dynamics override; the actual choices are observable through the
//!   [`Metrics`] shard counters;
//! - **online** ([`ShardedBackend::online`]): like adaptive, but the
//!   thresholds come from a shared
//!   [`OnlineSelector`](crate::selector::OnlineSelector), every shard's
//!   wallclock is reported back to it, and its periodic refits shift
//!   later per-shard choices (`DESIGN.md` §Measured calibration).

use super::features::{self, ShardFeatures};
use super::partition::{PartitionConfig, RowPartition};
use crate::backend::{
    execute_sddmm_traced, execute_sddmm_variant_traced, execute_traced, execute_variant_traced,
    Execution, NativeBackend, PreparedOperand, SddmmExecution, SpmmBackend,
};
use crate::coordinator::metrics::Metrics;
use crate::kernels::{registry, KernelKind, SparseOp, VariantEntry};
use crate::obs::{trace, workload, AuditEntry};
use crate::selector::{AdaptiveSelector, Decision, SddmmSelector};
use crate::sparse::{CsrMatrix, DenseMatrix};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One prepared shard: its span + features, a content fingerprint of the
/// row slice (so structural deltas can prove a shard untouched), and the
/// inner backend's prepared operand — `Arc`-shared so an untouched shard
/// carries over to a re-cut partition without copying.
struct PreparedShard {
    features: ShardFeatures,
    fingerprint: u64,
    operand: Arc<PreparedOperand>,
}

/// The sharded backend's prepared state for one registered matrix: the
/// shards plus the partition they were cut from (the input
/// [`RowPartition::recut_degraded`] needs on a structural delta).
struct ShardedPrepared {
    shards: Vec<PreparedShard>,
    partition: RowPartition,
}

/// FNV-1a over a row slice's full content (shape, pattern, values).
///
/// [`CsrMatrix::fingerprint`] is deliberately epoch-rotated (two prepares
/// of identical content must not alias in the engine's cache), so shard
/// reuse needs its own *content* hash: equal slices hash equal, which is
/// exactly what proves a prepared shard operand still valid after a
/// structural delta elsewhere in the matrix.
fn shard_fingerprint(sub: &CsrMatrix) -> u64 {
    fn eat(h: &mut u64, word: u64) {
        for byte in word.to_le_bytes() {
            *h ^= u64::from(byte);
            *h = h.wrapping_mul(0x100000001b3);
        }
    }
    let mut h: u64 = 0xcbf29ce484222325;
    eat(&mut h, sub.rows as u64);
    eat(&mut h, sub.cols as u64);
    for r in 0..sub.rows {
        let (cols, vals) = sub.row(r);
        eat(&mut h, cols.len() as u64);
        for &c in cols {
            eat(&mut h, u64::from(c));
        }
        for &v in vals {
            eat(&mut h, u64::from(v.to_bits()));
        }
    }
    h
}

/// Per-shard kernel-choice policy (see the module docs).
enum ShardSelection {
    /// Every shard runs the caller's kernel.
    Fixed,
    /// Per-shard Fig.-4 rules with fixed thresholds.
    Static(AdaptiveSelector),
    /// Per-shard rules from a shared online-refined selector; shard
    /// wallclocks feed back into it.
    Online(Arc<crate::selector::OnlineSelector>),
}

/// Row-sharded execution backend over any inner [`SpmmBackend`].
pub struct ShardedBackend {
    inner: Box<dyn SpmmBackend>,
    config: PartitionConfig,
    selection: ShardSelection,
    /// Per-shard SDDMM rules, consulted in `Static` selection mode (the
    /// `Online` mode asks the shared selector, `Fixed` the caller).
    sddmm_selector: SddmmSelector,
    metrics: Arc<Metrics>,
}

impl ShardedBackend {
    /// Sharded execution over a full-parallelism [`NativeBackend`],
    /// fixed-kernel mode, default imbalance bound.
    ///
    /// The inner pool is deliberately *not* divided by K: the partition
    /// can shrink below the requested K per matrix (imbalance bound,
    /// K > rows), and a statically divided pool would then strand most
    /// of the machine — a collapsed single-shard partition on a
    /// `cores/K`-sized pool runs K× slower than plain native. With the
    /// full pool a collapsed partition degrades to exactly native
    /// performance, while high fan-out costs only transient scheduler
    /// oversubscription (pool threads are scoped per kernel call, and
    /// `ThreadPool::for_work` keeps small shards serial anyway).
    pub fn new(shards: usize) -> Self {
        Self::over(Box::new(NativeBackend::default()), shards)
    }

    /// Sharded execution over an explicit inner backend.
    pub fn over(inner: Box<dyn SpmmBackend>, shards: usize) -> Self {
        Self {
            inner,
            config: PartitionConfig::new(shards),
            selection: ShardSelection::Fixed,
            sddmm_selector: SddmmSelector::default(),
            metrics: Arc::new(Metrics::default()),
        }
    }

    /// Enable per-shard adaptive selection with the given rule thresholds.
    pub fn adaptive(mut self, selector: AdaptiveSelector) -> Self {
        self.selection = ShardSelection::Static(selector);
        self
    }

    /// Enable per-shard adaptive selection driven by a shared
    /// [`OnlineSelector`](crate::selector::OnlineSelector): each shard's
    /// choice comes from the selector's current thresholds (plus its
    /// exploration budget), and each shard's wallclock is reported back,
    /// so refits shift later choices under live traffic.
    pub fn online(mut self, selector: Arc<crate::selector::OnlineSelector>) -> Self {
        self.selection = ShardSelection::Online(selector);
        self
    }

    /// Override the partition imbalance bound (see
    /// [`RowPartition::balanced`]).
    pub fn with_max_imbalance(mut self, bound: f64) -> Self {
        self.config.max_imbalance = bound;
        self
    }

    /// Record shard executions into a shared metrics instance (the engine
    /// passes its own so request- and shard-level counters land together).
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = metrics;
        self
    }

    /// The metrics instance shard executions are recorded into.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The partition configuration in effect.
    pub fn config(&self) -> PartitionConfig {
        self.config
    }

    /// The per-shard selector thresholds, if adaptive or online mode is
    /// on (online mode reports its current snapshot).
    pub fn selector(&self) -> Option<AdaptiveSelector> {
        match &self.selection {
            ShardSelection::Fixed => None,
            ShardSelection::Static(s) => Some(*s),
            ShardSelection::Online(o) => Some(o.current()),
        }
    }

    /// Override the per-shard SDDMM rule thresholds (used in `Static`
    /// selection mode; `Fixed` mode follows the caller's kernel and
    /// `Online` mode asks the shared selector).
    pub fn with_sddmm_selector(mut self, selector: SddmmSelector) -> Self {
        self.sddmm_selector = selector;
        self
    }

    /// The per-shard SDDMM rule thresholds in effect for `Static` mode.
    pub fn sddmm_selector(&self) -> SddmmSelector {
        self.sddmm_selector
    }

    /// Record one shard-grain selector decision into the audit log and
    /// return the chosen kernel (`Fixed` mode makes no decision and is
    /// not audited here — the request grain already covers it).
    #[allow(clippy::too_many_arguments)]
    fn audit_shard(
        &self,
        op: SparseOp,
        shard: usize,
        selector: &'static str,
        s: &PreparedShard,
        n: usize,
        decision: Decision,
        variant: Option<&'static str>,
        explored: bool,
    ) -> KernelKind {
        let kernel = decision.kernel;
        self.metrics.audit().push(AuditEntry {
            seq: 0,
            op,
            grain: "shard",
            shard: Some(shard),
            selector,
            matrix: None,
            features: s.features.features,
            n,
            thresholds: decision.thresholds,
            rule: decision.rule,
            kernel,
            variant,
            explored,
            realized_cost: None,
        });
        kernel
    }

    /// Record one batch's nnz imbalance (heaviest shard vs. the mean)
    /// before a fan-out — the paper's workload-balancing quality as a
    /// measured distribution.
    fn record_imbalance(&self, shards: &[PreparedShard]) {
        let max_nnz = shards.iter().map(|s| s.features.features.nnz as u64).max().unwrap_or(0);
        let total: u64 = shards.iter().map(|s| s.features.features.nnz as u64).sum();
        self.metrics.record_shard_imbalance(max_nnz, total, shards.len() as u64);
    }

    /// Record one shard execution's analytic workload under the variant
    /// that actually ran (the family's canonical variant when no
    /// generated entry was resolved), sized by the shard's own features.
    fn record_shard_workload(
        &self,
        op: SparseOp,
        kernel: KernelKind,
        entry: Option<&'static VariantEntry>,
        shard: &PreparedShard,
        width: usize,
        took: Duration,
    ) {
        let ran = entry.unwrap_or_else(|| registry().canonical(op, kernel));
        let f = &shard.features.features;
        let est = workload::estimate(&ran.variant, f.rows, f.nnz, width);
        self.metrics.record_workload(ran.id, &est, took);
    }
}

impl SpmmBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn prepare(&self, csr: &CsrMatrix) -> Result<PreparedOperand> {
        let partition = RowPartition::balanced(csr, &self.config);
        let mut shards = Vec::with_capacity(partition.len());
        for sf in features::extract(csr, &partition) {
            let sub = csr.row_slice(sf.span.rows.clone());
            let fingerprint = shard_fingerprint(&sub);
            let operand = self
                .inner
                .prepare(&sub)
                .with_context(|| format!("preparing shard rows {:?}", sf.span.rows))?;
            shards.push(PreparedShard {
                features: sf,
                fingerprint,
                operand: Arc::new(operand),
            });
        }
        Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(ShardedPrepared { shards, partition }),
        ))
    }

    fn prepare_delta(
        &self,
        prev: &PreparedOperand,
        csr: &CsrMatrix,
        structural: bool,
    ) -> Option<Result<PreparedOperand>> {
        let prep: &ShardedPrepared = match prev.state() {
            Ok(p) => p,
            Err(e) => return Some(Err(e)),
        };
        // Structural batches: moved non-zeros may shift the nnz-balanced
        // cuts, but `RowPartition::recut_degraded` bounds the re-cut to
        // the overloaded neighborhoods — every span whose rows *and*
        // content survived verbatim keeps its prepared operand (the Arc
        // carries over), and only touched or re-cut spans re-prepare.
        if structural {
            if prev.rows() != csr.rows || prev.cols() != csr.cols {
                // deltas mutate edges, not dimensions: a shape change is
                // a different matrix — decline so the caller re-prepares
                return None;
            }
            let partition = prep.partition.recut_degraded(csr, &self.config);
            let old: HashMap<(usize, usize), &PreparedShard> = prep
                .shards
                .iter()
                .map(|s| ((s.features.span.rows.start, s.features.span.rows.end), s))
                .collect();
            let (mut reused, mut reprepared) = (0u64, 0u64);
            let mut shards = Vec::with_capacity(partition.len());
            for sf in features::extract(csr, &partition) {
                let sub = csr.row_slice(sf.span.rows.clone());
                let fingerprint = shard_fingerprint(&sub);
                let prior = old
                    .get(&(sf.span.rows.start, sf.span.rows.end))
                    .filter(|s| s.fingerprint == fingerprint);
                let operand = match prior {
                    Some(s) => {
                        reused += 1;
                        s.operand.clone()
                    }
                    None => {
                        reprepared += 1;
                        match self.inner.prepare(&sub).with_context(|| {
                            format!("re-preparing shard rows {:?}", sf.span.rows)
                        }) {
                            Ok(op) => Arc::new(op),
                            Err(e) => return Some(Err(e)),
                        }
                    }
                };
                shards.push(PreparedShard {
                    features: sf,
                    fingerprint,
                    operand,
                });
            }
            self.metrics.record_shard_reuse(reused, reprepared);
            return Some(Ok(PreparedOperand::new(
                csr.rows,
                csr.cols,
                csr.nnz(),
                Box::new(ShardedPrepared { shards, partition }),
            )));
        }
        if prev.rows() != csr.rows || prev.cols() != csr.cols || prev.nnz() != csr.nnz() {
            return Some(Err(anyhow::anyhow!(
                "value-only delta changed the matrix shape: prepared {}x{} nnz {}, got {}x{} nnz {}",
                prev.rows(),
                prev.cols(),
                prev.nnz(),
                csr.rows,
                csr.cols,
                csr.nnz()
            )));
        }
        // Value-only: the partition depends only on the (unchanged) row
        // lengths, so every span, every shard feature and every segment
        // cut carries over — each shard just patches its value stream
        // through the inner backend.
        let mut shards = Vec::with_capacity(prep.shards.len());
        for shard in &prep.shards {
            let sub = csr.row_slice(shard.features.span.rows.clone());
            let operand = match self.inner.prepare_delta(&shard.operand, &sub, false)? {
                Ok(op) => op,
                Err(e) => return Some(Err(e)),
            };
            shards.push(PreparedShard {
                features: shard.features.clone(),
                fingerprint: shard_fingerprint(&sub),
                operand: Arc::new(operand),
            });
        }
        Some(Ok(PreparedOperand::new(
            csr.rows,
            csr.cols,
            csr.nnz(),
            Box::new(ShardedPrepared {
                shards,
                partition: prep.partition.clone(),
            }),
        )))
    }

    fn execute(
        &self,
        operand: &PreparedOperand,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<Execution> {
        let prep: &ShardedPrepared = operand.state()?;
        operand.check_operand(x)?;
        let n = x.cols;
        // Per-shard choice: the family kernel plus, in online mode, the
        // concrete generated variant (the selector's learned per-bucket
        // preference, or an exploration sibling).
        let choices: Vec<(KernelKind, Option<&'static VariantEntry>)> = match &self.selection {
            ShardSelection::Static(sel) => prep
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let decision = sel.decide(&s.features.features, n);
                    let k =
                        self.audit_shard(SparseOp::Spmm, i, "adaptive", s, n, decision, None, false);
                    (k, None)
                })
                .collect(),
            ShardSelection::Online(sel) => prep
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let (decision, entry, explored) = sel.decide_variant(&s.features.features, n);
                    let k = self.audit_shard(
                        SparseOp::Spmm,
                        i,
                        "online",
                        s,
                        n,
                        decision,
                        Some(entry.label),
                        explored,
                    );
                    (k, Some(entry))
                })
                .collect(),
            ShardSelection::Fixed => vec![(kernel, None); prep.shards.len()],
        };
        // Fan out: one scoped thread per shard (K is small), all sharing
        // the inner backend; each reports its own wallclock so stragglers
        // are visible in the shard metrics.
        let inner = self.inner.as_ref();
        self.record_imbalance(&prep.shards);
        let mut fan = trace::span("fanout");
        fan.set_attr("shards", prep.shards.len());
        let handle = trace::handle();
        let results: Vec<Result<(Execution, Duration)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = prep
                .shards
                .iter()
                .zip(&choices)
                .enumerate()
                .map(|(i, (shard, &(k, entry)))| {
                    let th = handle.clone();
                    scope.spawn(move || -> Result<(Execution, Duration)> {
                        let _trace = th.as_ref().map(trace::attach);
                        let mut sp = trace::span("shard");
                        sp.set_attr("shard", i);
                        sp.set_attr("kernel", k.label());
                        if let Some(e) = entry {
                            sp.set_attr("variant", e.label);
                        }
                        sp.set_attr("rows", format!("{:?}", shard.features.span.rows));
                        let t0 = Instant::now();
                        let exec = match entry {
                            Some(e) => execute_variant_traced(inner, &shard.operand, x, e)?,
                            None => execute_traced(inner, &shard.operand, x, k)?,
                        };
                        Ok((exec, t0.elapsed()))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard thread panicked"))
                .collect()
        });
        fan.end();
        // Gather: shard i produced rows `span.rows` of Y, a contiguous
        // row-major block — reassembly is a straight copy.
        let mut y = DenseMatrix::zeros(operand.rows(), n);
        let mut labels = Vec::with_capacity(prep.shards.len());
        for (i, ((shard, &(k, entry)), res)) in
            prep.shards.iter().zip(&choices).zip(results).enumerate()
        {
            let (exec, took) = res.with_context(|| {
                format!("shard {i} (rows {:?})", shard.features.span.rows)
            })?;
            let lo = shard.features.span.rows.start * n;
            y.data[lo..lo + exec.y.data.len()].copy_from_slice(&exec.y.data);
            match entry {
                Some(e) => {
                    self.metrics.record_shard_variant(e.id, took);
                }
                None => self.metrics.record_shard(k, took),
            }
            self.record_shard_workload(SparseOp::Spmm, k, entry, shard, n, took);
            if let (ShardSelection::Online(sel), Some(e)) = (&self.selection, entry) {
                sel.observe_variant(&shard.features.features, n, e, took);
            }
            labels.push(exec.artifact);
        }
        Ok(Execution {
            y,
            artifact: format!("sharded(k={})[{}]", prep.shards.len(), labels.join("+")),
        })
    }

    fn execute_sddmm(
        &self,
        operand: &PreparedOperand,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmExecution> {
        let prep: &ShardedPrepared = operand.state()?;
        operand.check_sddmm_operands(u, v)?;
        let d = u.cols;
        let choices: Vec<(KernelKind, Option<&'static VariantEntry>)> = match &self.selection {
            ShardSelection::Static(_) => prep
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let decision = self.sddmm_selector.decide(&s.features.features, d);
                    let k =
                        self.audit_shard(SparseOp::Sddmm, i, "sddmm", s, d, decision, None, false);
                    (k, None)
                })
                .collect(),
            ShardSelection::Online(sel) => prep
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let (decision, entry, explored) =
                        sel.decide_sddmm_variant(&s.features.features, d);
                    let k = self.audit_shard(
                        SparseOp::Sddmm,
                        i,
                        "online-sddmm",
                        s,
                        d,
                        decision,
                        Some(entry.label),
                        explored,
                    );
                    (k, Some(entry))
                })
                .collect(),
            ShardSelection::Fixed => vec![(kernel, None); prep.shards.len()],
        };
        // Fan out: shard i owns the rows of its span, whose U block is the
        // matching contiguous row slice; V is shared whole. Shard outputs
        // are disjoint contiguous nnz ranges of the stream (row slices
        // preserve stream order), so the gather is a straight copy.
        let inner = self.inner.as_ref();
        self.record_imbalance(&prep.shards);
        let mut fan = trace::span("fanout");
        fan.set_attr("shards", prep.shards.len());
        fan.set_attr("op", SparseOp::Sddmm.label());
        let handle = trace::handle();
        let results: Vec<Result<(SddmmExecution, Duration)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = prep
                .shards
                .iter()
                .zip(&choices)
                .enumerate()
                .map(|(i, (shard, &(k, entry)))| {
                    let rows = shard.features.span.rows.clone();
                    let usub = DenseMatrix::from_vec(
                        rows.end - rows.start,
                        d,
                        u.data[rows.start * d..rows.end * d].to_vec(),
                    );
                    let th = handle.clone();
                    scope.spawn(move || -> Result<(SddmmExecution, Duration)> {
                        let _trace = th.as_ref().map(trace::attach);
                        let mut sp = trace::span("shard");
                        sp.set_attr("shard", i);
                        sp.set_attr("kernel", k.label());
                        if let Some(e) = entry {
                            sp.set_attr("variant", e.label);
                        }
                        sp.set_attr("rows", format!("{:?}", shard.features.span.rows));
                        let t0 = Instant::now();
                        let exec = match entry {
                            Some(e) => {
                                execute_sddmm_variant_traced(inner, &shard.operand, &usub, v, e)?
                            }
                            None => execute_sddmm_traced(inner, &shard.operand, &usub, v, k)?,
                        };
                        Ok((exec, t0.elapsed()))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("sddmm shard thread panicked"))
                .collect()
        });
        fan.end();
        let mut values = vec![0f32; operand.nnz()];
        let mut labels = Vec::with_capacity(prep.shards.len());
        let mut off = 0usize;
        for (i, ((shard, &(k, entry)), res)) in
            prep.shards.iter().zip(&choices).zip(results).enumerate()
        {
            let (exec, took) = res.with_context(|| {
                format!("sddmm shard {i} (rows {:?})", shard.features.span.rows)
            })?;
            values[off..off + exec.values.len()].copy_from_slice(&exec.values);
            off += exec.values.len();
            match entry {
                Some(e) => {
                    self.metrics.record_shard_variant(e.id, took);
                }
                None => self.metrics.record_sddmm_shard(k, took),
            }
            self.record_shard_workload(SparseOp::Sddmm, k, entry, shard, d, took);
            if let (ShardSelection::Online(sel), Some(e)) = (&self.selection, entry) {
                sel.observe_variant(&shard.features.features, d, e, took);
            }
            labels.push(exec.artifact);
        }
        Ok(SddmmExecution {
            values,
            artifact: format!(
                "sharded(k={})/sddmm[{}]",
                prep.shards.len(),
                labels.join("+")
            ),
        })
    }

    fn available_n(&self) -> Option<Vec<usize>> {
        self.inner.available_n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::assert_close;

    #[test]
    fn fixed_mode_matches_reference_for_all_kernels() {
        let mut rng = Xoshiro256::seeded(401);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 90, 0.08, &mut rng));
        let backend = ShardedBackend::new(3);
        let op = backend.prepare(&csr).unwrap();
        assert_eq!((op.rows(), op.cols(), op.nnz()), (120, 90, csr.nnz()));
        let x = DenseMatrix::random(90, 6, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(120, 6);
        spmm_reference(&csr, &x, &mut want);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert!(exec.artifact.starts_with("sharded(k=3)["), "{}", exec.artifact);
            assert!(exec.artifact.contains(kind.label()), "{}", exec.artifact);
            assert_close(&exec.y.data, &want.data, 1e-5, 1e-5).unwrap();
        }
        assert_eq!(backend.metrics().shard_executions(), 4 * 3);
    }

    #[test]
    fn adaptive_mode_diverges_per_shard_and_records() {
        // Two-regime fixture: K=2 cuts between the long-row head and the
        // short-row tail; at N=1 the head picks PR-RS and the tail PR-WB.
        let csr = features::two_regime_matrix();
        let mut rng = Xoshiro256::seeded(402);
        let backend = ShardedBackend::new(2).adaptive(AdaptiveSelector::default());
        let op = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::random(2048, 1, 1.0, &mut rng);
        // the caller's kernel is only a hint in adaptive mode
        let exec = backend.execute(&op, &x, KernelKind::SrRs).unwrap();
        let mut want = DenseMatrix::zeros(csr.rows, 1);
        spmm_reference(&csr, &x, &mut want);
        assert_close(&exec.y.data, &want.data, 1e-4, 1e-4).unwrap();
        let counts = backend.metrics().shard_kernel_counts();
        assert_eq!(counts, [0, 0, 1, 1], "sr_rs/sr_wb/pr_rs/pr_wb: {counts:?}");
        assert!(exec.artifact.contains("pr_rs") && exec.artifact.contains("pr_wb"));
    }

    /// Interleaved moderate skew: every 12th row is long, so a 2-way
    /// nnz-balanced cut gives both shards cv_row ≈ 1.4 — below the
    /// default `T_cv = 1.5` (rule says SR-RS at N = 32) but above the
    /// refit grid's smaller candidates, i.e. a workload whose choice a
    /// threshold refit *can* flip.
    fn moderately_skewed_matrix() -> CsrMatrix {
        let mut coo = CooMatrix::new(96, 256);
        for r in 0..96 {
            if r % 12 == 0 {
                for c in 0..20 {
                    coo.push(r, (r + 7 * c) % 256, 1.0);
                }
            } else {
                coo.push(r, r % 256, 1.0);
                coo.push(r, (r + 101) % 256, 1.0);
            }
        }
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn online_mode_selects_observes_and_shifts() {
        use crate::selector::{OnlineConfig, OnlineSelector};
        use std::time::Duration;
        let metrics = Arc::new(Metrics::default());
        let online = Arc::new(OnlineSelector::new(
            AdaptiveSelector::default(),
            metrics.clone(),
            OnlineConfig {
                explore_every: 0, // deterministic choices for this test
                refit_every: 0,   // refit explicitly below
                min_observations: 2,
            },
        ));
        let backend = ShardedBackend::new(2).online(online.clone()).with_metrics(metrics.clone());
        assert_eq!(backend.selector(), Some(AdaptiveSelector::default()));

        let csr = moderately_skewed_matrix();
        // pin the fixture's premise: both shards sit in the flippable
        // cv band, and the default rule picks SR-RS for them at N=32
        let partition = RowPartition::balanced(&csr, &backend.config());
        let shard_feats = features::extract(&csr, &partition);
        assert_eq!(shard_feats.len(), 2);
        for sf in &shard_feats {
            assert!(
                sf.features.cv_row > 1.05 && sf.features.cv_row < 1.5,
                "shard cv {}",
                sf.features.cv_row
            );
            assert_eq!(
                AdaptiveSelector::default().select(&sf.features, 32),
                KernelKind::SrRs
            );
        }

        let op = backend.prepare(&csr).unwrap();
        let mut rng = Xoshiro256::seeded(405);
        let x = DenseMatrix::random(256, 32, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(csr.rows, 32);
        spmm_reference(&csr, &x, &mut want);

        // Baseline request: both shards run the rule choice SR-RS, and
        // each shard execution also lands in the online selector.
        let exec = backend.execute(&op, &x, KernelKind::PrRs).unwrap();
        assert_close(&exec.y.data, &want.data, 1e-4, 1e-4).unwrap();
        assert_eq!(metrics.shard_kernel_counts(), [2, 0, 0, 0]);
        assert_eq!(online.observations(), 2);
        assert!(metrics.total_cost_observations() >= 2);

        // Teach the selector that SR-WB is far cheaper on this bucket
        // (as it would be on hardware where this much skew already
        // starves row-split), then refit: T_cv drops and the per-shard
        // choices flip to SR-WB on the very next request.
        let sf = shard_feats[0].features;
        for _ in 0..6 {
            online.observe(&sf, 32, KernelKind::SrRs, Duration::from_millis(5));
            online.observe(&sf, 32, KernelKind::SrWb, Duration::from_micros(50));
        }
        assert!(online.refit(), "evidence moves T_cv");
        assert!(online.current().t_cv <= 1.0, "{:?}", online.current());
        let exec = backend.execute(&op, &x, KernelKind::PrRs).unwrap();
        assert_close(&exec.y.data, &want.data, 1e-4, 1e-4).unwrap();
        assert_eq!(
            metrics.shard_kernel_counts(),
            [2, 2, 0, 0],
            "both shards now pick SR-WB"
        );
    }

    #[test]
    fn sddmm_fixed_mode_is_bit_identical_to_reference() {
        use crate::kernels::dense::sddmm_reference;
        let mut rng = Xoshiro256::seeded(407);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(110, 80, 0.07, &mut rng));
        let backend = ShardedBackend::new(3);
        let op = backend.prepare(&csr).unwrap();
        let d = 9;
        let u = DenseMatrix::random(110, d, 1.0, &mut rng);
        let v = DenseMatrix::random(80, d, 1.0, &mut rng);
        let mut want = vec![0f32; csr.nnz()];
        sddmm_reference(&csr, &u, &v, &mut want);
        for kind in KernelKind::ALL {
            let exec = backend.execute_sddmm(&op, &u, &v, kind).unwrap();
            assert!(
                exec.artifact.starts_with("sharded(k=3)/sddmm["),
                "{}",
                exec.artifact
            );
            assert!(exec.artifact.contains(kind.label()), "{}", exec.artifact);
            assert_eq!(exec.values, want, "{kind:?}");
        }
        assert_eq!(backend.metrics().sddmm_shard_executions(), 4 * 3);
        // SpMM shard counters stay untouched: the ops are tagged apart
        assert_eq!(backend.metrics().shard_executions(), 0);
    }

    #[test]
    fn sddmm_adaptive_mode_selects_per_shard_by_d_and_skew() {
        use crate::kernels::dense::sddmm_reference;
        let csr = moderately_skewed_matrix();
        let backend = ShardedBackend::new(2).adaptive(AdaptiveSelector::default());
        // pin the premise: both shards sit above the SDDMM balance
        // threshold (0.5) — their per-nnz cost is uniform, so skew alone
        // decides WB
        let partition = RowPartition::balanced(&csr, &backend.config());
        for sf in features::extract(&csr, &partition) {
            assert!(sf.features.cv_row > 0.5, "shard cv {}", sf.features.cv_row);
        }
        let op = backend.prepare(&csr).unwrap();
        let mut rng = Xoshiro256::seeded(408);
        // d below the lane threshold → sequential dots, balanced: SR-WB
        let d_small = 8;
        let u = DenseMatrix::random(csr.rows, d_small, 1.0, &mut rng);
        let v = DenseMatrix::random(csr.cols, d_small, 1.0, &mut rng);
        let mut want = vec![0f32; csr.nnz()];
        sddmm_reference(&csr, &u, &v, &mut want);
        let exec = backend.execute_sddmm(&op, &u, &v, KernelKind::PrRs).unwrap();
        assert_eq!(exec.values, want);
        assert_eq!(backend.metrics().sddmm_shard_kernel_counts(), [0, 2, 0, 0]);
        // d at the lane threshold → lane-parallel dots, balanced: PR-WB
        let d_large = 32;
        let u = DenseMatrix::random(csr.rows, d_large, 1.0, &mut rng);
        let v = DenseMatrix::random(csr.cols, d_large, 1.0, &mut rng);
        let mut want = vec![0f32; csr.nnz()];
        sddmm_reference(&csr, &u, &v, &mut want);
        let exec = backend.execute_sddmm(&op, &u, &v, KernelKind::SrRs).unwrap();
        assert_eq!(exec.values, want);
        assert_eq!(backend.metrics().sddmm_shard_kernel_counts(), [0, 2, 0, 2]);
    }

    #[test]
    fn sddmm_degenerate_and_mismatched_operands() {
        let backend = ShardedBackend::new(4);
        // empty matrix: one empty shard, empty output
        let empty = CsrMatrix::from_coo(&CooMatrix::new(0, 5));
        let op = backend.prepare(&empty).unwrap();
        let exec = backend
            .execute_sddmm(
                &op,
                &DenseMatrix::zeros(0, 3),
                &DenseMatrix::zeros(5, 3),
                KernelKind::PrWb,
            )
            .unwrap();
        assert!(exec.values.is_empty());
        // operand shape mismatches are rejected
        let mut rng = Xoshiro256::seeded(409);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(30, 20, 0.2, &mut rng));
        let op = backend.prepare(&csr).unwrap();
        assert!(backend
            .execute_sddmm(
                &op,
                &DenseMatrix::zeros(30, 3),
                &DenseMatrix::zeros(20, 4),
                KernelKind::SrRs
            )
            .is_err());
    }

    #[test]
    fn degenerate_shapes_fan_out_safely() {
        let backend = ShardedBackend::new(4);
        // empty matrix
        let empty = CsrMatrix::from_coo(&CooMatrix::new(0, 7));
        let op = backend.prepare(&empty).unwrap();
        let exec = backend
            .execute(&op, &DenseMatrix::zeros(7, 3), KernelKind::PrWb)
            .unwrap();
        assert_eq!((exec.y.rows, exec.y.cols), (0, 3));
        // more shards than rows
        let mut coo = CooMatrix::new(3, 4);
        coo.push(0, 1, 2.0);
        coo.push(2, 3, 4.0);
        let tiny = CsrMatrix::from_coo(&coo);
        let op = backend.prepare(&tiny).unwrap();
        let x = DenseMatrix::from_vec(4, 2, (0..8).map(|i| i as f32).collect());
        let mut want = DenseMatrix::zeros(3, 2);
        spmm_reference(&tiny, &x, &mut want);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!(exec.y.data, want.data);
        }
        // zero-width dense operand
        let exec = backend
            .execute(&op, &DenseMatrix::zeros(4, 0), KernelKind::SrWb)
            .unwrap();
        assert_eq!((exec.y.rows, exec.y.cols), (3, 0));
    }

    #[test]
    fn value_only_prepare_delta_keeps_cuts_and_matches_full_prepare() {
        use crate::sparse::EdgeDelta;
        let mut rng = Xoshiro256::seeded(411);
        let mut csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 90, 0.08, &mut rng));
        let backend = ShardedBackend::new(3);
        let prev = backend.prepare(&csr).unwrap();

        // rewrite every edge's value (pattern untouched)
        let mut delta = EdgeDelta::new();
        for r in 0..csr.rows {
            let (cols, vals) = csr.row(r);
            for (c, v) in cols.iter().zip(vals) {
                delta.insert(r, *c as usize, v * 0.5 + 1.0);
            }
        }
        let rep = delta.apply(&mut csr);
        assert!(!rep.structural);
        let patched = backend.prepare_delta(&prev, &csr, false).unwrap().unwrap();
        let fresh = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::random(90, 6, 1.0, &mut rng);
        let u = DenseMatrix::random(120, 8, 1.0, &mut rng);
        let v = DenseMatrix::random(90, 8, 1.0, &mut rng);
        for kind in KernelKind::ALL {
            let a = backend.execute(&patched, &x, kind).unwrap();
            let b = backend.execute(&fresh, &x, kind).unwrap();
            assert_eq!(a.y.data, b.y.data, "{kind:?}");
            assert_eq!(a.artifact, b.artifact, "same cuts, same labels");
            let sa = backend.execute_sddmm(&patched, &u, &v, kind).unwrap();
            let sb = backend.execute_sddmm(&fresh, &u, &v, kind).unwrap();
            assert_eq!(sa.values, sb.values, "{kind:?}");
        }

        // structural batches no longer decline: one added edge re-cuts
        // at most its own neighborhood, so the untouched shards keep
        // their prepared operands and only the touched one re-prepares
        let mut grow = EdgeDelta::new();
        let r0 = (0..csr.rows).find(|&r| csr.row_nnz(r) < csr.cols).unwrap();
        let c0 = (0..csr.cols as u32)
            .find(|c| csr.row(r0).0.binary_search(c).is_err())
            .unwrap();
        grow.insert(r0, c0 as usize, 9.0);
        let rep = grow.apply(&mut csr);
        assert!(rep.structural);
        let grown = backend.prepare_delta(&patched, &csr, true).unwrap().unwrap();
        assert_eq!(grown.nnz(), csr.nnz());
        let fresh = backend.prepare(&csr).unwrap();
        let a = backend.execute(&grown, &x, KernelKind::SrRs).unwrap();
        let b = backend.execute(&fresh, &x, KernelKind::SrRs).unwrap();
        assert_eq!(a.y.data, b.y.data);
        assert_eq!(
            (
                backend.metrics().shard_operands_reused(),
                backend.metrics().shard_operands_reprepared()
            ),
            (2, 1),
            "one edge touches one shard; the other two carry over"
        );
    }

    #[test]
    fn structural_prepare_delta_reprepares_only_touched_shards() {
        use crate::sparse::EdgeDelta;
        let mut rng = Xoshiro256::seeded(412);
        let mut csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(160, 100, 0.06, &mut rng));
        let backend = ShardedBackend::new(4);
        let prev = backend.prepare(&csr).unwrap();

        // drop one edge from the last shard's row range only
        let prep_rows = csr.rows;
        let r0 = (3 * prep_rows / 4..prep_rows)
            .find(|&r| csr.row_nnz(r) > 0)
            .unwrap();
        let c0 = csr.row(r0).0[0] as usize;
        let mut delta = EdgeDelta::new();
        delta.delete(r0, c0);
        let rep = delta.apply(&mut csr);
        assert!(rep.structural);

        let patched = backend.prepare_delta(&prev, &csr, true).unwrap().unwrap();
        let reused = backend.metrics().shard_operands_reused();
        let reprepared = backend.metrics().shard_operands_reprepared();
        assert_eq!(reused + reprepared, 4, "every shard is accounted for");
        assert!(reused >= 2, "untouched shards keep their operands: {reused}");
        assert!(reprepared >= 1, "the touched shard re-prepares");

        // the patched operand is execution-equivalent to a fresh prepare
        let fresh = backend.prepare(&csr).unwrap();
        let x = DenseMatrix::random(100, 5, 1.0, &mut rng);
        let u = DenseMatrix::random(160, 6, 1.0, &mut rng);
        let v = DenseMatrix::random(100, 6, 1.0, &mut rng);
        for kind in KernelKind::ALL {
            let a = backend.execute(&patched, &x, kind).unwrap();
            let b = backend.execute(&fresh, &x, kind).unwrap();
            assert_eq!(a.y.data, b.y.data, "{kind:?}");
            let sa = backend.execute_sddmm(&patched, &u, &v, kind).unwrap();
            let sb = backend.execute_sddmm(&fresh, &u, &v, kind).unwrap();
            assert_eq!(sa.values, sb.values, "{kind:?}");
        }

        // a shape change is a different matrix: still declined
        let wider = CsrMatrix::from_coo(&CooMatrix::new(160, 101));
        assert!(backend.prepare_delta(&patched, &wider, true).is_none());
    }

    #[test]
    fn dimension_mismatch_is_rejected() {
        let mut rng = Xoshiro256::seeded(403);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(30, 20, 0.2, &mut rng));
        let backend = ShardedBackend::new(2);
        let op = backend.prepare(&csr).unwrap();
        let bad = DenseMatrix::zeros(19, 2);
        assert!(backend.execute(&op, &bad, KernelKind::SrRs).is_err());
        // operands from a different backend are refused
        let native = NativeBackend::serial();
        let foreign = native.prepare(&csr).unwrap();
        assert!(backend
            .execute(&foreign, &DenseMatrix::zeros(20, 2), KernelKind::SrRs)
            .is_err());
    }
}
