//! Per-kernel warp schedules: translate a sparse matrix + dense width into
//! the per-warp work trace each CUDA kernel design would generate.
//!
//! Each builder mirrors the control structure of the corresponding kernel
//! in `kernels/` (and of the paper's CUDA kernels):
//!
//! - [`sr_rs`]  — sequential reduction, row split. At small N a warp covers
//!   `32/N` *rows* (CSR-scalar shape: divergent lanes, uncoalesced sparse
//!   loads); at N ≥ 32 a warp covers one row × a 32-column tile (GE-SpMM
//!   RowSplit shape: broadcast sparse loads, coalesced dense lines). With
//!   **CSC** the sparse stream is staged warp-coalesced through shared
//!   memory (§2.1.3).
//! - [`sr_wb`]  — sequential reduction over fixed-nnz segments; boundary
//!   rows flushed with atomics.
//! - [`pr_rs`]  — CSR-Vector: warp per row, coalesced sparse loads, dense
//!   gather of **VDL** `(1,N)` lane fragments, merge tree. Lane-private
//!   partials cost registers: occupancy degrades as N grows (Insight 1).
//! - [`pr_wb`]  — VSR: warp per segment, segmented-scan network, per-run
//!   dumps (stores + boundary atomics).
//! - [`cusparse_spmv`] / [`cusparse_spmm`] — CSR-Adaptive-style vendor
//!   baseline (row binning; no nnz-level balancing).
//! - [`aspt`]   — panel-tiled baseline with dense-tile reuse.

use super::config::GpuConfig;
use super::cost::{
    distinct_sectors_with, sector_round, WarpCost, ALU, ATOMIC, MEM_ISSUE, SECTOR_ISSUE, SHFL,
    SMEM,
};
use super::exec::occupancy_from_registers;
use crate::kernels::baseline::AsptPanelStats;
use crate::sparse::{CsrMatrix, SegmentedMatrix};

/// Raw trace of one kernel invocation, before occupancy/bandwidth folding.
#[derive(Clone, Debug, Default)]
pub struct KernelTrace {
    pub warps: Vec<WarpCost>,
    /// bytes of sparse-operand traffic (streamed once, no reuse)
    pub sparse_bytes: f64,
    /// requested dense-operand traffic (L2 correction applied later)
    pub dense_bytes: f64,
    /// output traffic
    pub out_bytes: f64,
    /// register-pressure occupancy cap (resident warps per SM)
    pub occupancy_cap: Option<usize>,
}

/// Columns of the dense tile covered by one warp.
const NT: usize = 32;

fn ntiles(n: usize) -> usize {
    n.div_ceil(NT).max(1)
}

fn tile_width(n: usize, t: usize) -> usize {
    (n - t * NT).min(NT)
}

/// Scratch buffers shared across a schedule build.
struct Scratch {
    addrs: Vec<u64>,
    sectors: Vec<u64>,
}

impl Scratch {
    fn new() -> Self {
        Self {
            addrs: Vec::with_capacity(64),
            sectors: Vec::with_capacity(256),
        }
    }

    /// Issue a gather of `len`-byte lane fragments; returns sector count.
    fn gather(&mut self, w: &mut WarpCost, len: usize, gpu: &GpuConfig) -> usize {
        if self.addrs.is_empty() {
            return 0;
        }
        let s = distinct_sectors_with(&self.addrs, len, gpu.sector, &mut self.sectors);
        // one LSU instruction + pipeline replays for extra sectors
        w.mem += MEM_ISSUE + (s as f64 - 1.0) * SECTOR_ISSUE;
        s
    }
}

/// SR-RS: sequential reduction, row split.
pub fn sr_rs(a: &CsrMatrix, n: usize, csc: bool, gpu: &GpuConfig) -> KernelTrace {
    let n = n.max(1);
    let mut tr = KernelTrace::default();
    // CSC's shared-memory staging needs the warp to own one row; GE-SpMM
    // uses it for the warp-per-row regime, which starts paying off at
    // N ≥ 8. Below that the kernel is CSR-scalar-shaped (g rows per warp)
    // and the csc flag has nothing to stage into.
    let warp_per_row = csc && n >= 8;
    let nt_cov = n.min(NT);
    let g = if warp_per_row { 1 } else { (NT / nt_cov).max(1) }; // rows per warp
    let tiles = ntiles(n);
    let groups = a.rows.div_ceil(g);
    let mut sc = Scratch::new();
    tr.warps.reserve(groups * tiles);
    for t in 0..tiles {
        let nt = tile_width(n, t);
        let frag = nt * 4;
        for gi in 0..groups {
            let r0 = gi * g;
            let r1 = (r0 + g).min(a.rows);
            let mut w = WarpCost::default();
            let mut e = 0usize; // total nnz in group
            let mut lmax = 0usize;
            for r in r0..r1 {
                let l = a.row_nnz(r);
                e += l;
                lmax = lmax.max(l);
            }
            // ---- sparse operand ----
            if warp_per_row {
                // warp-coalesced stage-in to shared memory (§2.1.3), then
                // per-lane iteration out of smem
                let chunks = e.div_ceil(NT);
                w.mem += chunks as f64 * 2.0 * MEM_ISSUE;
                // smem reads issue on the LD/ST pipe
                w.mem += lmax as f64 * SMEM;
            } else if g == 1 {
                // one row per warp: per-element (val,col) broadcast — the
                // pair rides one 8-byte access plus a half-issue for the
                // second array
                w.mem += lmax as f64 * 1.5 * MEM_ISSUE;
            } else {
                // CSR-scalar: lanes walk their own rows — per-step gather
                // over the lanes' (val,col) pairs (8 B each)
                for s in 0..lmax {
                    sc.addrs.clear();
                    for r in r0..r1 {
                        if a.row_nnz(r) > s {
                            sc.addrs.push((a.indptr[r] as u64 + s as u64) * 8);
                        }
                    }
                    sc.gather(&mut w, 8, gpu);
                }
            }
            tr.sparse_bytes += e as f64 * 8.0;
            // ---- dense operand ----
            if g == 1 {
                // GE-SpMM coarsening: when the tile is narrower than the
                // warp (8 ≤ nt < 32), lane groups process `ep = 32/nt`
                // elements concurrently — one issue serves ep scattered
                // fragments, extra fragments replaying per sector.
                let ep = (NT / nt.max(1)).max(1);
                let frag_sectors = frag.div_ceil(gpu.sector).max(1);
                let groups = lmax.div_ceil(ep);
                w.mem += groups as f64
                    * (MEM_ISSUE + (ep - 1) as f64 * frag_sectors as f64 * SECTOR_ISSUE);
                tr.dense_bytes += e as f64 * sector_round(frag, gpu);
            } else {
                for s in 0..lmax {
                    sc.addrs.clear();
                    for r in r0..r1 {
                        if a.row_nnz(r) > s {
                            let c = a.indices[a.indptr[r] as usize + s] as u64;
                            sc.addrs.push(c * (n as u64 * 4) + (t as u64 * 128));
                        }
                    }
                    let secs = sc.gather(&mut w, frag, gpu);
                    tr.dense_bytes += (secs * gpu.sector) as f64;
                }
            }
            // ---- compute + store ----
            w.alu += lmax as f64 * ALU;
            if g == 1 {
                w.mem += MEM_ISSUE;
            } else {
                // adjacent output rows strided by N*4
                sc.addrs.clear();
                for r in r0..r1 {
                    sc.addrs.push(r as u64 * (n as u64 * 4) + t as u64 * 128);
                }
                sc.gather(&mut w, frag, gpu);
            }
            tr.out_bytes += ((r1 - r0) * nt * 4) as f64;
            tr.warps.push(w);
        }
    }
    tr
}

/// SR-WB: sequential reduction over fixed-nnz segments.
pub fn sr_wb(seg: &SegmentedMatrix, n: usize, gpu: &GpuConfig) -> KernelTrace {
    let n = n.max(1);
    let mut tr = KernelTrace::default();
    let tiles = ntiles(n);
    let mut sc = Scratch::new();
    let spans: Vec<usize> = (0..seg.num_segments)
        .map(|s| seg.segment_row_span(s))
        .collect();
    tr.warps.reserve(seg.num_segments * tiles);
    for t in 0..tiles {
        let nt = tile_width(n, t);
        let frag = nt * 4;
        for s in 0..seg.num_segments {
            let (_, cols, _) = seg.segment(s);
            let mut w = WarpCost::default();
            // coalesced loads of val/col/row (3 × 128 B)
            w.mem += 3.0 * MEM_ISSUE;
            tr.sparse_bytes += (seg.seg_len * 12) as f64;
            if n < NT {
                // SpMV-ish: lanes hold elements, gather dense fragments,
                // sequential smem reduction per row run
                sc.addrs.clear();
                sc.addrs
                    .extend(cols.iter().map(|&c| c as u64 * (n as u64 * 4)));
                let secs = sc.gather(&mut w, frag, gpu);
                tr.dense_bytes += (secs * gpu.sector) as f64;
                // serial smem reduction: one lane walks the segment; the
                // smem reads issue on the LD/ST pipe (this is the cost
                // VSR's shuffle network avoids)
                w.mem += seg.seg_len as f64 * SMEM;
                w.alu += seg.seg_len as f64 * ALU;
            } else {
                // SpMM: warp covers a 32-column tile, iterates elements
                // sequentially; one dense line broadcast per element
                w.mem += seg.seg_len as f64 * (MEM_ISSUE + SMEM);
                tr.dense_bytes += seg.seg_len as f64 * sector_round(frag, gpu);
                w.alu += seg.seg_len as f64 * ALU;
            }
            // boundary rows via (batch-amortized) atomics, interior runs
            // via scattered stores — same carry scheme as PR-WB
            let span = spans[s] as f64;
            w.mem += ATOMIC + (span - 1.0).max(0.0) * SECTOR_ISSUE;
            tr.out_bytes += span * (nt * 4) as f64;
            tr.warps.push(w);
        }
    }
    tr
}

/// Registers per thread for the PR kernels: base + N lane-private partials.
fn pr_occupancy(n: usize) -> usize {
    occupancy_from_registers(24 + 2 * n)
}

/// Lane-private partials beyond what the register file holds spill to
/// local memory: each spilled partial costs a read+write per element step.
/// This is the mechanism that makes parallel-reduction untenable at large
/// N (Insight 1).
const SPILL_FREE_PARTIALS: usize = 64;

fn spilled_partials(n: usize) -> usize {
    n.saturating_sub(SPILL_FREE_PARTIALS)
}

/// PR-RS: CSR-Vector with VDL `(1,N)` lane fragments.
pub fn pr_rs(a: &CsrMatrix, n: usize, gpu: &GpuConfig) -> KernelTrace {
    let n = n.max(1);
    let mut tr = KernelTrace {
        occupancy_cap: Some(pr_occupancy(n)),
        ..Default::default()
    };
    let mut sc = Scratch::new();
    let frag = n * 4;
    tr.warps.reserve(a.rows);
    for r in 0..a.rows {
        let (cols, _) = a.row(r);
        let l = cols.len();
        let mut w = WarpCost::default();
        let windows = l.div_ceil(NT).max(1);
        let mut k = 0;
        for _ in 0..windows {
            let hi = (k + NT).min(l);
            // coalesced sparse loads (val + col)
            w.mem += 2.0 * MEM_ISSUE;
            tr.sparse_bytes += (hi - k) as f64 * 8.0;
            // dense gather of lane fragments
            sc.addrs.clear();
            sc.addrs
                .extend(cols[k..hi].iter().map(|&c| c as u64 * frag as u64));
            let secs = sc.gather(&mut w, frag.max(4), gpu);
            tr.dense_bytes += (secs * gpu.sector) as f64;
            // lane multiply (N partials) + merge tree (5 steps × N)
            w.alu += n as f64 * ALU + 5.0 * SHFL * n as f64;
            // register-spill traffic for partials past the register file
            let spill = spilled_partials(n);
            if spill > 0 {
                w.mem += spill as f64 * 2.0 * MEM_ISSUE;
                tr.dense_bytes += (32 * spill * 8) as f64;
            }
            k = hi;
        }
        // store the (1, N) output row
        w.mem += (frag.div_ceil(gpu.line)).max(1) as f64 * MEM_ISSUE;
        tr.out_bytes += frag as f64;
        tr.warps.push(w);
    }
    tr
}

/// PR-WB: the paper's VSR.
pub fn pr_wb(seg: &SegmentedMatrix, n: usize, gpu: &GpuConfig) -> KernelTrace {
    let n = n.max(1);
    let mut tr = KernelTrace {
        occupancy_cap: Some(pr_occupancy(n)),
        ..Default::default()
    };
    let mut sc = Scratch::new();
    let frag = n * 4;
    tr.warps.reserve(seg.num_segments);
    for s in 0..seg.num_segments {
        let (_, cols, _) = seg.segment(s);
        let span = seg.segment_row_span(s) as f64;
        let mut w = WarpCost::default();
        // coalesced loads: val, col, row
        w.mem += 3.0 * MEM_ISSUE;
        tr.sparse_bytes += (seg.seg_len * 12) as f64;
        // dense gather (VDL fragments)
        sc.addrs.clear();
        sc.addrs
            .extend(cols.iter().map(|&c| c as u64 * frag as u64));
        let secs = sc.gather(&mut w, frag.max(4), gpu);
        tr.dense_bytes += (secs * gpu.sector) as f64;
        // multiply + segmented-scan network (5 predicated steps × N)
        w.alu += n as f64 * ALU + 5.0 * SHFL * n as f64;
        // register-spill traffic (same pressure as PR-RS)
        let spill = spilled_partials(n);
        if spill > 0 {
            w.mem += spill as f64 * 2.0 * MEM_ISSUE;
            tr.dense_bytes += (32 * spill * 8) as f64;
        }
        // dumps: interior runs are plain scattered stores; boundary
        // atomics amortize across the multi-segment batches one warp
        // processes in the production kernel (VSR carries partial runs
        // across segments in registers, GE-SpMM §4.2)
        w.mem += ATOMIC + (span - 1.0).max(0.0) * SECTOR_ISSUE;
        tr.out_bytes += span * frag as f64;
        tr.warps.push(w);
    }
    tr
}

/// cuSPARSE-like SpMV: CSR-Adaptive. Short rows are packed into row-aligned
/// ~32-nnz bins (CSR-Stream); long rows take the CSR-Vector path. No
/// nnz-level balancing across row boundaries — a mega-row stays serial in
/// one warp, which is exactly where the paper's WB kernels win.
pub fn cusparse_spmv(a: &CsrMatrix, gpu: &GpuConfig) -> KernelTrace {
    let mut tr = KernelTrace::default();
    let mut sc = Scratch::new();
    let mut r = 0usize;
    while r < a.rows {
        let l = a.row_nnz(r);
        if l >= NT {
            // CSR-Vector path
            let (cols, _) = a.row(r);
            let mut w = WarpCost::default();
            let mut k = 0;
            while k < l {
                let hi = (k + NT).min(l);
                w.mem += 2.0 * MEM_ISSUE;
                tr.sparse_bytes += (hi - k) as f64 * 8.0;
                sc.addrs.clear();
                sc.addrs.extend(cols[k..hi].iter().map(|&c| c as u64 * 4));
                let secs = sc.gather(&mut w, 4, gpu);
                tr.dense_bytes += (secs * gpu.sector) as f64;
                w.alu += ALU + 5.0 * SHFL;
                k = hi;
            }
            // row-block descriptor + indptr loads + store
            w.mem += 3.0 * MEM_ISSUE;
            w.alu += 4.0 * ALU;
            tr.out_bytes += 4.0;
            tr.warps.push(w);
            r += 1;
        } else {
            // CSR-Stream bin
            let bin_start = r;
            let mut bin_nnz = 0usize;
            while r < a.rows && a.row_nnz(r) < NT && bin_nnz + a.row_nnz(r) <= NT {
                bin_nnz += a.row_nnz(r);
                r += 1;
            }
            if r == bin_start {
                r += 1; // always progress
            }
            let mut w = WarpCost::default();
            w.mem += 2.0 * MEM_ISSUE;
            tr.sparse_bytes += bin_nnz as f64 * 8.0;
            sc.addrs.clear();
            for rr in bin_start..r {
                let (cols, _) = a.row(rr);
                sc.addrs.extend(cols.iter().map(|&c| c as u64 * 4));
            }
            let secs = sc.gather(&mut w, 4, gpu);
            tr.dense_bytes += (secs * gpu.sector) as f64;
            // row-block descriptor + indptr loads + per-row smem
            // reduction + bin store
            w.alu += 5.0 * ALU;
            w.mem += 3.0 * MEM_ISSUE + (r - bin_start) as f64 * 2.0 * SMEM;
            tr.out_bytes += (r - bin_start) as f64 * 4.0;
            tr.warps.push(w);
        }
    }
    tr
}

/// cuSPARSE-like SpMM: csrmm ≈ row-split sequential reduction without the
/// paper's CSC staging. The 0.85 issue credit models csrmm2's read-only
/// cache path, which amortizes part of the per-element broadcast cost —
/// without it the simulated gap to GE-SpMM overshoots the measurements in
/// the paper's own prior work ([14] reports 1.3–1.5×).
pub fn cusparse_spmm(a: &CsrMatrix, n: usize, gpu: &GpuConfig) -> KernelTrace {
    let mut tr = sr_rs(a, n, /*csc=*/ false, gpu);
    for w in &mut tr.warps {
        w.mem *= 0.85;
    }
    tr
}

/// ASpT-like SpMM: panels with dense-tile reuse through shared memory.
pub fn aspt(panels: &[AsptPanelStats], n: usize, gpu: &GpuConfig) -> KernelTrace {
    let n = n.max(1);
    let mut tr = KernelTrace::default();
    let tiles = ntiles(n);
    for t in 0..tiles {
        let nt = tile_width(n, t);
        let frag = nt * 4;
        for p in panels {
            let mut w = WarpCost::default();
            // dense tiles: one coalesced X-row load per dense column,
            // then entries stream through smem (the reuse)
            w.mem += p.dense_cols as f64 * MEM_ISSUE;
            tr.dense_bytes += p.dense_cols as f64 * sector_round(frag, gpu);
            w.mem += (p.dense_entries.div_ceil(NT)) as f64 * 2.0 * MEM_ISSUE;
            tr.sparse_bytes += p.dense_entries as f64 * 8.0;
            w.mem += p.dense_entries as f64 * SMEM;
            w.alu += p.dense_entries as f64 * ALU;
            // sparse remainder: ASpT stages it through shared memory too
            // (it is a tuned kernel); dense loads use the same coarsening
            // as SR-RS
            let ep = (NT / nt.max(1)).max(1);
            let frag_sectors = frag.div_ceil(gpu.sector).max(1);
            let per_group = MEM_ISSUE + (ep - 1) as f64 * frag_sectors as f64 * SECTOR_ISSUE;
            // column extraction breaks the remainder's row contiguity
            // (GE-SpMM [14] reports this as ASpT's main regression), so
            // the stage-in replays ~3x vs a contiguous CSR stream
            w.mem += (p.sparse_entries.div_ceil(NT)) as f64 * 2.0 * MEM_ISSUE * 3.0
                + (p.sparse_entries.div_ceil(ep)) as f64 * per_group;
            w.mem += p.sparse_entries as f64 * SMEM;
            w.alu += p.sparse_entries as f64 * ALU;
            tr.sparse_bytes += p.sparse_entries as f64 * 8.0;
            tr.dense_bytes += p.sparse_entries as f64 * sector_round(frag, gpu);
            // stores
            w.mem += ((p.rows * frag).div_ceil(gpu.line)).max(1) as f64 * MEM_ISSUE * 0.25;
            tr.out_bytes += (p.rows * frag) as f64;
            tr.warps.push(w);
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    fn gpu() -> GpuConfig {
        GpuConfig::rtx3090()
    }

    fn random_csr(rows: usize, cols: usize, density: f64, seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256::seeded(seed);
        CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, &mut rng))
    }

    fn mem_sum(t: &KernelTrace) -> f64 {
        t.warps.iter().map(|w| w.mem).sum()
    }

    #[test]
    fn sr_rs_groups_rows_at_small_n() {
        let a = random_csr(128, 128, 0.1, 601);
        // N=1 → 32 rows per warp → 4 warps; N=64 → 1 row × 2 tiles → 256
        assert_eq!(sr_rs(&a, 1, false, &gpu()).warps.len(), 4);
        assert_eq!(sr_rs(&a, 64, false, &gpu()).warps.len(), 256);
    }

    #[test]
    fn csc_reduces_mem_issue_not_bytes() {
        let a = random_csr(200, 200, 0.2, 602);
        let with = sr_rs(&a, 128, true, &gpu());
        let without = sr_rs(&a, 128, false, &gpu());
        assert!(
            mem_sum(&with) < 0.8 * mem_sum(&without),
            "CSC should cut LSU cycles: {} vs {}",
            mem_sum(&with),
            mem_sum(&without)
        );
        assert_eq!(with.sparse_bytes, without.sparse_bytes);
    }

    #[test]
    fn pr_fragments_ride_free_up_to_sector() {
        let a = random_csr(128, 4096, 0.01, 603);
        let n1 = pr_rs(&a, 1, &gpu());
        let n4 = pr_rs(&a, 4, &gpu());
        let n64 = pr_rs(&a, 64, &gpu());
        assert!(
            n4.dense_bytes < 1.5 * n1.dense_bytes,
            "VDL economy: n4 {} vs n1 {}",
            n4.dense_bytes,
            n1.dense_bytes
        );
        assert!(n64.dense_bytes > 5.0 * n1.dense_bytes);
        // register pressure: occupancy cap shrinks with N
        assert!(n64.occupancy_cap.unwrap() < n1.occupancy_cap.unwrap());
    }

    #[test]
    fn pr_wb_balances_mem_cycles() {
        // one mega row: PR-RS gives it one huge warp; PR-WB splits it
        let mut coo = CooMatrix::new(1000, 1000);
        for c in 0..1000 {
            coo.push(0, c, 1.0);
        }
        for r in 1..1000 {
            coo.push(r, r, 1.0);
        }
        let a = CsrMatrix::from_coo(&coo);
        let seg = SegmentedMatrix::from_csr(&a, crate::kernels::WARP);
        let rs = pr_rs(&a, 1, &gpu());
        let wb = pr_wb(&seg, 1, &gpu());
        let max_mem = |t: &KernelTrace| t.warps.iter().map(|w| w.mem).fold(0.0, f64::max);
        assert!(
            max_mem(&rs) > 4.0 * max_mem(&wb),
            "mega-row warp should dominate RS: {} vs {}",
            max_mem(&rs),
            max_mem(&wb)
        );
    }

    #[test]
    fn cusparse_spmv_bins_short_rows() {
        let mut coo = CooMatrix::new(1000, 1000);
        for r in 0..1000 {
            coo.push(r, r, 1.0);
            coo.push(r, (r + 1) % 1000, 1.0);
        }
        let a = CsrMatrix::from_coo(&coo);
        let tr = cusparse_spmv(&a, &gpu());
        assert!(
            tr.warps.len() < 200,
            "expected binning, got {} warps",
            tr.warps.len()
        );
    }

    #[test]
    fn traces_are_empty_safe() {
        let a = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        let seg = SegmentedMatrix::from_csr(&a, crate::kernels::WARP);
        for tr in [
            sr_rs(&a, 8, true, &gpu()),
            sr_rs(&a, 8, false, &gpu()),
            sr_wb(&seg, 8, &gpu()),
            pr_rs(&a, 8, &gpu()),
            pr_wb(&seg, 8, &gpu()),
            cusparse_spmv(&a, &gpu()),
        ] {
            assert!(!tr.warps.is_empty());
            assert!(tr.warps.iter().all(|w| w.total().is_finite()));
        }
    }
}
