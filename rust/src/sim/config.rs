//! GPU hardware configurations for the cost model.
//!
//! Parameters for the three GPUs of the paper's evaluation (§3.1). Values
//! are public spec-sheet numbers; the cost model only depends on their
//! *ratios* (SM count × occupancy vs bandwidth vs clock), which is what
//! preserves the paper's relative results across the three cards.

/// Hardware description consumed by the cost model.
#[derive(Clone, Copy, Debug)]
pub struct GpuConfig {
    pub name: &'static str,
    /// streaming multiprocessors
    pub sms: usize,
    /// resident warps per SM at the occupancy these kernels achieve
    pub warps_per_sm: usize,
    /// core clock (GHz)
    pub clock_ghz: f64,
    /// DRAM bandwidth (GB/s)
    pub dram_gbps: f64,
    /// L2 cache size (bytes)
    pub l2_bytes: usize,
    /// memory transaction sector size (bytes)
    pub sector: usize,
    /// full cache line (bytes)
    pub line: usize,
    /// fixed kernel-launch overhead (seconds)
    pub launch_s: f64,
}

impl GpuConfig {
    /// Nvidia Tesla V100 (Volta, CC 7.0): 80 SMs, 900 GB/s HBM2.
    pub fn v100() -> Self {
        Self {
            name: "v100",
            sms: 80,
            warps_per_sm: 32,
            clock_ghz: 1.38,
            dram_gbps: 900.0,
            l2_bytes: 6 * 1024 * 1024,
            sector: 32,
            line: 128,
            launch_s: 4e-6,
        }
    }

    /// Nvidia RTX 2080 (Turing, CC 7.5): 46 SMs, 448 GB/s GDDR6.
    pub fn rtx2080() -> Self {
        Self {
            name: "rtx2080",
            sms: 46,
            warps_per_sm: 32,
            clock_ghz: 1.71,
            dram_gbps: 448.0,
            l2_bytes: 4 * 1024 * 1024,
            sector: 32,
            line: 128,
            launch_s: 4e-6,
        }
    }

    /// Nvidia RTX 3090 (Ampere, CC 8.6): 82 SMs, 936 GB/s GDDR6X.
    pub fn rtx3090() -> Self {
        Self {
            name: "rtx3090",
            sms: 82,
            warps_per_sm: 48,
            clock_ghz: 1.70,
            dram_gbps: 936.0,
            l2_bytes: 6 * 1024 * 1024,
            sector: 32,
            line: 128,
            launch_s: 4e-6,
        }
    }

    /// The three evaluation GPUs in paper order.
    pub fn all() -> [GpuConfig; 3] {
        [Self::v100(), Self::rtx2080(), Self::rtx3090()]
    }

    /// Look up by name.
    pub fn by_name(name: &str) -> Option<GpuConfig> {
        Self::all().into_iter().find(|g| g.name == name)
    }

    /// Total concurrent warp slots (SMs × resident warps).
    pub fn warp_slots(&self) -> usize {
        self.sms * self.warps_per_sm
    }

    /// Cycles available per second.
    pub fn cycles_per_second(&self) -> f64 {
        self.clock_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_and_slots() {
        let g = GpuConfig::by_name("v100").unwrap();
        assert_eq!(g.warp_slots(), 80 * 32);
        assert!(GpuConfig::by_name("h100").is_none());
        assert_eq!(GpuConfig::all().len(), 3);
    }

    #[test]
    fn relative_capability_ordering() {
        // 3090 should have more parallel slots than 2080; V100 and 3090
        // have comparable bandwidth, both well above the 2080.
        let v100 = GpuConfig::v100();
        let r2080 = GpuConfig::rtx2080();
        let r3090 = GpuConfig::rtx3090();
        assert!(r3090.warp_slots() > r2080.warp_slots());
        assert!(v100.dram_gbps > 1.5 * r2080.dram_gbps);
        assert!((r3090.dram_gbps - v100.dram_gbps).abs() < 100.0);
    }
}
