//! Execution model: per-warp cost vectors → makespan under three resource
//! bounds.
//!
//! 1. **LSU bound** — each SM has one load/store pipe shared by its
//!    resident warps, so memory-issue cycles schedule onto `SMs` slots.
//!    This is where coalescing quality and skew both bite: a mega-row's
//!    transactions pile onto one SM.
//! 2. **Slot bound** — total warp cycles schedule onto
//!    `SMs × warps_per_SM` slots (optionally capped by register-pressure
//!    occupancy). Captures compute/latency limits.
//! 3. **DRAM bound** — bytes / bandwidth.
//!
//! The paper's Insight 3 falls out of the scheduling: with many more warps
//! than slots, makespans approach `sum/slots` and per-warp imbalance stops
//! mattering (new warps backfill finished slots); with few warps, the
//! longest warp dominates and workload-balancing pays.

use super::config::GpuConfig;
use super::cost::{Bound, SimResult, WarpCost};

/// Greedy list-scheduling makespan: assign warps in order to the
/// earliest-free slot. O(W log S).
pub fn makespan_cycles(warp_cycles: impl Iterator<Item = f64>, slots: usize) -> f64 {
    let slots = slots.max(1);
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    // fixed-point cycles (1/16 cycle resolution) for Ord
    let to_fx = |c: f64| (c * 16.0) as u64;
    let mut heap: BinaryHeap<Reverse<u64>> = BinaryHeap::with_capacity(slots);
    let mut makespan = 0u64;
    for c in warp_cycles {
        let free_at = if heap.len() < slots {
            0
        } else {
            heap.pop().unwrap().0
        };
        let done = free_at + to_fx(c);
        makespan = makespan.max(done);
        heap.push(Reverse(done));
    }
    makespan as f64 / 16.0
}

/// Combine per-warp costs with the bandwidth bound and launch overhead.
/// `occupancy_cap` limits resident warps per SM (register pressure).
pub fn combine(
    warps: &[WarpCost],
    dram_bytes: f64,
    occupancy_cap: Option<usize>,
    gpu: &GpuConfig,
) -> SimResult {
    let lsu = makespan_cycles(warps.iter().map(|w| w.mem), gpu.sms);
    let resident = occupancy_cap
        .unwrap_or(gpu.warps_per_sm)
        .min(gpu.warps_per_sm)
        .max(1);
    let slots = makespan_cycles(warps.iter().map(|w| w.total()), gpu.sms * resident);
    let lsu_s = lsu / gpu.cycles_per_second();
    let slot_s = slots / gpu.cycles_per_second();
    let dram_s = dram_bytes / (gpu.dram_gbps * 1e9);
    let (body, bound) = [
        (lsu_s, Bound::Lsu),
        (slot_s, Bound::Slots),
        (dram_s, Bound::Dram),
    ]
    .into_iter()
    .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
    .unwrap();
    SimResult {
        seconds: body + gpu.launch_s,
        lsu_cycles: lsu,
        slot_cycles: slots,
        dram_bytes,
        warps: warps.len(),
        bound,
    }
}

/// Clamp total DRAM traffic for a repeatedly-read operand: once the
/// operand fits in L2, re-reads are L2 hits, so DRAM sees at most one full
/// read of it (plus the compulsory floor `min_bytes`).
pub fn l2_corrected_bytes(
    requested_bytes: f64,
    operand_bytes: f64,
    l2_bytes: usize,
    min_bytes: f64,
) -> f64 {
    if operand_bytes <= l2_bytes as f64 {
        requested_bytes.min(operand_bytes.max(min_bytes))
    } else {
        requested_bytes
    }
}

/// Register-pressure occupancy cap for kernels holding `regs_per_thread`
/// registers: SMs have a 64K × 32-bit register file.
pub fn occupancy_from_registers(regs_per_thread: usize) -> usize {
    const REGFILE: usize = 65_536;
    const THREADS_PER_WARP: usize = 32;
    (REGFILE / (THREADS_PER_WARP * regs_per_thread.max(1))).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(mems: &[f64]) -> Vec<WarpCost> {
        mems.iter().map(|&m| WarpCost { mem: m, alu: 0.0 }).collect()
    }

    #[test]
    fn single_wave_is_max() {
        let m = makespan_cycles([10.0, 50.0, 20.0].into_iter(), 8);
        assert_eq!(m, 50.0);
    }

    #[test]
    fn many_waves_approach_average_load() {
        let m = makespan_cycles(std::iter::repeat(7.0).take(1000), 10);
        assert!((m - 700.0).abs() < 1.0, "makespan {m}");
    }

    #[test]
    fn straggler_amortizes_under_load() {
        // Insight 3: with many short warps, one straggler hides.
        let mut big = vec![10.0; 10_000];
        big.push(1000.0);
        let m = makespan_cycles(big.iter().cloned(), 4);
        let avg_load = (10.0 * 10_000.0 + 1000.0) / 4.0;
        assert!(m < avg_load * 1.05, "straggler should amortize: {m} vs {avg_load}");
        // but dominates when slots are plentiful
        let wide = makespan_cycles(big.iter().cloned(), 20_000);
        assert_eq!(wide, 1000.0);
    }

    #[test]
    fn combine_picks_dominant_bound() {
        let gpu = super::super::config::GpuConfig::v100();
        // tiny compute, huge traffic → DRAM bound
        let r = combine(&costs(&[100.0]), 1e9, None, &gpu);
        assert_eq!(r.bound, super::super::cost::Bound::Dram);
        // heavy mem issue, no traffic → LSU bound
        let r2 = combine(&costs(&vec![1e5; 1000]), 10.0, None, &gpu);
        assert_eq!(r2.bound, super::super::cost::Bound::Lsu);
        // alu-only warps → slot bound
        let alu_warps: Vec<WarpCost> = (0..10_000)
            .map(|_| WarpCost { mem: 0.0, alu: 1e4 })
            .collect();
        let r3 = combine(&alu_warps, 10.0, None, &gpu);
        assert_eq!(r3.bound, super::super::cost::Bound::Slots);
    }

    #[test]
    fn occupancy_cap_slows_slot_bound() {
        let gpu = super::super::config::GpuConfig::v100();
        let warps: Vec<WarpCost> = (0..100_000)
            .map(|_| WarpCost { mem: 0.0, alu: 100.0 })
            .collect();
        let free = combine(&warps, 0.0, None, &gpu);
        let capped = combine(&warps, 0.0, Some(4), &gpu);
        assert!(
            capped.seconds > 3.5 * free.seconds,
            "cap should slow: {} vs {}",
            capped.seconds,
            free.seconds
        );
    }

    #[test]
    fn occupancy_from_registers_breakpoints() {
        assert_eq!(occupancy_from_registers(32), 64);
        assert_eq!(occupancy_from_registers(256), 8);
        assert!(occupancy_from_registers(10_000) >= 1);
    }

    #[test]
    fn l2_correction() {
        assert_eq!(l2_corrected_bytes(100e6, 1e6, 6 << 20, 0.0), 1e6);
        assert_eq!(l2_corrected_bytes(100e6, 50e6, 6 << 20, 0.0), 100e6);
        assert_eq!(l2_corrected_bytes(100e6, 1e6, 6 << 20, 2e6), 2e6);
    }
}
