//! GPU cost simulator — the evaluation substrate standing in for the
//! paper's V100 / RTX 2080 / RTX 3090 testbed (see `DESIGN.md`
//! §Substitutions).
//!
//! The pipeline: [`schedules`] builds the per-warp work trace a kernel
//! design would generate for a given matrix and dense width; [`exec`]
//! folds the trace through the GPU's occupancy (wave) model and DRAM
//! bandwidth; [`simulate`] is the public entry point.
//!
//! The model is calibrated for *relative* fidelity: who wins, by roughly
//! what factor, and where the crossovers fall as the paper's two input
//! axes (sparsity pattern, dense width N) vary. Absolute seconds are not
//! comparable to the authors' testbed.

pub mod config;
pub mod cost;
pub mod exec;
pub mod schedules;

pub use config::GpuConfig;
pub use cost::SimResult;

use crate::kernels::baseline::{AsptMatrix, AsptPanelStats};
use crate::kernels::KernelKind;
use crate::sparse::{CsrMatrix, SegmentedMatrix};

/// Kernel designs the simulator can run (the paper's four + variants for
/// the ablations + the two comparison baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimKernel {
    /// SR-RS with the CSC optimization (our sequential row-split kernel).
    SrRs,
    /// SR-RS without CSC (ablation §2.1.3 baseline).
    SrRsNoCsc,
    /// SR-WB (sequential, nnz-split segments).
    SrWb,
    /// PR-RS with VDL fragments (our parallel row-split kernel).
    PrRs,
    /// PR SpMM as N independent SpMV passes (ablation §2.1.2 strawman).
    PrRsNSpmv,
    /// PR-WB — VSR.
    PrWb,
    /// cuSPARSE-like vendor baseline.
    CuSparse,
    /// ASpT-like adaptive-tiling baseline.
    Aspt,
}

impl SimKernel {
    /// The paper's four selectable designs (what the adaptive strategy
    /// chooses among).
    pub const OURS: [SimKernel; 4] = [
        SimKernel::SrRs,
        SimKernel::SrWb,
        SimKernel::PrRs,
        SimKernel::PrWb,
    ];

    /// Label for bench output.
    pub fn label(&self) -> &'static str {
        match self {
            SimKernel::SrRs => "sr_rs",
            SimKernel::SrRsNoCsc => "sr_rs_nocsc",
            SimKernel::SrWb => "sr_wb",
            SimKernel::PrRs => "pr_rs",
            SimKernel::PrRsNSpmv => "pr_rs_nspmv",
            SimKernel::PrWb => "pr_wb",
            SimKernel::CuSparse => "cusparse",
            SimKernel::Aspt => "aspt",
        }
    }

    /// Map from the coordinator's [`KernelKind`].
    pub fn from_kind(k: KernelKind) -> SimKernel {
        match k {
            KernelKind::SrRs => SimKernel::SrRs,
            KernelKind::SrWb => SimKernel::SrWb,
            KernelKind::PrRs => SimKernel::PrRs,
            KernelKind::PrWb => SimKernel::PrWb,
        }
    }
}

/// A matrix prepared for simulation: every format the schedules need,
/// built once.
pub struct SimMatrix {
    pub csr: CsrMatrix,
    pub segments: SegmentedMatrix,
    aspt_panels: Vec<AsptPanelStats>,
}

impl SimMatrix {
    /// Preprocess all kernel input formats (outside any timed region,
    /// matching how the paper amortizes format construction).
    pub fn new(csr: CsrMatrix) -> Self {
        let segments = SegmentedMatrix::from_csr(&csr, crate::kernels::WARP);
        let aspt_panels = AsptMatrix::from_csr(&csr).panel_stats();
        Self {
            csr,
            segments,
            aspt_panels,
        }
    }

    /// Total floating-point work for dense width `n`.
    pub fn flops(&self, n: usize) -> f64 {
        2.0 * self.csr.nnz() as f64 * n.max(1) as f64
    }
}

/// Simulate one kernel invocation of `Y = A · X` with dense width `n`
/// (`n == 1` ⇒ SpMV) on `gpu`.
pub fn simulate(kernel: SimKernel, a: &SimMatrix, n: usize, gpu: &GpuConfig) -> SimResult {
    let n = n.max(1);
    // the strawman runs N separate SpMV launches
    if kernel == SimKernel::PrRsNSpmv {
        let one = simulate(SimKernel::PrRs, a, 1, gpu);
        return SimResult {
            seconds: one.seconds * n as f64,
            lsu_cycles: one.lsu_cycles * n as f64,
            slot_cycles: one.slot_cycles * n as f64,
            dram_bytes: one.dram_bytes * n as f64,
            warps: one.warps * n,
            bound: one.bound,
        };
    }
    let trace = match kernel {
        SimKernel::SrRs => schedules::sr_rs(&a.csr, n, true, gpu),
        SimKernel::SrRsNoCsc => schedules::sr_rs(&a.csr, n, false, gpu),
        SimKernel::SrWb => schedules::sr_wb(&a.segments, n, gpu),
        SimKernel::PrRs => schedules::pr_rs(&a.csr, n, gpu),
        SimKernel::PrWb => schedules::pr_wb(&a.segments, n, gpu),
        SimKernel::CuSparse => {
            if n == 1 {
                schedules::cusparse_spmv(&a.csr, gpu)
            } else {
                schedules::cusparse_spmm(&a.csr, n, gpu)
            }
        }
        SimKernel::Aspt => schedules::aspt(&a.aspt_panels, n, gpu),
        SimKernel::PrRsNSpmv => unreachable!(),
    };
    finish(trace, &a.csr, n, gpu)
}

/// Fold a raw trace through the L2 correction and the execution model.
fn finish(
    trace: schedules::KernelTrace,
    csr: &CsrMatrix,
    n: usize,
    gpu: &GpuConfig,
) -> SimResult {
    // Dense operand X (K × N f32): re-reads are partially absorbed by L2.
    // When X fits, DRAM sees at most one full read; when it spills, the
    // surviving fraction of re-read traffic scales with how badly it
    // spills (a standard capacity-miss approximation).
    let x_bytes = (csr.cols * n * 4) as f64;
    let dense_dram = if x_bytes <= gpu.l2_bytes as f64 {
        trace.dense_bytes.min(x_bytes.max(trace.dense_bytes.min(x_bytes)))
    } else {
        let spill = 1.0 - gpu.l2_bytes as f64 / x_bytes;
        x_bytes + (trace.dense_bytes - x_bytes).max(0.0) * spill
    };
    let dram = trace.sparse_bytes + dense_dram + trace.out_bytes;
    exec::combine(&trace.warps, dram, trace.occupancy_cap, gpu)
}

/// Simulate the best of the paper's four designs (oracle selection).
pub fn simulate_oracle(a: &SimMatrix, n: usize, gpu: &GpuConfig) -> (SimKernel, SimResult) {
    SimKernel::OURS
        .iter()
        .map(|&k| (k, simulate(k, a, n, gpu)))
        .min_by(|x, y| x.1.seconds.partial_cmp(&y.1.seconds).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    /// seconds minus launch overhead — isolates the modeled kernel body.
    fn body(r: SimResult, gpu: &GpuConfig) -> f64 {
        r.seconds - gpu.launch_s
    }

    fn uniform_matrix(rows: usize, avg_row: usize, seed: u64) -> SimMatrix {
        let mut rng = Xoshiro256::seeded(seed);
        let density = avg_row as f64 / rows as f64;
        SimMatrix::new(CsrMatrix::from_coo(&CooMatrix::random_uniform(
            rows, rows, density, &mut rng,
        )))
    }

    /// A deliberately skewed matrix: mostly short rows plus a few
    /// fixed-size mega rows that serialize any row-split kernel. The mega
    /// rows do NOT scale with `rows`, so growing the matrix grows only the
    /// balanced bulk (used to show the WB edge fading with total work).
    fn skewed_matrix(rows: usize, seed: u64) -> SimMatrix {
        let mut rng = Xoshiro256::seeded(seed);
        let mut coo = CooMatrix::random_uniform(rows, rows, 4.0 / rows as f64, &mut rng);
        let mega_len = 10_000.min(rows);
        for mega in 0..5 {
            for k in 0..mega_len {
                coo.push(mega * (rows / 8), (k * 2 + mega) % rows, 1.0);
            }
        }
        SimMatrix::new(CsrMatrix::from_coo(&coo))
    }

    #[test]
    fn all_kernels_produce_finite_positive_times() {
        let m = uniform_matrix(2000, 8, 701);
        let gpu = GpuConfig::v100();
        for k in [
            SimKernel::SrRs,
            SimKernel::SrRsNoCsc,
            SimKernel::SrWb,
            SimKernel::PrRs,
            SimKernel::PrRsNSpmv,
            SimKernel::PrWb,
            SimKernel::CuSparse,
            SimKernel::Aspt,
        ] {
            for n in [1usize, 4, 32, 128] {
                let r = simulate(k, &m, n, &gpu);
                assert!(
                    r.seconds.is_finite() && r.seconds > 0.0,
                    "{:?} n={n}: {:?}",
                    k,
                    r
                );
            }
        }
    }

    /// Paper Insight 1 / Fig. 5 middle: parallel-reduction wins at small N,
    /// sequential-reduction (with CSC) wins at large N.
    #[test]
    fn pr_sr_crossover_with_n() {
        let m = uniform_matrix(20_000, 16, 702);
        let gpu = GpuConfig::rtx3090();
        let pr1 = body(simulate(SimKernel::PrRs, &m, 1, &gpu), &gpu);
        let sr1 = body(simulate(SimKernel::SrRs, &m, 1, &gpu), &gpu);
        assert!(pr1 < sr1, "PR should win at N=1: pr {pr1} sr {sr1}");
        let pr32 = body(simulate(SimKernel::PrRs, &m, 32, &gpu), &gpu);
        let sr32 = body(simulate(SimKernel::SrRs, &m, 32, &gpu), &gpu);
        assert!(sr32 < pr32, "SR should win at N=32: pr {pr32} sr {sr32}");
        let pr128 = body(simulate(SimKernel::PrRs, &m, 128, &gpu), &gpu);
        let sr128 = body(simulate(SimKernel::SrRs, &m, 128, &gpu), &gpu);
        assert!(
            sr128 < 0.7 * pr128,
            "SR should win clearly at N=128: pr {pr128} sr {sr128}"
        );
    }

    /// Paper Insight 2: workload-balancing wins on skewed matrices
    /// (straggler rows), and is ≈neutral-to-negative on balanced ones.
    #[test]
    fn wb_helps_skewed_hurts_balanced() {
        let gpu = GpuConfig::v100();
        let skew = skewed_matrix(3000, 703);
        let wb = body(simulate(SimKernel::PrWb, &skew, 1, &gpu), &gpu);
        let rs = body(simulate(SimKernel::PrRs, &skew, 1, &gpu), &gpu);
        assert!(
            wb < 0.7 * rs,
            "WB should win clearly on skew: wb {wb} rs {rs}"
        );

        let flat = uniform_matrix(20_000, 32, 704);
        let wb2 = body(simulate(SimKernel::PrWb, &flat, 1, &gpu), &gpu);
        let rs2 = body(simulate(SimKernel::PrRs, &flat, 1, &gpu), &gpu);
        assert!(
            rs2 <= wb2 * 1.05,
            "balanced: RS should be ≥ competitive: wb {wb2} rs {rs2}"
        );
    }

    /// Paper Insight 3: imbalance stops mattering once the workload is
    /// large (waves amortize the straggler), so the WB edge shrinks.
    #[test]
    fn wb_benefit_fades_with_total_work() {
        let gpu = GpuConfig::v100();
        // same skew shape, small vs large total workload
        let small = skewed_matrix(3000, 705);
        let large = skewed_matrix(60_000, 706);
        let edge = |m: &SimMatrix| {
            let wb = body(simulate(SimKernel::PrWb, m, 1, &gpu), &gpu);
            let rs = body(simulate(SimKernel::PrRs, m, 1, &gpu), &gpu);
            rs / wb
        };
        let e_small = edge(&small);
        let e_large = edge(&large);
        assert!(
            e_small > e_large,
            "WB edge should fade with scale: small {e_small} large {e_large}"
        );
    }

    /// §2.1.3: CSC speeds up sequential-reduction SpMM at large N.
    #[test]
    fn csc_speedup_at_n128() {
        // sized so X stays L2-resident at n=128 (otherwise both variants
        // are DRAM-bound and converge)
        let m = uniform_matrix(8_000, 16, 707);
        let gpu = GpuConfig::rtx3090();
        let with = body(simulate(SimKernel::SrRs, &m, 128, &gpu), &gpu);
        let without = body(simulate(SimKernel::SrRsNoCsc, &m, 128, &gpu), &gpu);
        let speedup = without / with;
        assert!(
            speedup > 1.05 && speedup < 3.0,
            "CSC speedup at N=128 out of band: {speedup}"
        );
    }

    /// §2.1.2: VDL beats N-separate-SpMV at N=2 (paper: 1.89×).
    #[test]
    fn vdl_beats_n_spmv() {
        let m = uniform_matrix(20_000, 16, 708);
        let gpu = GpuConfig::rtx3090();
        let vdl = body(simulate(SimKernel::PrRs, &m, 2, &gpu), &gpu);
        let straw = simulate(SimKernel::PrRsNSpmv, &m, 2, &gpu).seconds - 2.0 * gpu.launch_s;
        let speedup = straw / vdl;
        assert!(
            speedup > 1.4 && speedup < 3.0,
            "VDL speedup out of band: {speedup}"
        );
    }

    /// Oracle picks a sensible design per regime.
    #[test]
    fn oracle_respects_regimes() {
        let gpu = GpuConfig::v100();
        let skew = skewed_matrix(3000, 709);
        let (k_small_n, _) = simulate_oracle(&skew, 1, &gpu);
        assert!(
            matches!(k_small_n, SimKernel::PrWb | SimKernel::SrWb),
            "skewed N=1 should pick a balanced kernel, got {:?}",
            k_small_n
        );
        let flat = uniform_matrix(20_000, 8, 710);
        let (k_large_n, _) = simulate_oracle(&flat, 128, &gpu);
        assert!(
            matches!(k_large_n, SimKernel::SrRs | SimKernel::SrWb),
            "N=128 should pick sequential reduction, got {:?}",
            k_large_n
        );
    }

    /// Ours (oracle over the four designs) should beat the vendor baseline
    /// on both a skewed and a clustered matrix at SpMM widths.
    #[test]
    fn ours_beats_cusparse_spmm() {
        let gpu = GpuConfig::rtx3090();
        // sized so X stays L2-resident at n=128 (the paper's SuiteSparse
        // regime) — with X spilling L2 both kernels are DRAM-bound and
        // converge, which the model reports honestly
        for (m, label) in [
            (uniform_matrix(8_000, 16, 711), "uniform"),
            (skewed_matrix(8_000, 712), "skewed"),
        ] {
            for n in [32usize, 128] {
                let (_, ours) = simulate_oracle(&m, n, &gpu);
                let cu = simulate(SimKernel::CuSparse, &m, n, &gpu);
                let ratio = cu.seconds / ours.seconds;
                assert!(
                    ratio > 1.0,
                    "{label} n={n}: ours should win, ratio {ratio}"
                );
            }
        }
    }
}
