//! Cost-model primitives: per-warp cycle accounting and memory-coalescing
//! arithmetic.
//!
//! The model is warp-analytic, not cycle-accurate. Each warp accumulates
//! two cycle pools:
//!
//! - **mem** — LSU issue cycles (transactions and sectors). All warps on
//!   an SM share one load/store pipe, so these bound throughput at
//!   `sum(mem)/SMs` and are where coalescing quality shows up.
//! - **alu** — arithmetic/shuffle/shared-memory cycles, overlappable
//!   across the resident-warp pool.
//!
//! [`super::exec`] combines the pools with the occupancy (wave) model and
//! the DRAM bandwidth bound. Constants are throughput costs (cycles a
//! warp's op occupies the pipe), not latencies — latency is assumed hidden
//! by the resident warps, the regime these streaming kernels run in.

use super::config::GpuConfig;

/// Issue cost of one full-width global-memory transaction (cycles).
pub const MEM_ISSUE: f64 = 4.0;
/// Issue cost of one 32-byte sector in a gather (cycles per sector).
pub const SECTOR_ISSUE: f64 = 2.0;
/// One ALU/FMA step (cycles).
pub const ALU: f64 = 1.0;
/// One shared-memory access (cycles).
pub const SMEM: f64 = 1.0;
/// One shuffle step of a reduction/scan network (cycles).
pub const SHFL: f64 = 2.0;
/// One global atomic update (cycles on the LSU; moderately contended).
pub const ATOMIC: f64 = 16.0;

/// Accumulated cost of one warp.
#[derive(Clone, Copy, Debug, Default)]
pub struct WarpCost {
    /// LSU issue cycles (serialized per SM)
    pub mem: f64,
    /// arithmetic cycles (overlappable)
    pub alu: f64,
}

impl WarpCost {
    /// Total slot cycles of this warp.
    pub fn total(&self) -> f64 {
        self.mem + self.alu
    }
}

/// Count distinct sectors touched by lanes reading `[addr, addr+len)`.
/// O(lanes · sectors-per-lane) with a small sort-based dedup. `scratch`
/// avoids per-call allocation on the hot path.
pub fn distinct_sectors_with(
    addrs: &[u64],
    len: usize,
    sector: usize,
    scratch: &mut Vec<u64>,
) -> usize {
    scratch.clear();
    let sec = sector as u64;
    for &a in addrs {
        let first = a / sec;
        let last = (a + len as u64 - 1) / sec;
        for s in first..=last {
            scratch.push(s);
        }
    }
    scratch.sort_unstable();
    scratch.dedup();
    scratch.len()
}

/// Allocation-per-call variant (tests, cold paths).
pub fn distinct_sectors(addrs: &[u64], len: usize, sector: usize) -> usize {
    let mut scratch = Vec::with_capacity(addrs.len() * 2);
    distinct_sectors_with(addrs, len, sector, &mut scratch)
}

/// Round byte count up to whole sectors.
pub fn sector_round(bytes: usize, gpu: &GpuConfig) -> f64 {
    (bytes.div_ceil(gpu.sector) * gpu.sector) as f64
}

/// Result of simulating one kernel invocation.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// end-to-end estimated time (seconds), including launch overhead
    pub seconds: f64,
    /// LSU makespan (cycles) — usually the binding constraint
    pub lsu_cycles: f64,
    /// warp-slot makespan (cycles)
    pub slot_cycles: f64,
    /// DRAM traffic after the L2 correction (bytes)
    pub dram_bytes: f64,
    /// number of warps launched
    pub warps: usize,
    /// which bound dominated
    pub bound: Bound,
}

/// The resource that set the simulated time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// per-SM load/store pipe throughput (coalescing-sensitive)
    Lsu,
    /// warp-slot occupancy / compute
    Slots,
    /// DRAM bandwidth
    Dram,
}

impl SimResult {
    /// Effective GFLOP/s for a workload of `flops` floating-point ops.
    pub fn gflops(&self, flops: f64) -> f64 {
        if self.seconds <= 0.0 {
            0.0
        } else {
            flops / self.seconds / 1e9
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warp_cost_totals() {
        let w = WarpCost { mem: 8.0, alu: 3.0 };
        assert_eq!(w.total(), 11.0);
    }

    #[test]
    fn gather_contiguous_lanes_coalesce() {
        // 32 lanes reading consecutive f32: 128 bytes = 4 sectors
        let addrs: Vec<u64> = (0..32u64).map(|l| l * 4).collect();
        assert_eq!(distinct_sectors(&addrs, 4, 32), 4);
    }

    #[test]
    fn gather_scattered_lanes_do_not() {
        // 32 lanes reading f32 4KB apart: 32 sectors
        let addrs: Vec<u64> = (0..32u64).map(|l| l * 4096).collect();
        assert_eq!(distinct_sectors(&addrs, 4, 32), 32);
    }

    #[test]
    fn gather_fragment_spanning_sectors() {
        // one lane reading 64 bytes starting at 16: sectors 0,1,2
        assert_eq!(distinct_sectors(&[16], 64, 32), 3);
    }

    #[test]
    fn vdl_sector_economy() {
        // The §2.1.2 effect: scattered lanes reading N*4 bytes each touch
        // the SAME sector count for N ∈ {1,2,4,8} — wider fragments ride
        // along free, which is exactly why VDL beats N separate SpMVs.
        let addrs_n1: Vec<u64> = (0..32u64).map(|l| l * 4096).collect();
        let n1 = distinct_sectors(&addrs_n1, 4, 32);
        let addrs_n4: Vec<u64> = (0..32u64).map(|l| l * 4096 * 4).collect();
        let n4 = distinct_sectors(&addrs_n4, 16, 32);
        assert_eq!(n1, n4, "float4 loads should touch no more sectors");
    }

    #[test]
    fn clustered_columns_share_sectors() {
        // 8 lanes reading f32 within one 32B sector
        let addrs: Vec<u64> = (0..8u64).map(|l| 1000 * 32 + l * 4).collect();
        assert_eq!(distinct_sectors(&addrs, 4, 32), 1);
    }

    #[test]
    fn gflops_sane() {
        let r = SimResult {
            seconds: 1e-3,
            lsu_cycles: 0.0,
            slot_cycles: 0.0,
            dram_bytes: 0.0,
            warps: 0,
            bound: Bound::Lsu,
        };
        assert!((r.gflops(2e9) - 2000.0).abs() < 1e-9);
    }
}
