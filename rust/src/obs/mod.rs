//! Observability: request-lifecycle tracing, lock-free latency
//! histograms, selector decision audit, and the exposition surface.
//!
//! The adaptive layers in this stack (per-shard kernel selection,
//! measured calibration, online threshold refinement, adaptive SR
//! traversal) all make runtime decisions; this subsystem makes them
//! visible from outside the process:
//!
//! - [`trace`] — zero-dependency structured spans with parent links and
//!   attributes, emitted at admission → batch flush → engine dispatch →
//!   shard fan-out → kernel inner call, captured per request into a
//!   [`trace::FlightRecorder`] ring of the last N traces.
//! - [`hist`] — log-bucketed lock-free [`hist::AtomicHistogram`]s (64
//!   power-of-√2 buckets over ns) behind every latency quantile in
//!   `coordinator::Metrics`; no lock on the record path.
//! - [`audit`] — the selector decision [`audit::AuditLog`]: features,
//!   thresholds, chosen kernel, exploration flag, realized cost.
//! - [`expo`] — Prometheus-text and JSON snapshot renderers over
//!   `Metrics` + histograms + audit, behind `ge-spmm stats` and
//!   `ge-spmm serve --stats-every/--stats-file`.
//! - [`workload`] — analytic roofline accounting: integer-exact flops /
//!   bytes / padding per variant execution, rendered as achieved
//!   GFLOP/s, GB/s and arithmetic intensity.
//! - [`regret`] — selector-regret counters: realized cost vs the best
//!   known competing variant per `(op, feature bucket)`, the paper's
//!   5–12% adaptivity-loss claim as a live metric.
//! - [`slo`] — rolling-window burn-rate monitors over latency-quantile
//!   and queue-depth objectives on the serve path.
//!
//! Everything here is part of the serving hot path's contract: the
//! uninstrumented cost is one thread-local read per span site and a few
//! relaxed atomics per metric (`benches/metrics_overhead.rs` measures
//! it). See `DESIGN.md` §Observability for the span taxonomy, the
//! bucket scheme, the audit fields and the exposition formats.

pub mod audit;
pub mod expo;
pub mod hist;
pub mod regret;
pub mod slo;
pub mod trace;
pub mod workload;

pub use audit::{AuditEntry, AuditLog};
pub use hist::{AtomicHistogram, HistogramSnapshot};
pub use regret::{RegretReport, RegretTracker};
pub use slo::{SloMonitor, SloReport, SloSpec};
pub use trace::{FlightRecorder, SpanRecord, TraceHandle};
pub use workload::{WorkloadEstimate, WorkloadTotals};

/// Aggregation grain of a latency histogram: whole requests at the
/// engine, or individual shard executions inside the sharded backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grain {
    /// Engine-level request latency.
    Request,
    /// Per-shard execution latency inside the fan-out.
    Shard,
}

impl Grain {
    /// Both grains, in exposition order.
    pub const ALL: [Grain; 2] = [Grain::Request, Grain::Shard];

    /// Label used in exposition output.
    pub fn label(&self) -> &'static str {
        match self {
            Grain::Request => "request",
            Grain::Shard => "shard",
        }
    }
}
