//! Request-lifecycle tracing: structured spans and the flight recorder.
//!
//! A [`Trace`] is one request's span tree: cheap records with parent
//! links, start/stop nanoseconds relative to the trace epoch, and
//! key/value attributes. Spans are opened through an **implicit
//! thread-local context** — [`span`] is a no-op returning an inert guard
//! when no trace is installed, so instrumented code (engine dispatch,
//! shard fan-out, kernel calls) pays almost nothing when nobody is
//! looking. The context propagates across the sharded backend's scoped
//! threads explicitly: capture a [`TraceHandle`] before the fan-out and
//! [`attach`] it inside each worker closure.
//!
//! Finished traces are committed into a [`FlightRecorder`] — a ring
//! buffer of the last N request traces, dumpable as JSON. The recorder
//! is lock-light: the only mutex acquisitions are one per span *end*
//! (on the trace's own span list) and one per request commit (on the
//! ring); the request hot path between spans takes no locks, and every
//! lock is poison-tolerant so a panicking worker cannot wedge tracing
//! for the whole server. Span taxonomy and attribute conventions are
//! documented in `DESIGN.md` §Observability.

use crate::util::json::{num, obj, s, Json};
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One closed span: a named interval within a trace.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Span id, unique within the trace (ids start at 1).
    pub id: u64,
    /// Parent span id; 0 means a root span.
    pub parent: u64,
    /// Span name (static taxonomy: `admission`, `batch`, `dispatch`,
    /// `delta`, ...).
    pub name: &'static str,
    /// Start offset from the trace epoch, ns.
    pub start_ns: u64,
    /// End offset from the trace epoch, ns.
    pub end_ns: u64,
    /// Key/value attributes set while the span was open.
    pub attrs: Vec<(&'static str, String)>,
}

impl SpanRecord {
    /// Span duration in ns.
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Look up an attribute by key.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| v.as_str())
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("parent", num(self.parent as f64)),
            ("name", s(self.name)),
            ("start_ns", num(self.start_ns as f64)),
            ("end_ns", num(self.end_ns as f64)),
            (
                "attrs",
                Json::Obj(
                    self.attrs
                        .iter()
                        .map(|(k, v)| (k.to_string(), s(v)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// One in-flight request's span collection.
///
/// Created at admission (or lazily by the engine for direct calls),
/// carried by [`TraceHandle`]s, finished by [`FlightRecorder::commit`].
#[derive(Debug)]
pub struct Trace {
    label: String,
    epoch: Instant,
    next_id: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
}

impl Trace {
    /// Start a new trace; the epoch (t=0 for all span offsets) is now.
    pub fn begin(label: impl Into<String>) -> Arc<Trace> {
        Arc::new(Trace {
            label: label.into(),
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            spans: Mutex::new(Vec::new()),
        })
    }

    /// The trace's request label (e.g. `spmm#42`).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Nanoseconds since the trace epoch.
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn push(&self, record: SpanRecord) {
        self.spans
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(record);
    }

    /// Record an already-measured root-level interval (used for spans
    /// whose start and end are observed on different threads, like the
    /// admission queue wait).
    pub fn record_raw(
        &self,
        name: &'static str,
        start_ns: u64,
        end_ns: u64,
        attrs: Vec<(&'static str, String)>,
    ) {
        let id = self.alloc_id();
        self.push(SpanRecord {
            id,
            parent: 0,
            name,
            start_ns,
            end_ns,
            attrs,
        });
    }

    /// Spans recorded so far (closed spans only).
    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

struct Ctx {
    trace: Arc<Trace>,
    parent: u64,
}

thread_local! {
    static CURRENT: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// Whether a trace is installed on this thread.
pub fn active() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// A portable reference to the current trace position: the trace plus
/// the span that new child spans should parent to. Capture with
/// [`handle`] before crossing a thread boundary, re-install on the other
/// side with [`attach`].
#[derive(Clone)]
pub struct TraceHandle {
    trace: Arc<Trace>,
    parent: u64,
}

impl TraceHandle {
    /// A handle at the root of `trace` (children become root-parented).
    pub fn of(trace: &Arc<Trace>) -> Self {
        Self {
            trace: trace.clone(),
            parent: 0,
        }
    }

    /// The underlying trace.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }
}

impl std::fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceHandle({}@{})", self.trace.label(), self.parent)
    }
}

/// Snapshot the current thread's trace position, if any.
pub fn handle() -> Option<TraceHandle> {
    CURRENT.with(|c| {
        c.borrow().as_ref().map(|ctx| TraceHandle {
            trace: ctx.trace.clone(),
            parent: ctx.parent,
        })
    })
}

/// Install a trace position on this thread until the returned scope
/// drops (the previous position, if any, is restored).
pub fn attach(h: &TraceHandle) -> TraceScope {
    let prev = CURRENT.with(|c| {
        c.borrow_mut().replace(Ctx {
            trace: h.trace.clone(),
            parent: h.parent,
        })
    });
    TraceScope { prev }
}

/// Guard restoring the previously-installed trace context on drop.
pub struct TraceScope {
    prev: Option<Ctx>,
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

struct ActiveSpan {
    trace: Arc<Trace>,
    id: u64,
    parent: u64,
    name: &'static str,
    start_ns: u64,
    attrs: Vec<(&'static str, String)>,
}

/// An open span; records itself into the trace when dropped (or ended).
/// Inert — every method a no-op — when no trace was installed at
/// creation, so instrumentation points cost one TLS read off-trace.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

impl SpanGuard {
    /// Whether this guard is actually recording into a trace.
    pub fn is_recording(&self) -> bool {
        self.active.is_some()
    }

    /// Attach a key/value attribute (no-op when not recording).
    pub fn set_attr(&mut self, key: &'static str, value: impl std::fmt::Display) {
        if let Some(a) = self.active.as_mut() {
            a.attrs.push((key, value.to_string()));
        }
    }

    /// Close the span now (idempotent; `Drop` calls this).
    pub fn end(&mut self) {
        if let Some(a) = self.active.take() {
            let end_ns = a.trace.elapsed_ns();
            // Restore the parent pointer if this span is still the
            // innermost on this thread's context.
            CURRENT.with(|c| {
                if let Some(ctx) = c.borrow_mut().as_mut() {
                    if Arc::ptr_eq(&ctx.trace, &a.trace) && ctx.parent == a.id {
                        ctx.parent = a.parent;
                    }
                }
            });
            a.trace.push(SpanRecord {
                id: a.id,
                parent: a.parent,
                name: a.name,
                start_ns: a.start_ns,
                end_ns,
                attrs: a.attrs,
            });
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.end();
    }
}

/// Open a span under the current thread's trace context. Returns an
/// inert guard when no trace is installed.
pub fn span(name: &'static str) -> SpanGuard {
    CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        match cur.as_mut() {
            None => SpanGuard { active: None },
            Some(ctx) => {
                let trace = ctx.trace.clone();
                let id = trace.alloc_id();
                let parent = ctx.parent;
                ctx.parent = id;
                let start_ns = trace.elapsed_ns();
                SpanGuard {
                    active: Some(ActiveSpan {
                        trace,
                        id,
                        parent,
                        name,
                        start_ns,
                        attrs: Vec::new(),
                    }),
                }
            }
        }
    })
}

/// Request-scope guard: if a trace is already installed (the serving
/// path created one at admission), this just opens a child span named
/// `name`; otherwise (direct engine calls) it begins an owned trace,
/// installs it, opens the span, and commits the trace to `recorder`
/// when dropped. Either way the instrumented region gets exactly one
/// span and direct callers get full traces for free.
pub struct RequestGuard {
    span: SpanGuard,
    owned: Option<(Arc<Trace>, Arc<FlightRecorder>, TraceScope)>,
}

impl RequestGuard {
    /// Attach a key/value attribute to the request span.
    pub fn set_attr(&mut self, key: &'static str, value: impl std::fmt::Display) {
        self.span.set_attr(key, value);
    }
}

impl Drop for RequestGuard {
    fn drop(&mut self) {
        self.span.end();
        if let Some((trace, recorder, scope)) = self.owned.take() {
            drop(scope); // uninstall before committing
            recorder.commit(&trace);
        }
    }
}

/// Enter a request scope (see [`RequestGuard`]).
pub fn request(name: &'static str, label: &str, recorder: &Arc<FlightRecorder>) -> RequestGuard {
    let owned = if active() {
        None
    } else {
        let trace = Trace::begin(label);
        let scope = attach(&TraceHandle::of(&trace));
        Some((trace, recorder.clone(), scope))
    };
    RequestGuard {
        span: span(name),
        owned,
    }
}

/// A committed trace, as stored in the flight recorder.
#[derive(Clone, Debug)]
pub struct FinishedTrace {
    /// Commit sequence number (1-based, process-wide per recorder) —
    /// the id histogram exemplars and Chrome export refer to.
    pub id: u64,
    /// The trace's request label.
    pub label: String,
    /// Nanoseconds from trace epoch to commit.
    pub duration_ns: u64,
    /// All closed spans, in completion order.
    pub spans: Vec<SpanRecord>,
}

impl FinishedTrace {
    /// First span with the given name, if any.
    pub fn span(&self, name: &str) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.name == name)
    }

    /// All spans with the given name.
    pub fn spans_named(&self, name: &str) -> Vec<&SpanRecord> {
        self.spans.iter().filter(|s| s.name == name).collect()
    }

    /// JSON form (used by the recorder dump and `ge-spmm stats`).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("label", s(&self.label)),
            ("duration_ns", num(self.duration_ns as f64)),
            (
                "spans",
                Json::Arr(self.spans.iter().map(|sp| sp.to_json()).collect()),
            ),
        ])
    }

    /// Append this trace's spans as Chrome trace-event begin/end pairs
    /// (`ph: "B"` / `ph: "E"`, one virtual thread per trace). Events are
    /// emitted by depth-first walk of the span tree — parents open
    /// before their children and close after them — so the stream is
    /// well-nested regardless of timestamp ties.
    fn chrome_events(&self, events: &mut Vec<Json>) {
        let tid = self.id as f64;
        events.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(1.0)),
            ("tid", num(tid)),
            (
                "args",
                obj(vec![("name", s(&format!("{}#{}", self.label, self.id)))]),
            ),
        ]));
        // span tree walk order: start time, then allocation order
        let mut order: Vec<usize> = (0..self.spans.len()).collect();
        order.sort_by_key(|&i| (self.spans[i].start_ns, self.spans[i].id));
        // iterative DFS over roots, children resolved by parent link
        let mut stack: Vec<(usize, bool)> = order
            .iter()
            .rev()
            .filter(|&&i| self.spans[i].parent == 0)
            .map(|&i| (i, false))
            .collect();
        while let Some((i, expanded)) = stack.pop() {
            let sp = &self.spans[i];
            if expanded {
                events.push(obj(vec![
                    ("name", s(sp.name)),
                    ("ph", s("E")),
                    ("pid", num(1.0)),
                    ("tid", num(tid)),
                    ("ts", num(sp.end_ns as f64 / 1000.0)),
                ]));
                continue;
            }
            let mut args: Vec<(&str, Json)> = sp.attrs.iter().map(|(k, v)| (*k, s(v))).collect();
            args.push(("trace", s(&self.label)));
            events.push(obj(vec![
                ("name", s(sp.name)),
                ("cat", s("ge-spmm")),
                ("ph", s("B")),
                ("pid", num(1.0)),
                ("tid", num(tid)),
                ("ts", num(sp.start_ns as f64 / 1000.0)),
                ("args", obj(args)),
            ]));
            stack.push((i, true));
            // children, latest-starting first so the earliest pops first
            for &c in order.iter().rev() {
                if self.spans[c].parent == sp.id {
                    stack.push((c, false));
                }
            }
        }
    }
}

/// One histogram→trace exemplar: the slowest retained trace whose total
/// duration landed in a given latency bucket, linking tail-latency
/// buckets back to a concrete recorded request.
#[derive(Clone, Debug)]
pub struct TraceExemplar {
    /// Latency bucket index (see [`super::hist::bucket_index`]).
    pub bucket: usize,
    /// Commit id of the exemplar trace.
    pub trace_id: u64,
    /// The exemplar trace's request label.
    pub label: String,
    /// The exemplar trace's total duration, ns.
    pub duration_ns: u64,
}

/// Ring buffer of the last N committed request traces.
#[derive(Debug)]
pub struct FlightRecorder {
    capacity: usize,
    committed: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<FinishedTrace>>,
}

impl FlightRecorder {
    /// Recorder keeping the last `capacity` traces (min 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            committed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total traces ever committed (monotone; the ring keeps the tail).
    pub fn committed(&self) -> u64 {
        self.committed.load(Ordering::Relaxed)
    }

    /// Traces evicted from the ring to make room for newer commits.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Move a trace's spans into the ring, evicting the oldest entry
    /// when full. One short lock per request.
    pub fn commit(&self, trace: &Arc<Trace>) {
        let duration_ns = trace.elapsed_ns();
        let spans = std::mem::take(&mut *trace.spans.lock().unwrap_or_else(|e| e.into_inner()));
        let id = self.committed.fetch_add(1, Ordering::Relaxed) + 1;
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(FinishedTrace {
            id,
            label: trace.label().to_string(),
            duration_ns,
            spans,
        });
    }

    /// Copy the recorded traces out, oldest first.
    pub fn traces(&self) -> Vec<FinishedTrace> {
        self.ring
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// Traces currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether no trace has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Full JSON dump: capacity, total committed, ring evictions, and
    /// the retained traces with their span trees.
    pub fn dump_json(&self) -> Json {
        obj(vec![
            ("capacity", num(self.capacity as f64)),
            ("committed", num(self.committed() as f64)),
            ("dropped", num(self.dropped() as f64)),
            (
                "traces",
                Json::Arr(self.traces().iter().map(|t| t.to_json()).collect()),
            ),
        ])
    }

    /// Histogram→trace exemplars over the retained traces: for every
    /// latency bucket some retained trace's total duration lands in,
    /// the slowest such trace. Links the tail buckets of the request
    /// histograms to a concrete span tree (`ge-spmm stats --traces`).
    pub fn exemplars(&self) -> Vec<TraceExemplar> {
        let mut best: std::collections::BTreeMap<usize, TraceExemplar> =
            std::collections::BTreeMap::new();
        for t in self.traces() {
            let bucket = super::hist::bucket_index(t.duration_ns);
            let replace = best
                .get(&bucket)
                .map(|e| t.duration_ns > e.duration_ns)
                .unwrap_or(true);
            if replace {
                best.insert(
                    bucket,
                    TraceExemplar {
                        bucket,
                        trace_id: t.id,
                        label: t.label.clone(),
                        duration_ns: t.duration_ns,
                    },
                );
            }
        }
        best.into_values().collect()
    }

    /// Render the retained traces as a Chrome trace-event document
    /// (`chrome://tracing` / Perfetto): one virtual thread per trace,
    /// well-nested `B`/`E` event pairs per span, and the exemplar links
    /// under `otherData`. `ge-spmm stats --traces --format chrome`
    /// prints exactly this document.
    pub fn chrome_trace_json(&self) -> Json {
        let mut events = Vec::new();
        for t in self.traces() {
            t.chrome_events(&mut events);
        }
        let exemplars = Json::Arr(
            self.exemplars()
                .iter()
                .map(|e| {
                    obj(vec![
                        ("bucket", num(e.bucket as f64)),
                        ("trace_id", num(e.trace_id as f64)),
                        ("label", s(&e.label)),
                        ("duration_ns", num(e.duration_ns as f64)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("displayTimeUnit", s("ms")),
            ("traceEvents", Json::Arr(events)),
            (
                "otherData",
                obj(vec![
                    ("committed", num(self.committed() as f64)),
                    ("dropped", num(self.dropped() as f64)),
                    ("exemplars", exemplars),
                ]),
            ),
        ])
    }
}

/// Default [`FlightRecorder`] ring capacity — the last N request traces
/// kept for inspection (`serve --trace-capacity` overrides it).
pub const DEFAULT_TRACE_CAPACITY: usize = 64;

impl Default for FlightRecorder {
    /// Recorder for the last [`DEFAULT_TRACE_CAPACITY`] requests.
    fn default() -> Self {
        Self::new(DEFAULT_TRACE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_inert_without_a_trace() {
        let mut sp = span("orphan");
        assert!(!sp.is_recording());
        sp.set_attr("k", "v");
        sp.end(); // no panic, nothing recorded anywhere
    }

    #[test]
    fn nesting_links_parents_and_restores_context() {
        let recorder = Arc::new(FlightRecorder::new(4));
        let trace = Trace::begin("t");
        {
            let _scope = attach(&TraceHandle::of(&trace));
            let outer = span("outer");
            {
                let mut inner = span("inner");
                inner.set_attr("k", 7);
            }
            drop(outer);
            let sibling = span("sibling");
            drop(sibling);
        }
        assert!(!active());
        recorder.commit(&trace);
        let traces = recorder.traces();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        let outer = t.span("outer").unwrap();
        let inner = t.span("inner").unwrap();
        let sibling = t.span("sibling").unwrap();
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.parent, outer.id);
        assert_eq!(sibling.parent, 0, "context restored after outer closed");
        assert_eq!(inner.attr("k"), Some("7"));
        assert!(inner.end_ns >= inner.start_ns);
    }

    #[test]
    fn handle_attach_carries_context_across_threads() {
        let recorder = Arc::new(FlightRecorder::new(4));
        let trace = Trace::begin("xthread");
        {
            let _scope = attach(&TraceHandle::of(&trace));
            let fan = span("fan");
            let h = handle().unwrap();
            std::thread::scope(|s| {
                s.spawn(|| {
                    let _scope = attach(&h);
                    let _sp = span("worker");
                });
            });
            drop(fan);
        }
        recorder.commit(&trace);
        let t = &recorder.traces()[0];
        let fan = t.span("fan").unwrap();
        let worker = t.span("worker").unwrap();
        assert_eq!(worker.parent, fan.id, "cross-thread span parents to fan");
    }

    #[test]
    fn request_guard_owns_and_commits_when_no_trace_is_installed() {
        let recorder = Arc::new(FlightRecorder::new(4));
        {
            let mut req = request("dispatch", "direct#1", &recorder);
            req.set_attr("op", "spmm");
            let _child = span("kernel");
        }
        assert_eq!(recorder.len(), 1);
        let t = &recorder.traces()[0];
        assert_eq!(t.label, "direct#1");
        let dispatch = t.span("dispatch").unwrap();
        assert_eq!(dispatch.attr("op"), Some("spmm"));
        assert_eq!(t.span("kernel").unwrap().parent, dispatch.id);

        // With a trace already installed, request() only adds a span.
        let outer = Trace::begin("outer");
        {
            let _scope = attach(&TraceHandle::of(&outer));
            let _req = request("dispatch", "ignored", &recorder);
        }
        assert_eq!(recorder.len(), 1, "no second commit for nested request");
        assert_eq!(outer.span_count(), 1);
    }

    #[test]
    fn ring_wraps_keeping_the_newest() {
        let recorder = Arc::new(FlightRecorder::new(3));
        for i in 0..7 {
            let trace = Trace::begin(format!("t{i}"));
            trace.record_raw("noop", 0, 1, vec![]);
            recorder.commit(&trace);
        }
        assert_eq!(recorder.len(), 3);
        assert_eq!(recorder.committed(), 7);
        assert_eq!(recorder.dropped(), 4, "evictions counted");
        let labels: Vec<_> = recorder.traces().iter().map(|t| t.label.clone()).collect();
        assert_eq!(labels, ["t4", "t5", "t6"]);
        let ids: Vec<_> = recorder.traces().iter().map(|t| t.id).collect();
        assert_eq!(ids, [5, 6, 7], "commit ids are 1-based and monotone");
        let dump = recorder.dump_json();
        assert_eq!(dump.get("committed").and_then(|j| j.as_usize()), Some(7));
        assert_eq!(dump.get("dropped").and_then(|j| j.as_usize()), Some(4));
        assert_eq!(dump.get("traces").and_then(|j| j.as_arr()).unwrap().len(), 3);
    }

    #[test]
    fn exemplars_pick_the_slowest_trace_per_bucket() {
        let recorder = Arc::new(FlightRecorder::new(8));
        // record_raw keeps the span lists non-empty; duration comes from
        // the trace epoch, so give the slow trace real elapsed time
        for label in ["fast1", "fast2"] {
            let t = Trace::begin(label);
            t.record_raw("noop", 0, 1, vec![]);
            recorder.commit(&t);
        }
        let slow = Trace::begin("slow");
        slow.record_raw("noop", 0, 1, vec![]);
        std::thread::sleep(std::time::Duration::from_millis(5));
        recorder.commit(&slow);
        let ex = recorder.exemplars();
        assert!(!ex.is_empty());
        // the slowest trace overall must be some bucket's exemplar
        let slowest = ex.iter().max_by_key(|e| e.duration_ns).unwrap();
        assert_eq!(slowest.label, "slow");
        assert_eq!(slowest.trace_id, 3);
        assert_eq!(slowest.bucket, super::super::hist::bucket_index(slowest.duration_ns));
        // buckets are unique and ordered
        let buckets: Vec<_> = ex.iter().map(|e| e.bucket).collect();
        let mut sorted = buckets.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(buckets, sorted);
    }

    #[test]
    fn chrome_export_is_well_nested() {
        let recorder = Arc::new(FlightRecorder::new(4));
        let trace = Trace::begin("chrome#1");
        {
            let _scope = attach(&TraceHandle::of(&trace));
            let _outer = span("outer");
            {
                let mut inner = span("inner");
                inner.set_attr("k", "v");
            }
            let _second = span("second");
        }
        recorder.commit(&trace);
        let doc = recorder.chrome_trace_json();
        // valid JSON that round-trips
        let text = doc.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        let events = doc.get("traceEvents").and_then(|j| j.as_arr()).unwrap();
        // per trace: 1 metadata + B/E per span
        assert_eq!(events.len(), 1 + 2 * 3);
        // begin/end events are stack-disciplined per tid
        let mut depth = 0i64;
        for ev in events {
            match ev.get("ph").and_then(|p| p.as_str()) {
                Some("B") => depth += 1,
                Some("E") => {
                    depth -= 1;
                    assert!(depth >= 0, "E without matching B");
                }
                Some("M") => {}
                other => panic!("unexpected ph {other:?}"),
            }
        }
        assert_eq!(depth, 0, "every B closed");
        // outer opens before its children, and closes after both
        // (`second` opened while `outer` was still the innermost span)
        let names: Vec<_> = events
            .iter()
            .filter_map(|e| {
                let ph = e.get("ph")?.as_str()?;
                let name = e.get("name")?.as_str()?;
                (ph != "M").then(|| format!("{ph}:{name}"))
            })
            .collect();
        assert_eq!(
            names,
            ["B:outer", "B:inner", "E:inner", "B:second", "E:second", "E:outer"]
        );
        // exemplars ride along under otherData
        let other = doc.get("otherData").unwrap();
        assert_eq!(other.get("committed").and_then(|j| j.as_usize()), Some(1));
        assert!(other.get("exemplars").and_then(|j| j.as_arr()).is_some());
    }
}
