//! Serving SLO monitors: rolling-window burn rates over latency and
//! queue-depth objectives.
//!
//! `ge-spmm serve --slo p99=2ms,queue=128` declares objectives; the
//! server reports every completed request's wall latency and the queue
//! depth it was admitted at into an [`SloMonitor`], which maintains a
//! rolling window (default 60 s, six 10 s slices) of breach counts per
//! objective. A latency objective `pXX<t` grants an error budget of
//! `1 − XX/100` — e.g. `p99=2ms` tolerates 1% of requests over 2 ms —
//! and the **burn rate** is the observed breach fraction divided by
//! that budget: burn 1.0 means the budget is being spent exactly as
//! fast as it accrues, above 1.0 the objective is breaching. Queue
//! objectives budget 1% of admissions above the target depth. The
//! report surfaces in the stats snapshot, the Prometheus exposition
//! (`ge_spmm_slo_*`), and a one-line health summary. See DESIGN.md
//! §Observability.

use crate::util::json::{self, Json};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default rolling window the burn rates are evaluated over.
pub const DEFAULT_WINDOW: Duration = Duration::from_secs(60);
/// Slices the window is divided into (breach counts age out per slice).
const SLICES: u32 = 6;

/// Error budgets: the tolerated breach fraction per objective kind.
const P50_BUDGET: f64 = 0.50;
const P90_BUDGET: f64 = 0.10;
const P99_BUDGET: f64 = 0.01;
const QUEUE_BUDGET: f64 = 0.01;

/// Parsed SLO objectives (from `serve --slo p99=2ms,queue=128`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SloSpec {
    /// Median-latency target.
    pub p50: Option<Duration>,
    /// 90th-percentile latency target.
    pub p90: Option<Duration>,
    /// 99th-percentile latency target.
    pub p99: Option<Duration>,
    /// Queue-depth target (admission depth must stay at or below this).
    pub queue: Option<u64>,
    /// Rolling-window override (`window=30s`); [`DEFAULT_WINDOW`] when
    /// absent.
    pub window: Option<Duration>,
}

impl SloSpec {
    /// Parse a comma-separated objective list: `p50`/`p90`/`p99` with a
    /// duration value (`ns`/`us`/`ms`/`s` suffix), `queue` with a depth,
    /// `window` with a duration. At least one objective is required.
    pub fn parse(text: &str) -> Result<SloSpec, String> {
        let mut spec = SloSpec::default();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("SLO term '{part}' is not key=value"))?;
            match key.trim() {
                "p50" => spec.p50 = Some(parse_duration(value)?),
                "p90" => spec.p90 = Some(parse_duration(value)?),
                "p99" => spec.p99 = Some(parse_duration(value)?),
                "queue" => {
                    spec.queue = Some(
                        value
                            .trim()
                            .parse::<u64>()
                            .map_err(|e| format!("SLO queue depth '{value}': {e}"))?,
                    )
                }
                "window" => spec.window = Some(parse_duration(value)?),
                other => return Err(format!("unknown SLO objective '{other}'")),
            }
        }
        if spec.is_empty() {
            return Err("SLO spec declares no objectives (try p99=2ms,queue=128)".to_string());
        }
        Ok(spec)
    }

    /// Whether no objective is set (`window` alone does not count).
    pub fn is_empty(&self) -> bool {
        self.p50.is_none() && self.p90.is_none() && self.p99.is_none() && self.queue.is_none()
    }

    /// Compact human rendering, e.g. `p99<2ms,queue<=128`.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for (name, t) in [("p50", self.p50), ("p90", self.p90), ("p99", self.p99)] {
            if let Some(t) = t {
                parts.push(format!("{name}<{}", format_duration(t)));
            }
        }
        if let Some(q) = self.queue {
            parts.push(format!("queue<={q}"));
        }
        parts.join(",")
    }
}

/// Parse a duration literal with an explicit unit suffix
/// (`250ns`, `80us`, `1.5ms`, `2s`).
pub fn parse_duration(text: &str) -> Result<Duration, String> {
    let t = text.trim();
    let (digits, factor) = if let Some(d) = t.strip_suffix("ns") {
        (d, 1e-9)
    } else if let Some(d) = t.strip_suffix("us") {
        (d, 1e-6)
    } else if let Some(d) = t.strip_suffix("ms") {
        (d, 1e-3)
    } else if let Some(d) = t.strip_suffix('s') {
        (d, 1.0)
    } else {
        return Err(format!("duration '{t}' needs a ns/us/ms/s suffix"));
    };
    let value: f64 = digits
        .trim()
        .parse()
        .map_err(|e| format!("duration '{t}': {e}"))?;
    if !value.is_finite() || value <= 0.0 {
        return Err(format!("duration '{t}' must be positive"));
    }
    Ok(Duration::from_secs_f64(value * factor))
}

/// Render a duration the way the parser accepts it.
fn format_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns % 1_000_000_000 == 0 {
        format!("{}s", ns / 1_000_000_000)
    } else if ns % 1_000_000 == 0 {
        format!("{}ms", ns / 1_000_000)
    } else if ns % 1_000 == 0 {
        format!("{}us", ns / 1_000)
    } else {
        format!("{ns}ns")
    }
}

/// One window slice: breach counts since `started`.
#[derive(Debug)]
struct Slice {
    started: Instant,
    total: u64,
    /// Latency breaches, indexed like the monitor's `latency_targets`.
    over: [u64; 3],
    queue_over: u64,
}

impl Slice {
    fn new(started: Instant) -> Self {
        Self {
            started,
            total: 0,
            over: [0; 3],
            queue_over: 0,
        }
    }
}

/// Rolling-window SLO evaluator. One instance per serving process,
/// installed on [`Metrics`](crate::coordinator::metrics::Metrics) so
/// the exposition layer can reach it.
#[derive(Debug)]
pub struct SloMonitor {
    spec: SloSpec,
    window: Duration,
    slice_len: Duration,
    slices: Mutex<VecDeque<Slice>>,
    observed: AtomicU64,
}

/// One objective's view in an [`SloReport`].
#[derive(Clone, Debug)]
pub struct SloObjective {
    /// Objective name: `p50`, `p90`, `p99`, or `queue`.
    pub name: &'static str,
    /// The target: latency nanoseconds, or queue depth.
    pub target: u64,
    /// Error budget (tolerated breach fraction).
    pub budget: f64,
    /// Requests that breached the target inside the window.
    pub breaches: u64,
    /// Burn rate: breach fraction / budget (1.0 = budget exhausted at
    /// exactly its accrual rate).
    pub burn_rate: f64,
    /// Whether the burn rate exceeds 1.0.
    pub breaching: bool,
}

/// Snapshot of the monitor over its live window.
#[derive(Clone, Debug)]
pub struct SloReport {
    /// The window the counts cover.
    pub window: Duration,
    /// Requests observed inside the window.
    pub total: u64,
    /// Requests observed over the monitor's lifetime.
    pub observed: u64,
    /// Per-objective burn rates.
    pub objectives: Vec<SloObjective>,
}

impl SloMonitor {
    /// Build a monitor over the spec's window ([`DEFAULT_WINDOW`] when
    /// unset).
    pub fn new(spec: SloSpec) -> Self {
        let window = spec.window.unwrap_or(DEFAULT_WINDOW).max(Duration::from_millis(6));
        Self {
            spec,
            window,
            slice_len: window / SLICES,
            slices: Mutex::new(VecDeque::new()),
            observed: AtomicU64::new(0),
        }
    }

    /// The objectives this monitor evaluates.
    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }

    /// Requests observed over the monitor's lifetime.
    pub fn observed(&self) -> u64 {
        self.observed.load(Ordering::Relaxed)
    }

    /// Report one completed request: its wall latency and the queue
    /// depth it was admitted at.
    pub fn observe(&self, latency: Duration, queue_depth: usize) {
        self.observed.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let mut slices = self.slices.lock().unwrap();
        self.prune(&mut slices, now);
        let open_new = match slices.back() {
            Some(back) => now.duration_since(back.started) >= self.slice_len,
            None => true,
        };
        if open_new {
            slices.push_back(Slice::new(now));
        }
        let slice = slices.back_mut().expect("slice just ensured");
        slice.total += 1;
        let targets = [self.spec.p50, self.spec.p90, self.spec.p99];
        for (i, t) in targets.iter().enumerate() {
            if let Some(t) = t {
                if latency > *t {
                    slice.over[i] += 1;
                }
            }
        }
        if let Some(q) = self.spec.queue {
            if queue_depth as u64 > q {
                slice.queue_over += 1;
            }
        }
    }

    /// Drop slices that have aged out of the window.
    fn prune(&self, slices: &mut VecDeque<Slice>, now: Instant) {
        while let Some(front) = slices.front() {
            if now.duration_since(front.started) > self.window {
                slices.pop_front();
            } else {
                break;
            }
        }
    }

    /// Evaluate the burn rates over the live window.
    pub fn report(&self) -> SloReport {
        let now = Instant::now();
        let mut slices = self.slices.lock().unwrap();
        self.prune(&mut slices, now);
        let mut total = 0u64;
        let mut over = [0u64; 3];
        let mut queue_over = 0u64;
        for s in slices.iter() {
            total += s.total;
            for (acc, o) in over.iter_mut().zip(&s.over) {
                *acc += o;
            }
            queue_over += s.queue_over;
        }
        drop(slices);
        let mut objectives = Vec::new();
        let latency = [
            ("p50", self.spec.p50, P50_BUDGET, over[0]),
            ("p90", self.spec.p90, P90_BUDGET, over[1]),
            ("p99", self.spec.p99, P99_BUDGET, over[2]),
        ];
        for (name, target, budget, breaches) in latency {
            if let Some(t) = target {
                objectives.push(objective(name, t.as_nanos() as u64, budget, breaches, total));
            }
        }
        if let Some(q) = self.spec.queue {
            objectives.push(objective("queue", q, QUEUE_BUDGET, queue_over, total));
        }
        SloReport {
            window: self.window,
            total,
            observed: self.observed(),
            objectives,
        }
    }
}

/// Assemble one objective row from its window counts.
fn objective(
    name: &'static str,
    target: u64,
    budget: f64,
    breaches: u64,
    total: u64,
) -> SloObjective {
    let fraction = if total == 0 {
        0.0
    } else {
        breaches as f64 / total as f64
    };
    let burn_rate = fraction / budget;
    SloObjective {
        name,
        target,
        budget,
        breaches,
        burn_rate,
        breaching: burn_rate > 1.0,
    }
}

impl SloReport {
    /// Whether every objective is inside its budget.
    pub fn healthy(&self) -> bool {
        self.objectives.iter().all(|o| !o.breaching)
    }

    /// One-line health summary for logs and `ge-spmm stats`.
    pub fn health_line(&self) -> String {
        let state = if self.healthy() { "HEALTHY" } else { "BREACHING" };
        let parts: Vec<String> = self
            .objectives
            .iter()
            .map(|o| {
                let target = if o.name == "queue" {
                    format!("<={}", o.target)
                } else {
                    format!("<{}", format_duration(Duration::from_nanos(o.target)))
                };
                format!(
                    "{}{} burn={:.2}{}",
                    o.name,
                    target,
                    o.burn_rate,
                    if o.breaching { "!" } else { "" }
                )
            })
            .collect();
        format!(
            "slo {} (window {}, {} requests): {}",
            state,
            format_duration(self.window),
            self.total,
            parts.join("; ")
        )
    }

    /// JSON rendering used by the stats snapshot.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("window_ms", json::num(self.window.as_secs_f64() * 1e3)),
            ("total", json::num(self.total as f64)),
            ("observed", json::num(self.observed as f64)),
            ("healthy", Json::Bool(self.healthy())),
            (
                "objectives",
                Json::Arr(
                    self.objectives
                        .iter()
                        .map(|o| {
                            json::obj(vec![
                                ("name", json::s(o.name)),
                                ("target", json::num(o.target as f64)),
                                ("budget", json::num(o.budget)),
                                ("breaches", json::num(o.breaches as f64)),
                                ("burn_rate", json::num(o.burn_rate)),
                                ("breaching", Json::Bool(o.breaching)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(spec: &str) -> SloMonitor {
        let mut spec = SloSpec::parse(spec).unwrap();
        // a huge window so tests never race slice expiry
        spec.window = Some(Duration::from_secs(3600));
        SloMonitor::new(spec)
    }

    #[test]
    fn parses_the_issue_example() {
        let spec = SloSpec::parse("p99=2ms,queue=128").unwrap();
        assert_eq!(spec.p99, Some(Duration::from_millis(2)));
        assert_eq!(spec.queue, Some(128));
        assert_eq!(spec.p50, None);
        assert_eq!(spec.summary(), "p99<2ms,queue<=128");
        assert_eq!(
            SloSpec::parse("p50=500us,p90=1ms,window=30s").unwrap().window,
            Some(Duration::from_secs(30))
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(SloSpec::parse("p99").is_err(), "no value");
        assert!(SloSpec::parse("p42=1ms").is_err(), "unknown objective");
        assert!(SloSpec::parse("p99=2").is_err(), "missing unit");
        assert!(SloSpec::parse("p99=-1ms").is_err(), "negative");
        assert!(SloSpec::parse("queue=many").is_err(), "non-numeric depth");
        assert!(SloSpec::parse("window=60s").is_err(), "no objectives");
    }

    #[test]
    fn healthy_traffic_stays_healthy() {
        let m = monitor("p99=2ms,queue=128");
        for _ in 0..100 {
            m.observe(Duration::from_micros(100), 1);
        }
        let r = m.report();
        assert_eq!(r.total, 100);
        assert!(r.healthy());
        assert_eq!(r.objectives.len(), 2);
        assert_eq!(r.objectives[0].burn_rate, 0.0);
        assert!(r.health_line().contains("HEALTHY"), "{}", r.health_line());
    }

    #[test]
    fn burn_rate_state_flips_on_an_induced_latency_breach() {
        let m = monitor("p99=1ms");
        // 2% of traffic over a 1% budget: burn rate 2.0 -> breaching
        for i in 0..100 {
            let lat = if i % 50 == 0 {
                Duration::from_millis(5)
            } else {
                Duration::from_micros(200)
            };
            m.observe(lat, 0);
        }
        let r = m.report();
        assert_eq!(r.total, 100);
        let p99 = &r.objectives[0];
        assert_eq!(p99.breaches, 2);
        assert!((p99.burn_rate - 2.0).abs() < 1e-9, "{}", p99.burn_rate);
        assert!(p99.breaching);
        assert!(!r.healthy());
        assert!(r.health_line().contains("BREACHING"), "{}", r.health_line());
    }

    #[test]
    fn queue_objective_counts_admission_depth() {
        let m = monitor("queue=4");
        for depth in 0..10 {
            m.observe(Duration::from_micros(50), depth);
        }
        let r = m.report();
        let q = &r.objectives[0];
        assert_eq!(q.name, "queue");
        assert_eq!(q.breaches, 5, "depths 5..=9 breach");
        assert!(q.breaching, "50% over a 1% budget");
    }

    #[test]
    fn report_json_round_trips() {
        let m = monitor("p99=1ms,queue=8");
        m.observe(Duration::from_millis(5), 20);
        let j = m.report().to_json();
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
        assert_eq!(j.get("healthy"), Some(&Json::Bool(false)));
    }

    #[test]
    fn slices_age_out_of_a_tiny_window() {
        let spec = SloSpec {
            p99: Some(Duration::from_millis(1)),
            window: Some(Duration::from_millis(6)),
            ..SloSpec::default()
        };
        let m = SloMonitor::new(spec);
        m.observe(Duration::from_millis(5), 0);
        assert_eq!(m.report().total, 1);
        std::thread::sleep(Duration::from_millis(20));
        let r = m.report();
        assert_eq!(r.total, 0, "breach aged out");
        assert!(r.healthy());
        assert_eq!(r.observed, 1, "lifetime counter keeps it");
    }
}
