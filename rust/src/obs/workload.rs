//! Analytic roofline workload accounting.
//!
//! Every kernel execution has a *knowable* work profile: the flop count
//! is `2·nnz·width` multiply-adds regardless of variant, and the bytes a
//! variant moves follow mechanically from its access pattern — CSR
//! streams for the row-split families, padded segment streams for the
//! workload-balanced ones, dense-row loads repeated per lane-tile pass,
//! and the output writes of its reduction style. [`estimate`] derives
//! that profile from a [`KernelVariant`] descriptor with pure integer
//! arithmetic, so tests can assert the counters exactly and the stats
//! renderer can report achieved GFLOP/s, GB/s and arithmetic intensity
//! per `(op, variant)` without hardware counters ("Design Principles for
//! Sparse Matrix Multiplication on the GPU", Yang et al., frames kernel
//! choice in exactly these roofline terms: work, traffic, balance).
//!
//! The model, per execution of `variant` over `(rows, nnz)` at dense
//! width `width` (`n` for SpMM, `d` for SDDMM):
//!
//! - **flops** = `2·nnz·width` (one multiply + one add per stored
//!   nonzero per lane).
//! - **sparse stream** (read once per lane-tile pass, i.e.
//!   `ceil(width / lane_tile)` times — the tiled loops re-walk the
//!   sparse structure for every tile of lanes):
//!   - row-split families: `(rows + 1)·4` row-pointer bytes plus
//!     `nnz·(4 + 4)` column-index and value bytes; the merge-path
//!     traversal re-reads the row pointers once more per pass for its
//!     path search;
//!   - balanced families: the padded segment stream —
//!     `ceil(nnz / seg_len)·seg_len` slots of 12 bytes each (value +
//!     column + row); the slots past `nnz` are counted again as
//!     [`WorkloadEstimate::padding_bytes`] waste.
//! - **dense loads** = `nnz·width·4` for SpMM (one `x` row slice per
//!   nonzero) and `2·nnz·width·4` for SDDMM (`u` and `v` slices).
//!   Summed over lane-tile passes this is exact, not per-pass.
//! - **output writes** = `rows·width·4` (SpMM) or `nnz·4` (SDDMM), plus
//!   one partial-accumulator flush per segment for the balanced
//!   families (`ceil(nnz / seg_len)·width·4` SpMM / `·4` SDDMM).
//!
//! Accumulated per registry variant in
//! [`Metrics`](crate::coordinator::metrics::Metrics) banks at the grain
//! that executed (request-level native dispatch, or per shard inside
//! the sharded backend), and rendered by `ge-spmm stats`. See DESIGN.md
//! §Observability.

use crate::kernels::{KernelVariant, SparseOp, Traversal};

/// Bytes per dense element / sparse value (`f32`).
const VAL_BYTES: u64 = 4;
/// Bytes per sparse index (`u32`).
const IDX_BYTES: u64 = 4;
/// Bytes per padded segment slot: value + column index + row index.
const SEG_SLOT_BYTES: u64 = 12;

/// Analytic per-execution workload profile. All fields are derived with
/// integer arithmetic from the variant descriptor and the matrix shape,
/// so equal inputs always produce equal counters (tests assert them
/// exactly).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkloadEstimate {
    /// Floating-point operations: `2·nnz·width` multiply-add pairs.
    pub flops: u64,
    /// Bytes read: sparse streams (once per lane-tile pass) plus dense
    /// operand loads.
    pub bytes_read: u64,
    /// Bytes written: output rows/entries plus balanced-family partial
    /// flushes.
    pub bytes_written: u64,
    /// The waste inside [`WorkloadEstimate::bytes_read`]: padded segment
    /// slots the balanced families stream past without doing work.
    pub padding_bytes: u64,
    /// Rows covered by the execution.
    pub rows: u64,
    /// Stored nonzeros covered by the execution.
    pub nnz: u64,
}

impl WorkloadEstimate {
    /// Total bytes moved (reads plus writes).
    pub fn bytes_total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Arithmetic intensity: flops per byte moved.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops as f64 / self.bytes_total().max(1) as f64
    }

    /// Element-wise accumulate, for rolling shard estimates up into a
    /// request-level view.
    pub fn accumulate(&mut self, other: &WorkloadEstimate) {
        self.flops += other.flops;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
        self.padding_bytes += other.padding_bytes;
        self.rows += other.rows;
        self.nnz += other.nnz;
    }
}

/// Derive the analytic workload profile of one execution of `variant`
/// over a `(rows, nnz)` sparse operand at dense width `width` (`n` for
/// SpMM, `d` for SDDMM). See the module docs for the exact model.
pub fn estimate(variant: &KernelVariant, rows: usize, nnz: usize, width: usize) -> WorkloadEstimate {
    let rows64 = rows as u64;
    let nnz64 = nnz as u64;
    let width64 = width.max(1) as u64;
    let tile = variant.lane_tile.max(1) as u64;
    let passes = width64.div_ceil(tile);
    let (sparse_pass, padding_pass, segments) = if variant.family.is_balanced() {
        let seg = variant.seg_len.max(1) as u64;
        let segments = nnz64.div_ceil(seg);
        let slots = segments * seg;
        (
            slots * SEG_SLOT_BYTES,
            (slots - nnz64) * SEG_SLOT_BYTES,
            segments,
        )
    } else {
        let mut bytes = (rows64 + 1) * IDX_BYTES + nnz64 * (IDX_BYTES + VAL_BYTES);
        if variant.traversal == Traversal::MergePath {
            bytes += (rows64 + 1) * IDX_BYTES;
        }
        (bytes, 0, 0)
    };
    let (dense_operands, output, partial_unit) = match variant.op {
        SparseOp::Spmm => (1, rows64 * width64 * VAL_BYTES, width64 * VAL_BYTES),
        SparseOp::Sddmm => (2, nnz64 * VAL_BYTES, VAL_BYTES),
    };
    WorkloadEstimate {
        flops: 2 * nnz64 * width64,
        bytes_read: sparse_pass * passes + dense_operands * nnz64 * width64 * VAL_BYTES,
        bytes_written: output + segments * partial_unit,
        padding_bytes: padding_pass * passes,
        rows: rows64,
        nnz: nnz64,
    }
}

/// Accumulated workload totals for one variant bank, paired with the
/// wall time attributed to those executions so achieved rates fall out:
/// `flops / ns` *is* GFLOP/s and `bytes / ns` *is* GB/s.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkloadTotals {
    /// Executions accumulated into this bank.
    pub execs: u64,
    /// Wall nanoseconds attributed to those executions.
    pub ns: u64,
    /// Accumulated flops.
    pub flops: u64,
    /// Accumulated bytes read.
    pub bytes_read: u64,
    /// Accumulated bytes written.
    pub bytes_written: u64,
    /// Accumulated segment-padding waste bytes.
    pub padding_bytes: u64,
    /// Accumulated rows processed.
    pub rows: u64,
    /// Accumulated nonzeros processed.
    pub nnz: u64,
}

impl WorkloadTotals {
    /// Total bytes moved (reads plus writes).
    pub fn bytes_total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Achieved GFLOP/s over the attributed wall time (0 when idle).
    pub fn achieved_gflops(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.flops as f64 / self.ns as f64
        }
    }

    /// Achieved GB/s over the attributed wall time (0 when idle).
    pub fn achieved_gbps(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.bytes_total() as f64 / self.ns as f64
        }
    }

    /// Arithmetic intensity of the accumulated work: flops per byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops as f64 / self.bytes_total().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;

    // Fixture shape shared by the hand computations below.
    const ROWS: usize = 4;
    const NNZ: usize = 10;
    const N: usize = 8;

    #[test]
    fn spmm_row_split_canonical_matches_hand_computation() {
        // sr_rs canonical: lane_tile = 8 -> one pass over n = 8.
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs);
        let w = estimate(&v, ROWS, NNZ, N);
        assert_eq!(w.flops, 160); // 2 * 10 * 8
        // sparse: (4+1)*4 indptr + 10*8 idx+val = 100; dense: 10*8*4 = 320
        assert_eq!(w.bytes_read, 420);
        assert_eq!(w.bytes_written, 128); // 4 * 8 * 4
        assert_eq!(w.padding_bytes, 0);
        assert_eq!((w.rows, w.nnz), (4, 10));
        assert_eq!(w.bytes_total(), 548);
        // pr_rs shares the layout, so it shares the byte model.
        let pr = KernelVariant::canonical(SparseOp::Spmm, KernelKind::PrRs);
        assert_eq!(estimate(&pr, ROWS, NNZ, N), w);
    }

    #[test]
    fn lane_tiling_rereads_the_sparse_stream_per_pass() {
        let base = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs);
        // t1: 8 passes -> sparse stream read 8 times.
        let w1 = estimate(&base.with_lane_tile(1), ROWS, NNZ, N);
        assert_eq!(w1.bytes_read, 100 * 8 + 320);
        // t4: 2 passes.
        let w4 = estimate(&base.with_lane_tile(4), ROWS, NNZ, N);
        assert_eq!(w4.bytes_read, 100 * 2 + 320);
        // flops and writes are tiling-invariant.
        assert_eq!(w1.flops, 160);
        assert_eq!(w4.bytes_written, 128);
    }

    #[test]
    fn merge_path_rereads_the_row_pointers() {
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs)
            .with_traversal(Traversal::MergePath);
        let w = estimate(&v, ROWS, NNZ, N);
        // one pass: 100 + extra (4+1)*4 = 120 sparse, + 320 dense
        assert_eq!(w.bytes_read, 440);
    }

    #[test]
    fn spmm_balanced_canonical_counts_segment_padding() {
        // sr_wb canonical: seg_len = 32 -> one 32-slot segment for 10 nnz.
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrWb);
        let w = estimate(&v, ROWS, NNZ, N);
        assert_eq!(w.flops, 160);
        // sparse: 32 * 12 = 384 (one pass); dense 320
        assert_eq!(w.bytes_read, 704);
        assert_eq!(w.padding_bytes, 22 * 12);
        // output 128 + one segment partial flush 8*4 = 32
        assert_eq!(w.bytes_written, 160);
        let pr = KernelVariant::canonical(SparseOp::Spmm, KernelKind::PrWb);
        assert_eq!(estimate(&pr, ROWS, NNZ, N), w);
    }

    #[test]
    fn short_segments_waste_less_but_flush_more() {
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrWb).with_seg_len(16);
        let w = estimate(&v, ROWS, NNZ, N);
        // one 16-slot segment: 16*12 = 192 sparse, 6 padded slots
        assert_eq!(w.bytes_read, 192 + 320);
        assert_eq!(w.padding_bytes, 6 * 12);
        assert_eq!(w.bytes_written, 128 + 32);
        // seg_len = 64: more padding, same single flush
        let w64 = estimate(&v.with_seg_len(64), ROWS, NNZ, N);
        assert_eq!(w64.padding_bytes, 54 * 12);
    }

    #[test]
    fn sddmm_canonicals_match_hand_computation() {
        const D: usize = 8;
        let rs = KernelVariant::canonical(SparseOp::Sddmm, KernelKind::SrRs);
        let w = estimate(&rs, ROWS, NNZ, D);
        assert_eq!(w.flops, 160);
        // sparse 100 (one pass) + dense 2*10*8*4 = 640
        assert_eq!(w.bytes_read, 740);
        assert_eq!(w.bytes_written, 40); // one f32 per nonzero
        let wb = KernelVariant::canonical(SparseOp::Sddmm, KernelKind::PrWb);
        let ww = estimate(&wb, ROWS, NNZ, D);
        assert_eq!(ww.bytes_read, 384 + 640);
        assert_eq!(ww.bytes_written, 40 + 4); // + one scalar partial flush
        assert_eq!(ww.padding_bytes, 22 * 12);
    }

    #[test]
    fn degenerate_shapes_stay_finite() {
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrWb);
        let empty = estimate(&v, 0, 0, 0);
        assert_eq!(empty.flops, 0);
        assert_eq!(empty.padding_bytes, 0);
        assert_eq!(empty.bytes_read, 0);
        assert!(empty.arithmetic_intensity() == 0.0);
        let zero_width = estimate(&v, ROWS, NNZ, 0);
        // width clamps to 1 lane
        assert_eq!(zero_width.flops, 20);
    }

    #[test]
    fn totals_rates_fall_out_of_the_units() {
        let t = WorkloadTotals {
            execs: 2,
            ns: 1_000,
            flops: 4_000,
            bytes_read: 1_500,
            bytes_written: 500,
            padding_bytes: 100,
            rows: 8,
            nnz: 20,
        };
        assert!((t.achieved_gflops() - 4.0).abs() < 1e-12);
        assert!((t.achieved_gbps() - 2.0).abs() < 1e-12);
        assert!((t.arithmetic_intensity() - 2.0).abs() < 1e-12);
        assert_eq!(WorkloadTotals::default().achieved_gflops(), 0.0);
    }

    #[test]
    fn accumulate_sums_every_counter() {
        let v = KernelVariant::canonical(SparseOp::Spmm, KernelKind::SrRs);
        let mut acc = estimate(&v, ROWS, NNZ, N);
        let one = acc;
        acc.accumulate(&one);
        assert_eq!(acc.flops, 2 * one.flops);
        assert_eq!(acc.bytes_total(), 2 * one.bytes_total());
        assert_eq!(acc.nnz, 2 * one.nnz);
    }
}
