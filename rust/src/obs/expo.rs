//! Exposition: render `Metrics` (counters, histogram banks, audit,
//! flight recorder) as a JSON snapshot or Prometheus text.
//!
//! Two entry points, one schema: [`snapshot`] turns a live
//! [`Metrics`] into a [`Json`] document, and [`prometheus_of`] renders
//! *any* such document — live or re-read from a `--stats-file` dump —
//! as Prometheus exposition text. `ge-spmm stats` and
//! `ge-spmm serve --stats-every/--stats-file` both go through here, so
//! a snapshot written to disk re-renders identically to a live one.
//!
//! Metric names are prefixed `ge_spmm_`; per-kernel series carry
//! `op`/`grain`/`kernel` labels (and `quantile` for latency), matching
//! the op × grain × kernel histogram banks in
//! [`Metrics::latency_histogram`]. Label values are escaped per the
//! exposition-format rules (backslash, double quote, newline) at every
//! interpolation site. Snapshots additionally carry the roofline
//! workload banks (`workload`), the selector-regret report (`regret`)
//! and, when a monitor is installed, the serving SLO report (`slo`);
//! [`prometheus_of`] tolerates documents missing any of the optional
//! sections so older `--stats-file` dumps still render.

use crate::coordinator::metrics::Metrics;
use crate::kernels::{registry, KernelKind, SparseOp};
use crate::obs::Grain;
use crate::util::json::{num, obj, s, Json};

/// Quantiles every latency series is rendered at.
pub const QUANTILES: [f64; 3] = [0.5, 0.9, 0.99];

/// Scalar counters: snapshot key, metric name, Prometheus type, help.
const COUNTERS: [(&str, &str, &str, &str); 11] = [
    (
        "requests",
        "ge_spmm_requests_total",
        "counter",
        "Completed SpMM requests.",
    ),
    (
        "errors",
        "ge_spmm_errors_total",
        "counter",
        "Failed requests.",
    ),
    (
        "sddmm_requests",
        "ge_spmm_sddmm_requests_total",
        "counter",
        "Completed SDDMM requests.",
    ),
    (
        "shard_executions",
        "ge_spmm_shard_executions_total",
        "counter",
        "SpMM shard executions inside sharded requests.",
    ),
    (
        "sddmm_shard_executions",
        "ge_spmm_sddmm_shard_executions_total",
        "counter",
        "SDDMM shard executions inside sharded requests.",
    ),
    (
        "cache_hits",
        "ge_spmm_cache_hits_total",
        "counter",
        "Prepared-matrix cache hits.",
    ),
    (
        "cache_misses",
        "ge_spmm_cache_misses_total",
        "counter",
        "Prepared-matrix cache misses.",
    ),
    (
        "cache_evictions",
        "ge_spmm_cache_evictions_total",
        "counter",
        "Prepared-matrix cache evictions.",
    ),
    (
        "rejections",
        "ge_spmm_rejections_total",
        "counter",
        "Requests refused at admission.",
    ),
    (
        "max_queue_depth",
        "ge_spmm_max_queue_depth",
        "gauge",
        "High-water mark of in-flight requests at admission.",
    ),
    (
        "cost_observations",
        "ge_spmm_cost_observations_total",
        "counter",
        "Normalized-cost observations feeding the online selector.",
    ),
];

/// Snapshot the full observability state of a [`Metrics`] hub as JSON:
/// scalar counters, one latency/selection row per op × grain × kernel,
/// the selector audit log, and flight-recorder totals.
pub fn snapshot(m: &Metrics) -> Json {
    let counters = obj(vec![
        ("requests", num(m.requests() as f64)),
        ("errors", num(m.errors() as f64)),
        ("sddmm_requests", num(m.sddmm_requests() as f64)),
        ("shard_executions", num(m.shard_executions() as f64)),
        (
            "sddmm_shard_executions",
            num(m.sddmm_shard_executions() as f64),
        ),
        ("cache_hits", num(m.cache_hits() as f64)),
        ("cache_misses", num(m.cache_misses() as f64)),
        ("cache_evictions", num(m.cache_evictions() as f64)),
        ("rejections", num(m.rejections() as f64)),
        ("max_queue_depth", num(m.max_queue_depth() as f64)),
        (
            "cost_observations",
            num(m.total_cost_observations() as f64),
        ),
    ]);

    let mut kernels = Vec::new();
    for op in [SparseOp::Spmm, SparseOp::Sddmm] {
        for grain in Grain::ALL {
            let selected = match (op, grain) {
                (SparseOp::Spmm, Grain::Request) => m.kernel_counts(),
                (SparseOp::Spmm, Grain::Shard) => m.shard_kernel_counts(),
                (SparseOp::Sddmm, Grain::Request) => m.sddmm_kernel_counts(),
                (SparseOp::Sddmm, Grain::Shard) => m.sddmm_shard_kernel_counts(),
            };
            for (i, kernel) in KernelKind::ALL.iter().enumerate() {
                let snap = m.latency_histogram(op, grain, *kernel);
                kernels.push(obj(vec![
                    ("op", s(op.label())),
                    ("grain", s(grain.label())),
                    ("kernel", s(kernel.label())),
                    ("selected", num(selected[i] as f64)),
                    ("count", num(snap.count as f64)),
                    ("sum_ns", num(snap.sum as f64)),
                    ("max_ns", num(snap.max as f64)),
                    ("mean_ns", num(snap.mean_ns())),
                    ("p50_ns", num(snap.quantile(0.5))),
                    ("p90_ns", num(snap.quantile(0.9))),
                    ("p99_ns", num(snap.quantile(0.99))),
                ]));
            }
        }
    }

    // One row per generated variant (additive next to the family-grain
    // `kernels` rows): how often each concrete variant was dispatched at
    // each grain. Families without non-canonical siblings still appear —
    // the canonical variant carries the family's counts.
    let variants = registry()
        .entries()
        .iter()
        .map(|e| {
            obj(vec![
                ("op", s(e.variant.op.label())),
                ("variant", s(e.label)),
                ("family", s(e.variant.family.label())),
                ("requests", num(m.variant_request_count(e.id) as f64)),
                (
                    "shard_executions",
                    num(m.variant_shard_count(e.id) as f64),
                ),
            ])
        })
        .collect();

    // Roofline workload rows: one per variant that actually executed,
    // with analytic flop/byte totals and the derived achieved rates.
    let mut wl_rows = Vec::new();
    for e in registry().entries() {
        let Some(t) = m.workload_totals(e.id) else {
            continue;
        };
        wl_rows.push(obj(vec![
            ("op", s(e.variant.op.label())),
            ("variant", s(e.label)),
            ("execs", num(t.execs as f64)),
            ("ns", num(t.ns as f64)),
            ("flops", num(t.flops as f64)),
            ("bytes_read", num(t.bytes_read as f64)),
            ("bytes_written", num(t.bytes_written as f64)),
            ("padding_bytes", num(t.padding_bytes as f64)),
            ("rows", num(t.rows as f64)),
            ("nnz", num(t.nnz as f64)),
            ("gflops", num(t.achieved_gflops())),
            ("gbps", num(t.achieved_gbps())),
            ("intensity", num(t.arithmetic_intensity())),
        ]));
    }
    let workload = obj(vec![
        ("flops_total", num(m.workload_flops_total() as f64)),
        (
            "shard_imbalance",
            obj(vec![
                ("batches", num(m.shard_imbalance_batches() as f64)),
                ("mean_milli", num(m.shard_imbalance_mean_milli() as f64)),
                ("max_milli", num(m.shard_imbalance_max_milli() as f64)),
            ]),
        ),
        ("variants", Json::Arr(wl_rows)),
    ]);

    // `null` when no monitor is installed: the key is always present so
    // the document schema is stable, but renderers skip the section.
    let slo = match m.slo() {
        Some(monitor) => monitor.report().to_json(),
        None => Json::Null,
    };

    let recorder = m.recorder();
    let exemplars = recorder
        .exemplars()
        .into_iter()
        .map(|e| {
            obj(vec![
                ("bucket", num(e.bucket as f64)),
                ("trace_id", num(e.trace_id as f64)),
                ("label", s(&e.label)),
                ("duration_ns", num(e.duration_ns as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("counters", counters),
        ("kernels", Json::Arr(kernels)),
        ("variants", Json::Arr(variants)),
        ("workload", workload),
        ("regret", m.regret().report().to_json()),
        ("slo", slo),
        ("audit", m.audit().to_json()),
        (
            "traces",
            obj(vec![
                ("capacity", num(recorder.capacity() as f64)),
                ("committed", num(recorder.committed() as f64)),
                ("retained", num(recorder.len() as f64)),
                ("dropped", num(recorder.dropped() as f64)),
                ("exemplars", Json::Arr(exemplars)),
            ]),
        ),
        ("summary", s(&m.summary())),
    ])
}

fn req_num(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("stats snapshot: missing numeric field '{key}'"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("stats snapshot: missing string field '{key}'"))
}

/// Format a metric value the way Prometheus expects: integers without a
/// fractional part.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn header(out: &mut String, name: &str, ty: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {ty}\n"));
}

/// Escape a label value per the Prometheus exposition format: inside
/// `label="..."`, backslash, double quote and newline must be escaped.
fn esc(v: &str) -> String {
    v.replace('\\', "\\\\")
        .replace('"', "\\\"")
        .replace('\n', "\\n")
}

/// Render a stats snapshot (as produced by [`snapshot`], possibly
/// re-read from a `--stats-file` dump) as Prometheus exposition text.
/// Fails with a description of the missing field if the document does
/// not follow the snapshot schema.
pub fn prometheus_of(snap: &Json) -> Result<String, String> {
    let counters = snap
        .get("counters")
        .ok_or_else(|| "stats snapshot: missing 'counters' object".to_string())?;
    let mut out = String::new();
    for (key, name, ty, help) in COUNTERS {
        let v = req_num(counters, key)?;
        header(&mut out, name, ty, help);
        out.push_str(&format!("{name} {}\n", fmt_value(v)));
    }

    let kernels = snap
        .get("kernels")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| "stats snapshot: missing 'kernels' array".to_string())?;
    header(
        &mut out,
        "ge_spmm_kernel_selected_total",
        "counter",
        "Kernel selections by op, grain and kernel.",
    );
    for row in kernels {
        let (op, grain, kernel) = (
            esc(req_str(row, "op")?),
            esc(req_str(row, "grain")?),
            esc(req_str(row, "kernel")?),
        );
        let v = req_num(row, "selected")?;
        out.push_str(&format!(
            "ge_spmm_kernel_selected_total{{op=\"{op}\",grain=\"{grain}\",kernel=\"{kernel}\"}} {}\n",
            fmt_value(v)
        ));
    }
    header(
        &mut out,
        "ge_spmm_latency_ns",
        "summary",
        "Execution latency quantiles (ns) by op, grain and kernel.",
    );
    for row in kernels {
        if req_num(row, "count")? == 0.0 {
            continue;
        }
        let (op, grain, kernel) = (
            esc(req_str(row, "op")?),
            esc(req_str(row, "grain")?),
            esc(req_str(row, "kernel")?),
        );
        let labels = format!("op=\"{op}\",grain=\"{grain}\",kernel=\"{kernel}\"");
        for q in QUANTILES {
            let key = format!("p{:.0}_ns", q * 100.0);
            let v = req_num(row, &key)?;
            out.push_str(&format!(
                "ge_spmm_latency_ns{{{labels},quantile=\"{q}\"}} {}\n",
                fmt_value(v)
            ));
        }
        out.push_str(&format!(
            "ge_spmm_latency_ns_sum{{{labels}}} {}\n",
            fmt_value(req_num(row, "sum_ns")?)
        ));
        out.push_str(&format!(
            "ge_spmm_latency_ns_count{{{labels}}} {}\n",
            fmt_value(req_num(row, "count")?)
        ));
        out.push_str(&format!(
            "ge_spmm_latency_ns_max{{{labels}}} {}\n",
            fmt_value(req_num(row, "max_ns")?)
        ));
    }

    // Optional (snapshots from before the variant registry lack it):
    // per-variant dispatch counts at both grains.
    if let Some(variants) = snap.get("variants").and_then(|j| j.as_arr()) {
        header(
            &mut out,
            "ge_spmm_variant_selected_total",
            "counter",
            "Generated-variant dispatches by op, grain and variant.",
        );
        for row in variants {
            let (op, variant, family) = (
                esc(req_str(row, "op")?),
                esc(req_str(row, "variant")?),
                esc(req_str(row, "family")?),
            );
            for (grain, key) in [("request", "requests"), ("shard", "shard_executions")] {
                let v = req_num(row, key)?;
                out.push_str(&format!(
                    "ge_spmm_variant_selected_total{{op=\"{op}\",grain=\"{grain}\",family=\"{family}\",variant=\"{variant}\"}} {}\n",
                    fmt_value(v)
                ));
            }
        }
    }

    // Optional (older snapshots lack it): roofline workload accounting.
    if let Some(wl) = snap.get("workload") {
        header(
            &mut out,
            "ge_spmm_flops_total",
            "counter",
            "Analytic floating-point operations across all executions.",
        );
        out.push_str(&format!(
            "ge_spmm_flops_total {}\n",
            fmt_value(req_num(wl, "flops_total")?)
        ));
        if let Some(imb) = wl.get("shard_imbalance") {
            header(
                &mut out,
                "ge_spmm_shard_imbalance_milli",
                "gauge",
                "Per-batch shard nnz imbalance (max_nnz*shards/total_nnz, milli; 1000 = balanced).",
            );
            for stat in ["mean", "max"] {
                let v = req_num(imb, &format!("{stat}_milli"))?;
                out.push_str(&format!(
                    "ge_spmm_shard_imbalance_milli{{stat=\"{stat}\"}} {}\n",
                    fmt_value(v)
                ));
            }
        }
        let rows = wl
            .get("variants")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| "stats snapshot: missing 'workload.variants' array".to_string())?;
        header(
            &mut out,
            "ge_spmm_workload_bytes_total",
            "counter",
            "Analytic bytes moved by executed kernels, by direction.",
        );
        for row in rows {
            let (op, variant) = (esc(req_str(row, "op")?), esc(req_str(row, "variant")?));
            for (dir, key) in [("read", "bytes_read"), ("written", "bytes_written")] {
                let v = req_num(row, key)?;
                out.push_str(&format!(
                    "ge_spmm_workload_bytes_total{{op=\"{op}\",variant=\"{variant}\",direction=\"{dir}\"}} {}\n",
                    fmt_value(v)
                ));
            }
        }
        header(
            &mut out,
            "ge_spmm_achieved_gflops",
            "gauge",
            "Achieved GFLOP/s per variant (analytic flops over measured ns).",
        );
        for row in rows {
            let (op, variant) = (esc(req_str(row, "op")?), esc(req_str(row, "variant")?));
            out.push_str(&format!(
                "ge_spmm_achieved_gflops{{op=\"{op}\",variant=\"{variant}\"}} {}\n",
                fmt_value(req_num(row, "gflops")?)
            ));
        }
        header(
            &mut out,
            "ge_spmm_arithmetic_intensity",
            "gauge",
            "Analytic flops per byte moved, per variant.",
        );
        for row in rows {
            let (op, variant) = (esc(req_str(row, "op")?), esc(req_str(row, "variant")?));
            out.push_str(&format!(
                "ge_spmm_arithmetic_intensity{{op=\"{op}\",variant=\"{variant}\"}} {}\n",
                fmt_value(req_num(row, "intensity")?)
            ));
        }
    }

    // Optional: selector-regret counters.
    if let Some(r) = snap.get("regret") {
        header(
            &mut out,
            "ge_spmm_regret_folds_total",
            "counter",
            "Realized costs folded into the selector-regret tracker.",
        );
        out.push_str(&format!(
            "ge_spmm_regret_folds_total {}\n",
            fmt_value(req_num(r, "folds")?)
        ));
        header(
            &mut out,
            "ge_spmm_regret_ratio",
            "gauge",
            "Aggregate selector regret: chosen cost over best-known cost, minus one.",
        );
        for (op, key) in [("spmm", "spmm_ratio"), ("sddmm", "sddmm_ratio")] {
            out.push_str(&format!(
                "ge_spmm_regret_ratio{{op=\"{op}\"}} {}\n",
                fmt_value(req_num(r, key)?)
            ));
        }
    }

    // Optional, and `null` when no monitor is installed: serving SLOs.
    if let Some(slo) = snap.get("slo") {
        if *slo != Json::Null {
            header(
                &mut out,
                "ge_spmm_slo_observed_total",
                "counter",
                "Requests observed by the SLO monitor.",
            );
            out.push_str(&format!(
                "ge_spmm_slo_observed_total {}\n",
                fmt_value(req_num(slo, "observed")?)
            ));
            let objectives = slo
                .get("objectives")
                .and_then(|j| j.as_arr())
                .ok_or_else(|| "stats snapshot: missing 'slo.objectives' array".to_string())?;
            header(
                &mut out,
                "ge_spmm_slo_burn_rate",
                "gauge",
                "Error-budget burn rate per SLO objective (1.0 = budget exhausted).",
            );
            for o in objectives {
                let name = esc(req_str(o, "name")?);
                out.push_str(&format!(
                    "ge_spmm_slo_burn_rate{{objective=\"{name}\"}} {}\n",
                    fmt_value(req_num(o, "burn_rate")?)
                ));
            }
            header(
                &mut out,
                "ge_spmm_slo_breaching",
                "gauge",
                "Whether each SLO objective's burn rate exceeds 1.0.",
            );
            for o in objectives {
                let name = esc(req_str(o, "name")?);
                let breaching = o
                    .get("breaching")
                    .and_then(|j| j.as_bool())
                    .ok_or_else(|| {
                        "stats snapshot: missing boolean field 'breaching'".to_string()
                    })?;
                out.push_str(&format!(
                    "ge_spmm_slo_breaching{{objective=\"{name}\"}} {}\n",
                    if breaching { 1 } else { 0 }
                ));
            }
        }
    }

    let audit = snap
        .get("audit")
        .ok_or_else(|| "stats snapshot: missing 'audit' object".to_string())?;
    for (key, name, help) in [
        (
            "recorded",
            "ge_spmm_audit_decisions_total",
            "Selector decisions recorded in the audit log.",
        ),
        (
            "explored",
            "ge_spmm_audit_explored_total",
            "Decisions where the online selector explored.",
        ),
        (
            "realized",
            "ge_spmm_audit_realized_total",
            "Decisions with a backfilled realized cost.",
        ),
    ] {
        let v = req_num(audit, key)?;
        header(&mut out, name, "counter", help);
        out.push_str(&format!("{name} {}\n", fmt_value(v)));
    }

    let traces = snap
        .get("traces")
        .ok_or_else(|| "stats snapshot: missing 'traces' object".to_string())?;
    header(
        &mut out,
        "ge_spmm_traces_committed_total",
        "counter",
        "Request traces committed to the flight recorder.",
    );
    out.push_str(&format!(
        "ge_spmm_traces_committed_total {}\n",
        fmt_value(req_num(traces, "committed")?)
    ));
    header(
        &mut out,
        "ge_spmm_traces_retained",
        "gauge",
        "Request traces currently retained in the ring.",
    );
    out.push_str(&format!(
        "ge_spmm_traces_retained {}\n",
        fmt_value(req_num(traces, "retained")?)
    ));
    // Optional (older snapshots lack it): ring-eviction count.
    if let Some(v) = traces.get("dropped").and_then(|j| j.as_f64()) {
        header(
            &mut out,
            "ge_spmm_traces_dropped_total",
            "counter",
            "Request traces evicted from the flight-recorder ring.",
        );
        out.push_str(&format!("ge_spmm_traces_dropped_total {}\n", fmt_value(v)));
    }
    Ok(out)
}

/// Render a live [`Metrics`] hub directly as Prometheus text.
pub fn prometheus_text(m: &Metrics) -> String {
    prometheus_of(&snapshot(m)).expect("snapshot always matches its own schema")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn snapshot_reflects_counters_and_histograms() {
        let m = Metrics::default();
        m.record(KernelKind::SrRs, Duration::from_micros(100));
        m.record(KernelKind::SrRs, Duration::from_micros(200));
        m.record_sddmm_shard(KernelKind::PrWb, Duration::from_micros(50));
        m.record_cache_miss();
        let snap = snapshot(&m);
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(counters.get("cache_misses").unwrap().as_usize(), Some(1));
        let kernels = snap.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), 16, "2 ops x 2 grains x 4 kernels");
        let sr_rs = kernels
            .iter()
            .find(|row| {
                row.get("op").unwrap().as_str() == Some("spmm")
                    && row.get("grain").unwrap().as_str() == Some("request")
                    && row.get("kernel").unwrap().as_str() == Some("sr_rs")
            })
            .unwrap();
        assert_eq!(sr_rs.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(sr_rs.get("selected").unwrap().as_usize(), Some(2));
        assert!(sr_rs.get("p50_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(snap.get("traces").is_some() && snap.get("audit").is_some());
    }

    #[test]
    fn snapshot_roundtrips_through_the_parser() {
        let m = Metrics::default();
        m.record(KernelKind::PrWb, Duration::from_micros(300));
        let snap = snapshot(&m);
        let reparsed = Json::parse(&snap.to_string_pretty()).unwrap();
        assert_eq!(reparsed, snap);
        // and the re-parsed document renders to the same Prometheus text
        assert_eq!(
            prometheus_of(&reparsed).unwrap(),
            prometheus_text(&m)
        );
    }

    #[test]
    fn prometheus_text_has_expected_series() {
        let m = Metrics::default();
        m.record(KernelKind::SrWb, Duration::from_micros(150));
        m.record_shard(KernelKind::PrRs, Duration::from_micros(40));
        let text = prometheus_text(&m);
        assert!(text.contains("# TYPE ge_spmm_requests_total counter"));
        assert!(text.contains("ge_spmm_requests_total 1"), "{text}");
        assert!(text.contains(
            "ge_spmm_kernel_selected_total{op=\"spmm\",grain=\"request\",kernel=\"sr_wb\"} 1"
        ));
        assert!(text.contains(
            "ge_spmm_kernel_selected_total{op=\"spmm\",grain=\"shard\",kernel=\"pr_rs\"} 1"
        ));
        assert!(
            text.contains("op=\"spmm\",grain=\"shard\",kernel=\"pr_rs\",quantile=\"0.99\""),
            "{text}"
        );
        // empty series emit no quantiles
        assert!(!text.contains("op=\"sddmm\",grain=\"request\",kernel=\"sr_rs\",quantile"));
        assert!(text.contains("ge_spmm_traces_committed_total 0"));
    }

    #[test]
    fn variant_rows_cover_the_registry_and_render_as_series() {
        let m = Metrics::default();
        let reg = registry();
        let alt = reg.by_label(SparseOp::Spmm, "sr_rs.t4").unwrap();
        assert!(m.record_request_variant(alt.id, Duration::from_micros(70)));
        assert!(m.record_shard_variant(alt.id, Duration::from_micros(20)));
        let snap = snapshot(&m);
        let variants = snap.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), reg.len(), "one row per generated variant");
        let row = variants
            .iter()
            .find(|r| {
                r.get("op").unwrap().as_str() == Some("spmm")
                    && r.get("variant").unwrap().as_str() == Some("sr_rs.t4")
            })
            .unwrap();
        assert_eq!(row.get("family").unwrap().as_str(), Some("sr_rs"));
        assert_eq!(row.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(row.get("shard_executions").unwrap().as_usize(), Some(1));
        let text = prometheus_text(&m);
        assert!(
            text.contains(
                "ge_spmm_variant_selected_total{op=\"spmm\",grain=\"request\",family=\"sr_rs\",variant=\"sr_rs.t4\"} 1"
            ),
            "{text}"
        );
        // a pre-registry snapshot (no 'variants' key) still renders
        let legacy = match snap {
            Json::Obj(mut fields) => {
                fields.remove("variants");
                Json::Obj(fields)
            }
            _ => unreachable!("snapshot is an object"),
        };
        let rendered = prometheus_of(&legacy).unwrap();
        assert!(!rendered.contains("ge_spmm_variant_selected_total"));
    }

    #[test]
    fn prometheus_of_rejects_malformed_documents() {
        assert!(prometheus_of(&Json::Null).is_err());
        let partial = obj(vec![("counters", obj(vec![("requests", num(1.0))]))]);
        let err = prometheus_of(&partial).unwrap_err();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn esc_escapes_prometheus_label_values() {
        assert_eq!(esc(r#"a\b"c"#), r#"a\\b\"c"#);
        assert_eq!(esc("line1\nline2"), "line1\\nline2");
        assert_eq!(esc("plain"), "plain");
    }

    #[test]
    fn label_values_are_escaped_in_exposition() {
        let m = Metrics::default();
        let mut snap = snapshot(&m);
        // splice a hostile variant label into the document
        if let Json::Obj(fields) = &mut snap {
            fields.insert(
                "variants".to_string(),
                Json::Arr(vec![obj(vec![
                    ("op", s("spmm")),
                    ("variant", s("bad\"label\\with\nnoise")),
                    ("family", s("sr_rs")),
                    ("requests", num(1.0)),
                    ("shard_executions", num(0.0)),
                ])]),
            );
        }
        let text = prometheus_of(&snap).unwrap();
        assert!(
            text.contains("variant=\"bad\\\"label\\\\with\\nnoise\""),
            "{text}"
        );
    }

    #[test]
    fn workload_regret_and_trace_sections_render() {
        let m = Metrics::default();
        let e = registry().by_label(SparseOp::Spmm, "sr_rs").unwrap();
        let est = crate::obs::workload::estimate(&e.variant, 4, 10, 8);
        assert!(m.record_workload(e.id, &est, Duration::from_nanos(80)));
        m.regret().fold(SparseOp::Spmm, 0, e.id, 2.0, 1.0);
        let snap = snapshot(&m);
        let wl = snap.get("workload").unwrap();
        assert_eq!(wl.get("flops_total").unwrap().as_usize(), Some(160));
        let rows = wl.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1, "only executed variants get workload rows");
        let text = prometheus_text(&m);
        assert!(text.contains("ge_spmm_flops_total 160"), "{text}");
        assert!(
            text.contains(
                "ge_spmm_workload_bytes_total{op=\"spmm\",variant=\"sr_rs\",direction=\"read\"} 420"
            ),
            "{text}"
        );
        assert!(
            text.contains("ge_spmm_achieved_gflops{op=\"spmm\",variant=\"sr_rs\"} 2"),
            "{text}"
        );
        assert!(text.contains("ge_spmm_regret_folds_total 1"), "{text}");
        assert!(text.contains("ge_spmm_regret_ratio{op=\"spmm\"} 1"), "{text}");
        assert!(text.contains("ge_spmm_traces_dropped_total 0"), "{text}");
        // no monitor installed: the slo key is null and emits nothing
        assert_eq!(snap.get("slo"), Some(&Json::Null));
        assert!(!text.contains("ge_spmm_slo_burn_rate"));
    }

    #[test]
    fn slo_section_renders_when_a_monitor_is_installed() {
        use crate::obs::slo::{SloMonitor, SloSpec};
        use std::sync::Arc;
        let m = Metrics::default();
        let monitor = Arc::new(SloMonitor::new(SloSpec::parse("p99=1ms,queue=4").unwrap()));
        monitor.observe(Duration::from_millis(5), 10);
        m.install_slo(monitor);
        let text = prometheus_text(&m);
        assert!(text.contains("ge_spmm_slo_observed_total 1"), "{text}");
        assert!(
            text.contains("ge_spmm_slo_burn_rate{objective=\"p99\"}"),
            "{text}"
        );
        assert!(
            text.contains("ge_spmm_slo_breaching{objective=\"p99\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("ge_spmm_slo_breaching{objective=\"queue\"} 1"),
            "{text}"
        );
    }
}
