//! Selector decision audit: why each kernel was chosen.
//!
//! Every adaptive decision in the stack — `AdaptiveSelector` /
//! `SddmmSelector` rule firings at request grain, per-shard choices in
//! the sharded backend, `OnlineSelector` picks including its exploration
//! swaps — records an [`AuditEntry`]: the input features, the thresholds
//! consulted (by name and value, enough to *reproduce* the decision),
//! the chosen kernel, and, once the online path observes the request's
//! cost, the realized normalized cost backfilled via
//! [`AuditLog::note_cost`]. The log is a bounded ring under one
//! poison-tolerant mutex (decisions are request-rate, not shard-op-rate
//! hot), queryable as a per-matrix "explain" report through
//! `SpmmEngine::explain` and summarized by the exposition surface.

use crate::features::MatrixFeatures;
use crate::kernels::{KernelKind, SparseOp};
use crate::util::json::{num, obj, s, Json};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One recorded selector decision.
#[derive(Clone, Debug)]
pub struct AuditEntry {
    /// Monotone sequence number, assigned by [`AuditLog::push`].
    pub seq: u64,
    /// Which sparse op the decision was for.
    pub op: SparseOp,
    /// Decision grain: `"request"` (engine selection) or `"shard"`.
    pub grain: &'static str,
    /// Shard index for shard-grain decisions.
    pub shard: Option<usize>,
    /// Deciding selector: `"adaptive"`, `"sddmm"`, `"online"`,
    /// `"online-sddmm"`, or `"fixed"`.
    pub selector: &'static str,
    /// Registered matrix id for request-grain decisions (shard-grain
    /// decisions happen below the handle layer).
    pub matrix: Option<usize>,
    /// The feature vector the selector saw.
    pub features: MatrixFeatures,
    /// Dense width `n` (SpMM) or dot width `d` (SDDMM).
    pub n: usize,
    /// Thresholds consulted, by name — replaying the selector's rule on
    /// `features`/`n` against these must reproduce `kernel`.
    pub thresholds: Vec<(&'static str, f64)>,
    /// Human-readable statement of the rule that fired.
    pub rule: String,
    /// The chosen kernel design (family grain — what the paper rules
    /// decide).
    pub kernel: KernelKind,
    /// The registry variant actually dispatched, by stable label, when
    /// the deciding path is variant-precise (`None` on family-only paths,
    /// which execute the canonical variant).
    pub variant: Option<&'static str>,
    /// Whether the online selector overrode the rule to explore.
    pub explored: bool,
    /// Normalized cost (`seconds / flops`) observed for this decision,
    /// backfilled by the online path via [`AuditLog::note_cost`].
    pub realized_cost: Option<f64>,
}

impl AuditEntry {
    /// Look up a consulted threshold by name.
    pub fn threshold(&self, name: &str) -> Option<f64> {
        self.thresholds
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| *v)
    }

    /// One-line rendering for explain reports.
    pub fn line(&self) -> String {
        // Only surface the variant when it refines the family — canonical
        // dispatch reads exactly as it did pre-registry.
        let variant = self
            .variant
            .filter(|v| *v != self.kernel.label())
            .map(|v| format!(" [{v}]"))
            .unwrap_or_default();
        let mut out = format!(
            "#{} [{} {}{}] n={} -> {}{} via {}{}: {}",
            self.seq,
            self.grain,
            self.op.label(),
            self.shard.map(|i| format!(" shard {i}")).unwrap_or_default(),
            self.n,
            self.kernel.label(),
            variant,
            self.selector,
            if self.explored { " (explore)" } else { "" },
            self.rule,
        );
        let thresholds = self
            .thresholds
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!(
            "; features {}; thresholds {}",
            self.features.summary(),
            thresholds
        ));
        if let Some(c) = self.realized_cost {
            out.push_str(&format!("; realized cost {c:.3e}"));
        }
        out
    }

    /// JSON form (used by the stats snapshot).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seq", num(self.seq as f64)),
            ("op", s(self.op.label())),
            ("grain", s(self.grain)),
            (
                "shard",
                self.shard.map(|i| num(i as f64)).unwrap_or(Json::Null),
            ),
            ("selector", s(self.selector)),
            (
                "matrix",
                self.matrix.map(|i| num(i as f64)).unwrap_or(Json::Null),
            ),
            (
                "features",
                obj(vec![
                    ("rows", num(self.features.rows as f64)),
                    ("cols", num(self.features.cols as f64)),
                    ("nnz", num(self.features.nnz as f64)),
                    ("avg_row", num(self.features.avg_row)),
                    ("cv_row", num(self.features.cv_row)),
                    ("max_row", num(self.features.max_row as f64)),
                ]),
            ),
            ("n", num(self.n as f64)),
            (
                "thresholds",
                Json::Obj(
                    self.thresholds
                        .iter()
                        .map(|(k, v)| (k.to_string(), num(*v)))
                        .collect(),
                ),
            ),
            ("rule", s(&self.rule)),
            ("kernel", s(self.kernel.label())),
            ("variant", self.variant.map(s).unwrap_or(Json::Null)),
            ("explored", Json::Bool(self.explored)),
            (
                "realized_cost",
                self.realized_cost.map(num).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Bounded ring of recent [`AuditEntry`]s plus monotone totals.
#[derive(Debug)]
pub struct AuditLog {
    capacity: usize,
    next_seq: AtomicU64,
    explored: AtomicU64,
    realized: AtomicU64,
    ring: Mutex<VecDeque<AuditEntry>>,
}

impl AuditLog {
    /// Log keeping the last `capacity` decisions (min 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            next_seq: AtomicU64::new(0),
            explored: AtomicU64::new(0),
            realized: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total decisions ever recorded (monotone).
    pub fn recorded(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    /// Total decisions flagged as online exploration.
    pub fn explored(&self) -> u64 {
        self.explored.load(Ordering::Relaxed)
    }

    /// Total decisions whose realized cost was backfilled.
    pub fn realized(&self) -> u64 {
        self.realized.load(Ordering::Relaxed)
    }

    /// Record a decision; returns its assigned sequence number.
    pub fn push(&self, mut entry: AuditEntry) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        entry.seq = seq;
        if entry.explored {
            self.explored.fetch_add(1, Ordering::Relaxed);
        }
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= self.capacity {
            ring.pop_front();
        }
        ring.push_back(entry);
        seq
    }

    /// Backfill the realized normalized cost onto the newest matching
    /// decision (same op, kernel and matrix nnz) that has none yet.
    /// Returns whether a decision was found — misses are expected once
    /// the ring has wrapped past the decision.
    pub fn note_cost(&self, op: SparseOp, kernel: KernelKind, nnz: usize, cost: f64) -> bool {
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        for entry in ring.iter_mut().rev() {
            if entry.op == op
                && entry.kernel == kernel
                && entry.features.nnz == nnz
                && entry.realized_cost.is_none()
            {
                entry.realized_cost = Some(cost);
                self.realized.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// Copy the retained decisions out, oldest first.
    pub fn entries(&self) -> Vec<AuditEntry> {
        self.ring
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// Decisions currently retained.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether no decision has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Retained request-grain decisions for one registered matrix.
    pub fn for_matrix(&self, matrix: usize) -> Vec<AuditEntry> {
        self.ring
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|e| e.matrix == Some(matrix))
            .cloned()
            .collect()
    }

    /// Multi-line explain report; restricted to one matrix's
    /// request-grain decisions when `matrix` is given.
    pub fn explain(&self, matrix: Option<usize>) -> String {
        let entries = match matrix {
            Some(id) => self.for_matrix(id),
            None => self.entries(),
        };
        let mut out = format!(
            "selector audit: {} decisions recorded ({} retained{}), {} explored, {} with realized cost\n",
            self.recorded(),
            entries.len(),
            matrix.map(|id| format!(" for matrix {id}")).unwrap_or_default(),
            self.explored(),
            self.realized(),
        );
        for e in &entries {
            out.push_str(&e.line());
            out.push('\n');
        }
        out
    }

    /// JSON form: totals plus the retained entries.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("capacity", num(self.capacity as f64)),
            ("recorded", num(self.recorded() as f64)),
            ("explored", num(self.explored() as f64)),
            ("realized", num(self.realized() as f64)),
            (
                "entries",
                Json::Arr(self.entries().iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }
}

impl Default for AuditLog {
    /// Log retaining the last 256 decisions.
    fn default() -> Self {
        Self::new(256)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::prng::Xoshiro256;

    fn entry(kernel: KernelKind, nnz_seed: u64) -> AuditEntry {
        let mut rng = Xoshiro256::seeded(nnz_seed);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(20, 20, 0.2, &mut rng));
        AuditEntry {
            seq: 0,
            op: SparseOp::Spmm,
            grain: "request",
            shard: None,
            selector: "adaptive",
            matrix: Some(3),
            features: MatrixFeatures::of(&csr),
            n: 32,
            thresholds: vec![("t_cv", 1.5)],
            rule: "cv_row <= t_cv -> sr_rs".to_string(),
            kernel,
            variant: None,
            explored: false,
            realized_cost: None,
        }
    }

    #[test]
    fn push_assigns_sequence_and_wraps() {
        let log = AuditLog::new(2);
        for i in 0..5u64 {
            assert_eq!(log.push(entry(KernelKind::SrRs, i)), i);
        }
        assert_eq!(log.recorded(), 5);
        assert_eq!(log.len(), 2);
        let seqs: Vec<u64> = log.entries().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, [3, 4]);
    }

    #[test]
    fn note_cost_backfills_newest_matching_entry() {
        let log = AuditLog::default();
        let e = entry(KernelKind::SrRs, 7);
        let nnz = e.features.nnz;
        log.push(e.clone());
        log.push(e);
        assert!(log.note_cost(SparseOp::Spmm, KernelKind::SrRs, nnz, 1e-9));
        let entries = log.entries();
        assert_eq!(entries[0].realized_cost, None, "older entry untouched");
        assert_eq!(entries[1].realized_cost, Some(1e-9), "newest matched first");
        assert!(!log.note_cost(SparseOp::Sddmm, KernelKind::SrRs, nnz, 1.0));
        assert_eq!(log.realized(), 1);
        assert!(log.entries()[1].line().contains("realized cost"));
    }

    #[test]
    fn explain_filters_by_matrix() {
        let log = AuditLog::default();
        let mut a = entry(KernelKind::SrWb, 1);
        a.matrix = Some(1);
        a.variant = Some("sr_wb.s64");
        let mut b = entry(KernelKind::PrRs, 2);
        b.matrix = Some(2);
        log.push(a);
        log.push(b);
        let report = log.explain(Some(1));
        assert!(report.contains("sr_wb"), "{report}");
        assert!(report.contains("[sr_wb.s64]"), "{report}");
        assert!(!report.contains("pr_rs"), "{report}");
        assert!(log.explain(None).contains("pr_rs"));
        assert_eq!(log.to_json().get("recorded").and_then(|j| j.as_usize()), Some(2));
        assert_eq!(log.entries()[0].threshold("t_cv"), Some(1.5));
    }
}
