//! Log-bucketed lock-free latency histograms.
//!
//! [`AtomicHistogram`] replaces the old mutex-guarded latency reservoir in
//! `coordinator::Metrics`: recording a sample is three relaxed atomic
//! RMWs (bucket count, total sum, running max) with **no lock on the hot
//! path**, so request workers and shard threads never contend on a
//! mutex just to be observable, and a panicking worker can never poison
//! the stats.
//!
//! The bucket scheme is 64 power-of-√2 buckets over nanoseconds: bucket
//! `i` covers `[√2^i, √2^(i+1))` ns, so the full range spans 1 ns to
//! `√2^64 = 2^32` ns ≈ 4.3 s — more than any sane kernel latency — with
//! a worst-case quantile error bounded by the bucket width, a factor of
//! √2 (the estimator answers the bucket's geometric midpoint, so the
//! bound is actually `2^(1/4)` each way). Values at or below 1 ns land
//! in bucket 0; values past the top land in bucket 63.
//!
//! Quantiles are computed from a [`HistogramSnapshot`] — a plain copy of
//! the counters taken with relaxed loads — via nearest-rank selection
//! over the cumulative bucket counts and geometric interpolation within
//! the selected bucket. See `DESIGN.md` §Observability.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of power-of-√2 buckets (covers 1 ns .. 2^32 ns ≈ 4.3 s).
pub const BUCKETS: usize = 64;

/// Bucket index for a nanosecond value: `floor(2·log2(v))`, clamped to
/// the bucket range. Integer-only — the √2 boundary test `v < 2^(k+0.5)`
/// is evaluated exactly as `v² < 2^(2k+1)` in 128-bit arithmetic.
pub fn bucket_index(ns: u64) -> usize {
    if ns <= 1 {
        return 0;
    }
    let k = ns.ilog2() as u64;
    let upper_half = (ns as u128) * (ns as u128) >= (1u128 << (2 * k + 1));
    ((2 * k + u64::from(upper_half)) as usize).min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`, in ns.
pub fn bucket_lower(i: usize) -> f64 {
    2f64.powf(i as f64 / 2.0)
}

/// Geometric midpoint of bucket `i`, in ns — the quantile estimator's
/// answer for ranks that land in the bucket.
pub fn bucket_mid(i: usize) -> f64 {
    2f64.powf((i as f64 + 0.5) / 2.0)
}

/// Lock-free log-bucketed histogram of nanosecond samples.
///
/// All updates are relaxed atomics; readers take a [`HistogramSnapshot`]
/// and compute quantiles from the copy. A snapshot taken concurrently
/// with writers may be mid-update (count and buckets read at slightly
/// different instants) but is always a valid histogram; once writers
/// quiesce the totals are exact.
#[derive(Debug)]
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl AtomicHistogram {
    /// An empty histogram. `const` so banks of histograms can be
    /// initialized in statics and struct literals without iteration.
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            counts: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample, in nanoseconds. Lock-free: three relaxed RMWs.
    pub fn record(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record one sample as a [`Duration`] (saturating past ~584 years).
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Accumulate `other`'s current contents into `self` without
    /// re-recording samples — the rollup path that aggregates
    /// per-variant banks into family/op views. Both histograms stay
    /// live; the merge is a snapshot-then-add, so samples recorded into
    /// `other` concurrently with the merge may or may not be included,
    /// exactly like any other relaxed reader.
    pub fn merge(&self, other: &AtomicHistogram) {
        let s = other.snapshot();
        for (dst, &src) in self.counts.iter().zip(s.counts.iter()) {
            if src > 0 {
                dst.fetch_add(src, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(s.count, Ordering::Relaxed);
        self.sum.fetch_add(s.sum, Ordering::Relaxed);
        self.max.fetch_max(s.max, Ordering::Relaxed);
    }

    /// Copy the counters out for quantile computation.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (dst, src) in counts.iter_mut().zip(self.counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A point-in-time copy of an [`AtomicHistogram`]'s counters.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (see [`bucket_index`]).
    pub counts: [u64; BUCKETS],
    /// Total samples.
    pub count: u64,
    /// Sum of all recorded nanoseconds.
    pub sum: u64,
    /// Largest recorded sample, in ns.
    pub max: u64,
}

impl HistogramSnapshot {
    /// An empty snapshot.
    pub fn empty() -> Self {
        Self {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0,
            max: 0,
        }
    }

    /// Merge several snapshots (e.g. the per-kernel histograms of one
    /// op × grain) into one combined distribution.
    pub fn merged(snaps: impl IntoIterator<Item = HistogramSnapshot>) -> Self {
        let mut out = Self::empty();
        for s in snaps {
            for (dst, src) in out.counts.iter_mut().zip(s.counts.iter()) {
                *dst += src;
            }
            out.count += s.count;
            out.sum += s.sum;
            out.max = out.max.max(s.max);
        }
        out
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of the recorded samples, in ns (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimated `q`-quantile in ns: nearest-rank selection over the
    /// cumulative bucket counts, answering the selected bucket's
    /// geometric midpoint (clamped by the exact running max). Relative
    /// error vs. an exact sort is bounded by the √2 bucket width.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = (q * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_mid(i).min(self.max as f64);
            }
        }
        self.max as f64
    }

    /// Median estimate, ns.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 90th percentile estimate, ns.
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    /// 99th percentile estimate, ns.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut prev = 0usize;
        for exp in 0..64u32 {
            let v = 1u64 << exp;
            let i = bucket_index(v);
            assert!(i >= prev, "bucket index must be monotone at {v}");
            assert!(i < BUCKETS);
            prev = i;
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 2); // log2 = 1 → floor(2·1) = 2
        assert_eq!(bucket_index(3), 3); // 2·log2(3) ≈ 3.17
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn bucket_bounds_contain_their_values() {
        for v in [1u64, 2, 3, 7, 100, 1_000, 123_456, 10_000_000_000] {
            let i = bucket_index(v);
            assert!(
                (v as f64) >= bucket_lower(i) - 1e-9,
                "{v} below lower bound of bucket {i}"
            );
            if i + 1 < BUCKETS {
                assert!(
                    (v as f64) < bucket_lower(i + 1) + 1e-9,
                    "{v} past upper bound of bucket {i}"
                );
            }
        }
    }

    #[test]
    fn records_and_summarizes() {
        let h = AtomicHistogram::new();
        for v in [100u64, 200, 300, 400, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 2000);
        assert_eq!(s.max, 1000);
        assert_eq!(s.counts.iter().sum::<u64>(), 5);
        assert!((s.mean_ns() - 400.0).abs() < 1e-9);
        // Quantiles are bucket-accurate: within a √2 factor of truth.
        let p50 = s.p50();
        assert!(p50 >= 300.0 / std::f64::consts::SQRT_2 && p50 <= 300.0 * std::f64::consts::SQRT_2);
        assert!(s.p99() <= s.max as f64 + 1e-9);
        assert_eq!(HistogramSnapshot::empty().quantile(0.5), 0.0);
    }

    #[test]
    fn merge_equals_recording_into_one_bank() {
        // Quantile correctness on merged banks: merging per-variant
        // histograms must yield exactly the distribution one combined
        // histogram would have recorded.
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        let combined = AtomicHistogram::new();
        for (i, v) in (0..200u64).map(|i| (i, 50 + i * 37)).collect::<Vec<_>>() {
            if i % 2 == 0 { &a } else { &b }.record(v);
            combined.record(v);
        }
        let rollup = AtomicHistogram::new();
        rollup.merge(&a);
        rollup.merge(&b);
        let m = rollup.snapshot();
        let c = combined.snapshot();
        assert_eq!(m.counts, c.counts);
        assert_eq!((m.count, m.sum, m.max), (c.count, c.sum, c.max));
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(m.quantile(q), c.quantile(q), "q={q}");
        }
        // merge is additive, not destructive: source banks unchanged
        assert_eq!(a.count() + b.count(), 200);
    }

    #[test]
    fn merged_combines_distributions() {
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        a.record(100);
        b.record(10_000);
        let m = HistogramSnapshot::merged([a.snapshot(), b.snapshot()]);
        assert_eq!(m.count, 2);
        assert_eq!(m.sum, 10_100);
        assert_eq!(m.max, 10_000);
        assert!(m.quantile(1.0) > m.quantile(0.0));
    }
}
