//! Selector-regret accounting: how much latency the selector's choices
//! actually left on the table.
//!
//! The paper's headline adaptivity claim is that its selection rules
//! lose only 5–12% versus the optimal kernel choice. This module turns
//! that figure into a live metric: every realized normalized cost (the
//! seconds-per-flop number the online selector already backfills onto
//! [`AuditEntry`](crate::obs::AuditEntry) records) is folded against the
//! best known cost among the competing variants of the same
//! `(op, feature bucket)` — the cheapest cell of the EWMA cost table at
//! fold time. The running sums give a cumulative regret ratio
//! (`chosen / best − 1`, 0 when the selector always picked the measured
//! winner) per bucket and per op, plus per-variant excess so
//! `ge-spmm stats --regret` can name the top mis-selected variants.
//! "Heuristic Adaptability to Input Dynamics for SpMM on GPUs" (Dai et
//! al.) motivates tracking this continuously: selection quality decays
//! silently as inputs drift.
//!
//! The tracker lives on [`Metrics`](crate::coordinator::metrics::Metrics)
//! (shared hub, like the audit log and flight recorder); the
//! [`OnlineSelector`](crate::selector::online::OnlineSelector) folds
//! into it from its observation path and re-exposes the report through
//! its `regret_report()` seam. See DESIGN.md §Observability.

use crate::kernels::generator::registry;
use crate::kernels::SparseOp;
use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free `f64` accumulator over bit-cast CAS — the same idiom as the
/// cost EWMAs in `Metrics`.
#[derive(Debug)]
struct AtomicF64(AtomicU64);

impl AtomicF64 {
    fn new() -> Self {
        Self(AtomicU64::new(0.0f64.to_bits()))
    }

    fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// One `(op, bucket)` regret cell: folds plus the chosen/best cost sums.
#[derive(Debug)]
struct Cell {
    folds: AtomicU64,
    chosen: AtomicF64,
    best: AtomicF64,
}

impl Cell {
    fn new() -> Self {
        Self {
            folds: AtomicU64::new(0),
            chosen: AtomicF64::new(),
            best: AtomicF64::new(),
        }
    }
}

/// Running regret counters, per `(op, feature bucket)` and per variant.
/// All operations are lock-free; sizing is fixed at construction (the
/// SpMM/SDDMM bucket counts and the registry length).
#[derive(Debug)]
pub struct RegretTracker {
    spmm: Vec<Cell>,
    sddmm: Vec<Cell>,
    variant_folds: Vec<AtomicU64>,
    variant_excess: Vec<AtomicF64>,
}

/// One per-bucket row of a [`RegretReport`].
#[derive(Clone, Copy, Debug)]
pub struct BucketRegret {
    /// Which op's bucket space this row indexes.
    pub op: SparseOp,
    /// Feature-bucket index (see `selector::online::feature_bucket`).
    pub bucket: usize,
    /// Realized costs folded into this cell.
    pub folds: u64,
    /// Sum of the realized (chosen) normalized costs.
    pub chosen_cost: f64,
    /// Sum of the best known competing costs at each fold.
    pub best_cost: f64,
    /// `chosen_cost / best_cost − 1` (0 for an always-optimal selector).
    pub regret_ratio: f64,
}

/// Per-variant excess row of a [`RegretReport`] — how much a variant
/// cost beyond the bucket's best when it was the one chosen.
#[derive(Clone, Copy, Debug)]
pub struct VariantRegret {
    /// Registry id of the chosen variant.
    pub id: usize,
    /// Registry label of the chosen variant.
    pub label: &'static str,
    /// The variant's op.
    pub op: SparseOp,
    /// Folds attributed to this variant.
    pub folds: u64,
    /// Summed excess ratio (`chosen / best − 1` per fold).
    pub excess: f64,
}

/// Snapshot of the regret counters, ready for rendering.
#[derive(Clone, Debug, Default)]
pub struct RegretReport {
    /// Total folds across both ops.
    pub folds: u64,
    /// Cumulative SpMM regret ratio.
    pub spmm_ratio: f64,
    /// Cumulative SDDMM regret ratio.
    pub sddmm_ratio: f64,
    /// Non-empty per-bucket rows, SpMM first, bucket-ordered.
    pub buckets: Vec<BucketRegret>,
    /// Variants with nonzero excess, worst offender first.
    pub variants: Vec<VariantRegret>,
}

impl RegretTracker {
    /// Build a tracker sized for `spmm_buckets` / `sddmm_buckets`
    /// feature buckets and `variants` registry entries.
    pub fn new(spmm_buckets: usize, sddmm_buckets: usize, variants: usize) -> Self {
        Self {
            spmm: (0..spmm_buckets).map(|_| Cell::new()).collect(),
            sddmm: (0..sddmm_buckets).map(|_| Cell::new()).collect(),
            variant_folds: (0..variants).map(|_| AtomicU64::new(0)).collect(),
            variant_excess: (0..variants).map(|_| AtomicF64::new()).collect(),
        }
    }

    /// Fold one realized cost: the selector chose `variant` in `(op,
    /// bucket)` and realized `chosen_cost`, while the cheapest competing
    /// cell was `best_cost`. Non-finite or non-positive costs and
    /// out-of-range indices are dropped (returns `false`). `best_cost`
    /// is clamped to `chosen_cost` — the realized cost is itself a known
    /// cost, so the best competitor can never be worse.
    pub fn fold(
        &self,
        op: SparseOp,
        bucket: usize,
        variant: usize,
        chosen_cost: f64,
        best_cost: f64,
    ) -> bool {
        if !(chosen_cost.is_finite() && best_cost.is_finite())
            || chosen_cost <= 0.0
            || best_cost <= 0.0
        {
            return false;
        }
        let bank = match op {
            SparseOp::Spmm => &self.spmm,
            SparseOp::Sddmm => &self.sddmm,
        };
        let Some(cell) = bank.get(bucket) else {
            return false;
        };
        let best = best_cost.min(chosen_cost);
        cell.folds.fetch_add(1, Ordering::Relaxed);
        cell.chosen.add(chosen_cost);
        cell.best.add(best);
        let slot = (self.variant_folds.get(variant), self.variant_excess.get(variant));
        if let (Some(f), Some(e)) = slot {
            f.fetch_add(1, Ordering::Relaxed);
            e.add(chosen_cost / best - 1.0);
        }
        true
    }

    /// Total folds across both ops.
    pub fn folds(&self) -> u64 {
        self.spmm
            .iter()
            .chain(self.sddmm.iter())
            .map(|c| c.folds.load(Ordering::Relaxed))
            .sum()
    }

    /// Snapshot the counters into a rendering-ready report.
    pub fn report(&self) -> RegretReport {
        let reg = registry();
        let mut buckets = Vec::new();
        let mut totals = [(0u64, 0.0f64, 0.0f64); 2];
        for (op, bank) in [(SparseOp::Spmm, &self.spmm), (SparseOp::Sddmm, &self.sddmm)] {
            for (bucket, cell) in bank.iter().enumerate() {
                let folds = cell.folds.load(Ordering::Relaxed);
                if folds == 0 {
                    continue;
                }
                let chosen = cell.chosen.get();
                let best = cell.best.get();
                let t = &mut totals[usize::from(op == SparseOp::Sddmm)];
                t.0 += folds;
                t.1 += chosen;
                t.2 += best;
                buckets.push(BucketRegret {
                    op,
                    bucket,
                    folds,
                    chosen_cost: chosen,
                    best_cost: best,
                    regret_ratio: ratio(chosen, best),
                });
            }
        }
        let mut variants: Vec<VariantRegret> = self
            .variant_folds
            .iter()
            .zip(&self.variant_excess)
            .enumerate()
            .filter_map(|(id, (folds, excess))| {
                let folds = folds.load(Ordering::Relaxed);
                let excess = excess.get();
                if folds == 0 || excess <= 0.0 {
                    return None;
                }
                let entry = reg.get(id)?;
                Some(VariantRegret {
                    id,
                    label: entry.label,
                    op: entry.variant.op,
                    folds,
                    excess,
                })
            })
            .collect();
        variants.sort_by(|a, b| b.excess.total_cmp(&a.excess));
        RegretReport {
            folds: totals[0].0 + totals[1].0,
            spmm_ratio: ratio(totals[0].1, totals[0].2),
            sddmm_ratio: ratio(totals[1].1, totals[1].2),
            buckets,
            variants,
        }
    }
}

/// `chosen / best − 1`, guarded against empty cells.
fn ratio(chosen: f64, best: f64) -> f64 {
    if best > 0.0 {
        (chosen / best - 1.0).max(0.0)
    } else {
        0.0
    }
}

impl RegretReport {
    /// JSON rendering used by the stats snapshot (and round-tripped by
    /// the file-mode Prometheus renderer).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("folds", json::num(self.folds as f64)),
            ("spmm_ratio", json::num(self.spmm_ratio)),
            ("sddmm_ratio", json::num(self.sddmm_ratio)),
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|b| {
                            json::obj(vec![
                                ("op", json::s(b.op.label())),
                                ("bucket", json::num(b.bucket as f64)),
                                ("folds", json::num(b.folds as f64)),
                                ("chosen_cost", json::num(b.chosen_cost)),
                                ("best_cost", json::num(b.best_cost)),
                                ("regret_ratio", json::num(b.regret_ratio)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "variants",
                Json::Arr(
                    self.variants
                        .iter()
                        .map(|v| {
                            json::obj(vec![
                                ("op", json::s(v.op.label())),
                                ("variant", json::s(v.label)),
                                ("folds", json::num(v.folds as f64)),
                                ("excess", json::num(v.excess)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Multi-line table for `ge-spmm stats --regret`: one row per
    /// non-empty bucket plus the top mis-selected variants.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "regret: folds={} spmm_ratio={:.4} sddmm_ratio={:.4}\n",
            self.folds, self.spmm_ratio, self.sddmm_ratio
        ));
        if self.buckets.is_empty() {
            out.push_str("  (no realized costs folded yet — run with --online traffic)\n");
            return out;
        }
        out.push_str("  op     bucket  folds  regret\n");
        for b in &self.buckets {
            out.push_str(&format!(
                "  {:<6} {:>6}  {:>5}  {:.4}\n",
                b.op.label(),
                b.bucket,
                b.folds,
                b.regret_ratio
            ));
        }
        if !self.variants.is_empty() {
            out.push_str("  top mis-selected variants:\n");
            for v in self.variants.iter().take(5) {
                out.push_str(&format!(
                    "    {:<6} {:<10} folds={} excess={:.4}\n",
                    v.op.label(),
                    v.label,
                    v.folds,
                    v.excess
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_choices_accumulate_zero_regret() {
        let t = RegretTracker::new(12, 6, registry().len());
        for _ in 0..10 {
            assert!(t.fold(SparseOp::Spmm, 3, 0, 2.0e-12, 2.0e-12));
        }
        let r = t.report();
        assert_eq!(r.folds, 10);
        assert_eq!(r.spmm_ratio, 0.0);
        assert_eq!(r.buckets.len(), 1);
        assert_eq!(r.buckets[0].regret_ratio, 0.0);
        assert!(r.variants.is_empty(), "no excess, no offenders");
    }

    #[test]
    fn mis_selection_shows_up_as_ratio_and_offender() {
        let t = RegretTracker::new(12, 6, registry().len());
        // chosen twice as expensive as the best competitor, 4 times
        for _ in 0..4 {
            t.fold(SparseOp::Spmm, 1, 2, 4.0e-12, 2.0e-12);
        }
        let r = t.report();
        assert_eq!(r.folds, 4);
        assert!((r.spmm_ratio - 1.0).abs() < 1e-9, "{}", r.spmm_ratio);
        assert_eq!(r.variants.len(), 1);
        assert_eq!(r.variants[0].id, 2);
        assert!((r.variants[0].excess - 4.0).abs() < 1e-9);
        assert!(r.render().contains("top mis-selected"));
    }

    #[test]
    fn ops_accumulate_independently() {
        let t = RegretTracker::new(12, 6, registry().len());
        t.fold(SparseOp::Spmm, 0, 0, 3.0e-12, 1.0e-12);
        t.fold(SparseOp::Sddmm, 0, 10, 1.0e-12, 1.0e-12);
        let r = t.report();
        assert!((r.spmm_ratio - 2.0).abs() < 1e-9);
        assert_eq!(r.sddmm_ratio, 0.0);
        assert_eq!(r.buckets.len(), 2);
        assert_eq!(r.buckets[0].op, SparseOp::Spmm);
        assert_eq!(r.buckets[1].op, SparseOp::Sddmm);
    }

    #[test]
    fn degenerate_folds_are_dropped() {
        let t = RegretTracker::new(12, 6, registry().len());
        assert!(!t.fold(SparseOp::Spmm, 0, 0, f64::NAN, 1.0));
        assert!(!t.fold(SparseOp::Spmm, 0, 0, 0.0, 1.0));
        assert!(!t.fold(SparseOp::Spmm, 99, 0, 1.0, 1.0), "bucket range");
        assert_eq!(t.folds(), 0);
        // a best "worse" than chosen clamps to chosen: zero regret
        assert!(t.fold(SparseOp::Spmm, 0, 0, 1.0e-12, 5.0e-12));
        assert_eq!(t.report().spmm_ratio, 0.0);
    }

    #[test]
    fn report_json_is_parseable_and_stable() {
        let t = RegretTracker::new(12, 6, registry().len());
        t.fold(SparseOp::Spmm, 2, 1, 2.0e-9, 1.0e-9);
        let j = t.report().to_json();
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
        assert_eq!(j.get("folds").and_then(Json::as_f64), Some(1.0));
    }
}
