//! CSR (compressed sparse row) — the canonical kernel input format.

use super::coo::CooMatrix;

/// CSR matrix: `indptr[r]..indptr[r+1]` indexes the non-zeros of row `r`
/// in `indices`/`values`. Column indices within a row are sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// Mutation epoch. Freshly built matrices start at 0; every applied
    /// [`crate::sparse::delta::EdgeDelta`] batch bumps it. The epoch is
    /// folded into [`CsrMatrix::fingerprint`], so a mutated matrix never
    /// aliases its pre-mutation prepared state in the serving cache even
    /// if a delta round-trips the content back to an earlier byte pattern.
    pub epoch: u64,
}

impl CsrMatrix {
    /// Build from COO (canonicalizes a copy: sorts, sums duplicates).
    pub fn from_coo(coo: &CooMatrix) -> Self {
        let mut c = coo.clone();
        c.canonicalize();
        let mut indptr = vec![0u32; c.rows + 1];
        for &r in &c.row_idx {
            indptr[r as usize + 1] += 1;
        }
        for r in 0..c.rows {
            indptr[r + 1] += indptr[r];
        }
        Self {
            rows: c.rows,
            cols: c.cols,
            indptr,
            indices: c.col_idx,
            values: c.values,
            epoch: 0,
        }
    }

    /// Build directly from raw parts (validates invariants).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<u32>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap() as usize, indices.len(), "indptr tail");
        for r in 0..rows {
            assert!(indptr[r] <= indptr[r + 1], "indptr must be nondecreasing");
        }
        assert!(
            indices.iter().all(|&c| (c as usize) < cols),
            "column index out of bounds"
        );
        Self {
            rows,
            cols,
            indptr,
            indices,
            values,
            epoch: 0,
        }
    }

    /// Number of non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// 64-bit (content, epoch) fingerprint (FNV-1a over the dimensions,
    /// the CSR layout arrays, the value bit patterns and the mutation
    /// epoch). Byte-identical matrices at the same epoch always
    /// fingerprint equal, regardless of how they were built — the cache
    /// key of the serving layer's prepared-matrix registry
    /// (`coordinator::cache`). A delta-mutated matrix (bumped epoch)
    /// fingerprints differently from every earlier state of the same
    /// handle, so stale prepared entries are invalidated rather than
    /// served. Distinct contents can collide in principle (FNV-1a is a
    /// 64-bit non-cryptographic hash): vanishingly unlikely for organic
    /// traffic, but do not key security decisions on it. O(nnz), i.e. no
    /// more than one backend `prepare` pass.
    pub fn fingerprint(&self) -> u64 {
        fn eat(h: u64, x: u64) -> u64 {
            (h ^ x).wrapping_mul(0x0000_0100_0000_01b3)
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = eat(h, self.rows as u64);
        h = eat(h, self.cols as u64);
        for &p in &self.indptr {
            h = eat(h, p as u64);
        }
        for &c in &self.indices {
            h = eat(h, c as u64);
        }
        for &v in &self.values {
            h = eat(h, v.to_bits() as u64);
        }
        eat(h, self.epoch)
    }

    /// Advance the mutation epoch (called by
    /// [`crate::sparse::delta::EdgeDelta::apply`] after a batch lands).
    /// Epoch-aware fingerprints keep the serving layer's cache honest
    /// across in-place mutation.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Heap footprint of the CSR arrays in bytes. The serving layer's
    /// cache budget is denominated in these — a backend-independent proxy
    /// for the size of the prepared state built from this matrix.
    pub fn heap_bytes(&self) -> usize {
        (self.indptr.len() + self.indices.len()) * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f32>()
    }

    /// Non-zero count of one row.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.indptr[r + 1] - self.indptr[r]) as usize
    }

    /// `(columns, values)` slices of one row.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[r] as usize;
        let hi = self.indptr[r + 1] as usize;
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Row lengths as f64 (feature extraction input).
    pub fn row_lengths(&self) -> Vec<f64> {
        (0..self.rows).map(|r| self.row_nnz(r) as f64).collect()
    }

    /// Extract rows `range` as a standalone CSR matrix over the same
    /// column space — the shard operand of `crate::shard`. O(slice nnz).
    pub fn row_slice(&self, range: std::ops::Range<usize>) -> CsrMatrix {
        assert!(
            range.start <= range.end && range.end <= self.rows,
            "row slice {}..{} out of bounds for {} rows",
            range.start,
            range.end,
            self.rows
        );
        let base = self.indptr[range.start];
        let lo = base as usize;
        let hi = self.indptr[range.end] as usize;
        CsrMatrix {
            rows: range.end - range.start,
            cols: self.cols,
            indptr: self.indptr[range.start..=range.end]
                .iter()
                .map(|&p| p - base)
                .collect(),
            indices: self.indices[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
            epoch: self.epoch,
        }
    }

    /// Same sparsity pattern, new values (`values.len()` must equal
    /// `nnz`). The SDDMM output constructor: `sample(A, U·Vᵀ)` produces
    /// one value per non-zero of `A` in stream order, and attention-style
    /// workloads feed that straight back into SpMM as a matrix sharing
    /// `A`'s pattern (`crate::gnn::attention`).
    pub fn with_values(&self, values: Vec<f32>) -> CsrMatrix {
        assert_eq!(values.len(), self.nnz(), "value count must match nnz");
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values,
            epoch: self.epoch,
        }
    }

    /// Transposed copy (CSC of self, re-expressed as CSR of Aᵀ) via
    /// counting sort — O(nnz + rows + cols).
    pub fn transposed(&self) -> CsrMatrix {
        let mut indptr = vec![0u32; self.cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            indptr[c + 1] += indptr[c];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for k in 0..cols.len() {
                let c = cols[k] as usize;
                let dst = cursor[c] as usize;
                indices[dst] = r as u32;
                values[dst] = vals[k];
                cursor[c] += 1;
            }
        }
        CsrMatrix {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            values,
            epoch: self.epoch,
        }
    }

    /// Dense row-major copy (tests only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for k in 0..cols.len() {
                out[r * self.cols + cols[k] as usize] += vals[k];
            }
        }
        out
    }

    /// Normalize rows to sum 1 (left stochastic), skipping empty rows.
    /// Used for GCN-style mean aggregation.
    pub fn row_normalized(&self) -> CsrMatrix {
        let mut out = self.clone();
        for r in 0..out.rows {
            let lo = out.indptr[r] as usize;
            let hi = out.indptr[r + 1] as usize;
            let sum: f32 = out.values[lo..hi].iter().sum();
            if sum != 0.0 {
                for v in &mut out.values[lo..hi] {
                    *v /= sum;
                }
            }
        }
        out
    }

    /// Symmetric GCN normalization  D^{-1/2} (A + I) D^{-1/2}.
    pub fn gcn_normalized(&self) -> CsrMatrix {
        assert_eq!(self.rows, self.cols, "gcn normalization needs square A");
        // A + I as COO
        let mut coo = CooMatrix::new(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for k in 0..cols.len() {
                coo.push(r, cols[k] as usize, vals[k]);
            }
            coo.push(r, r, 1.0);
        }
        let a_hat = CsrMatrix::from_coo(&coo);
        let deg: Vec<f32> = (0..a_hat.rows)
            .map(|r| a_hat.row(r).1.iter().sum::<f32>())
            .collect();
        let mut out = a_hat.clone();
        for r in 0..out.rows {
            let lo = out.indptr[r] as usize;
            let hi = out.indptr[r + 1] as usize;
            let dr = deg[r].max(1e-12).sqrt();
            for k in lo..hi {
                let c = out.indices[k] as usize;
                let dc = deg[c].max(1e-12).sqrt();
                out.values[k] /= dr * dc;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::run_prop;

    fn small() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(2, 1, 4.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn from_coo_layout() {
        let m = small();
        assert_eq!(m.indptr, vec![0, 2, 2, 4]);
        assert_eq!(m.indices, vec![0, 2, 0, 1]);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        let (c, v) = m.row(2);
        assert_eq!(c, &[0, 1]);
        assert_eq!(v, &[3.0, 4.0]);
    }

    #[test]
    fn transpose_matches_dense() {
        let m = small();
        let t = m.transposed();
        let d = m.to_dense();
        let td = t.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], td[c * 3 + r]);
            }
        }
    }

    #[test]
    fn transpose_matches_dense_property() {
        // CSC-view round trip: Aᵀ's dense form is the element-wise
        // transpose of A's, across shapes and densities (not just the
        // fixed `small()` fixture).
        run_prop("csr transpose vs dense", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let density = g.f64_in(0.01, 0.5);
            let coo = CooMatrix::random_uniform(rows, cols, density, g.rng());
            let m = CsrMatrix::from_coo(&coo);
            let t = m.transposed();
            if (t.rows, t.cols) != (cols, rows) {
                return Err(format!("shape {}x{}", t.rows, t.cols));
            }
            let d = m.to_dense();
            let td = t.to_dense();
            for r in 0..rows {
                for c in 0..cols {
                    if d[r * cols + c] != td[c * rows + r] {
                        return Err(format!("[{r},{c}] {rows}x{cols}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn with_values_swaps_values_only() {
        let m = small();
        let s = m.with_values(vec![9.0, 8.0, 7.0, 6.0]);
        assert_eq!(s.indptr, m.indptr);
        assert_eq!(s.indices, m.indices);
        assert_eq!(s.values, vec![9.0, 8.0, 7.0, 6.0]);
        assert_eq!((s.rows, s.cols), (m.rows, m.cols));
    }

    #[test]
    #[should_panic(expected = "value count")]
    fn with_values_checks_length() {
        small().with_values(vec![1.0]);
    }

    #[test]
    fn transpose_is_involution_property() {
        run_prop("csr transpose involution", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let density = g.f64_in(0.01, 0.5);
            let coo = CooMatrix::random_uniform(rows, cols, density, g.rng());
            let m = CsrMatrix::from_coo(&coo);
            let tt = m.transposed().transposed();
            if tt == m {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} density {density}"))
            }
        });
    }

    #[test]
    fn coo_csr_dense_agree_property() {
        run_prop("coo->csr preserves dense", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            if csr.to_dense() == coo.to_dense() {
                Ok(())
            } else {
                Err(format!("{rows}x{cols}"))
            }
        });
    }

    #[test]
    fn row_slice_extracts_contiguous_rows() {
        let m = small();
        let s = m.row_slice(1..3);
        assert_eq!((s.rows, s.cols), (2, 3));
        assert_eq!(s.indptr, vec![0, 0, 2]);
        assert_eq!(s.to_dense(), &m.to_dense()[3..9]);
        // degenerate slices
        assert_eq!(m.row_slice(0..0).nnz(), 0);
        assert_eq!(m.row_slice(0..3), m);
    }

    #[test]
    fn row_slices_reassemble_to_dense_property() {
        run_prop("csr row slices reassemble", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let m = CsrMatrix::from_coo(&coo);
            let cut = g.usize_in(0, rows + 1);
            let (head, tail) = (m.row_slice(0..cut), m.row_slice(cut..rows));
            let mut dense = head.to_dense();
            dense.extend_from_slice(&tail.to_dense());
            if dense == m.to_dense() {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} cut {cut}"))
            }
        });
    }

    #[test]
    fn row_normalized_rows_sum_to_one() {
        let mut rng = Xoshiro256::seeded(21);
        let coo = CooMatrix::random_uniform(50, 50, 0.1, &mut rng);
        let m = CsrMatrix::from_coo(&coo).row_normalized();
        for r in 0..m.rows {
            let (_, vals) = m.row(r);
            if !vals.is_empty() {
                let s: f32 = vals.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
            }
        }
    }

    #[test]
    fn gcn_normalized_is_symmetric_for_symmetric_input() {
        // build a symmetric matrix
        let mut coo = CooMatrix::new(6, 6);
        for (a, b) in [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (1, 4)] {
            coo.push(a, b, 1.0);
            coo.push(b, a, 1.0);
        }
        let norm = CsrMatrix::from_coo(&coo).gcn_normalized();
        let d = norm.to_dense();
        for r in 0..6 {
            for c in 0..6 {
                assert!((d[r * 6 + c] - d[c * 6 + r]).abs() < 1e-6);
            }
        }
        // self-loops present
        for r in 0..6 {
            assert!(d[r * 6 + r] > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "indptr tail")]
    fn from_parts_validates() {
        CsrMatrix::from_parts(2, 2, vec![0, 1, 3], vec![0, 1], vec![1.0, 2.0]);
    }

    #[test]
    fn fingerprint_is_content_determined() {
        let m = small();
        // rebuilding from the same triplets fingerprints identically
        assert_eq!(m.fingerprint(), small().fingerprint());
        assert_eq!(m.fingerprint(), m.clone().fingerprint());
        // any content change moves the fingerprint
        let mut value_changed = m.clone();
        value_changed.values[0] += 1.0;
        assert_ne!(m.fingerprint(), value_changed.fingerprint());
        let mut index_changed = m.clone();
        index_changed.indices[0] += 1;
        assert_ne!(m.fingerprint(), index_changed.fingerprint());
        // same (empty) content at transposed dimensions differs
        let a = CsrMatrix::from_coo(&CooMatrix::new(3, 4));
        let b = CsrMatrix::from_coo(&CooMatrix::new(4, 3));
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn heap_bytes_counts_the_three_arrays() {
        let m = small(); // indptr 4, indices 4, values 4
        assert_eq!(m.heap_bytes(), (4 + 4) * 4 + 4 * 4);
    }

    #[test]
    fn epoch_moves_the_fingerprint_without_touching_content() {
        let m = small();
        let mut bumped = m.clone();
        bumped.bump_epoch();
        assert_eq!(bumped.epoch, 1);
        // arrays are byte-identical, but the serving cache must not alias
        // the mutated matrix with its pre-mutation prepared state
        assert_eq!(bumped.indptr, m.indptr);
        assert_eq!(bumped.indices, m.indices);
        assert_eq!(bumped.values, m.values);
        assert_ne!(m.fingerprint(), bumped.fingerprint());
        // each further bump keeps moving it
        let fp1 = bumped.fingerprint();
        bumped.bump_epoch();
        assert_ne!(fp1, bumped.fingerprint());
    }

    #[test]
    fn epoch_propagates_through_derived_matrices() {
        let mut m = small();
        m.bump_epoch();
        m.bump_epoch();
        assert_eq!(m.row_slice(0..2).epoch, 2);
        assert_eq!(m.with_values(vec![1.0; m.nnz()]).epoch, 2);
        assert_eq!(m.transposed().epoch, 2);
        // fresh constructions always start at 0
        assert_eq!(small().epoch, 0);
        assert_eq!(
            CsrMatrix::from_parts(1, 1, vec![0, 1], vec![0], vec![1.0]).epoch,
            0
        );
    }
}
