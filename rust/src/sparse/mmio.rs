//! MatrixMarket (`.mtx`) I/O — the SuiteSparse interchange format.
//!
//! Supports `matrix coordinate {real,integer,pattern} {general,symmetric}`,
//! which covers the overwhelming majority of SuiteSparse. Pattern entries
//! get value 1.0; symmetric files are expanded to general on read.

use super::coo::CooMatrix;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Symmetry {
    General,
    Symmetric,
}

/// Read a MatrixMarket coordinate file into COO (1-based indices converted
/// to 0-based; symmetric entries mirrored).
pub fn read_matrix_market(path: &Path) -> Result<CooMatrix> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    read_matrix_market_from(BufReader::new(file))
}

/// Read from any buffered reader (unit-testable without files).
pub fn read_matrix_market_from<R: BufRead>(reader: R) -> Result<CooMatrix> {
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty MatrixMarket file"),
        }
    };
    let toks: Vec<&str> = header.split_whitespace().collect();
    if toks.len() < 5 || toks[0] != "%%MatrixMarket" || toks[1] != "matrix" {
        bail!("bad MatrixMarket header: {header}");
    }
    if toks[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", toks[2]);
    }
    let field = match toks[3] {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => bail!("unsupported field type: {other}"),
    };
    let symmetry = match toks[4] {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        other => bail!("unsupported symmetry: {other}"),
    };

    // skip comments, find the size line
    let size_line = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                break l;
            }
            None => bail!("missing size line"),
        }
    };
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .with_context(|| format!("bad size line: {size_line}"))?;
    if dims.len() != 3 {
        bail!("size line must have 3 fields: {size_line}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = CooMatrix::new(rows, cols);
    let mut read = 0usize;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        let expect_fields = if field == Field::Pattern { 2 } else { 3 };
        if parts.len() < expect_fields {
            bail!("bad entry line: {t}");
        }
        let r: usize = parts[0].parse().with_context(|| format!("row in: {t}"))?;
        let c: usize = parts[1].parse().with_context(|| format!("col in: {t}"))?;
        if r == 0 || c == 0 || r > rows || c > cols {
            bail!("entry ({r},{c}) out of bounds for {rows}x{cols}");
        }
        let v: f32 = match field {
            Field::Pattern => 1.0,
            _ => parts[2].parse().with_context(|| format!("value in: {t}"))?,
        };
        coo.push(r - 1, c - 1, v);
        if symmetry == Symmetry::Symmetric && r != c {
            coo.push(c - 1, r - 1, v);
        }
        read += 1;
    }
    if read != nnz {
        bail!("expected {nnz} entries, found {read}");
    }
    Ok(coo)
}

/// Write COO as a `general real` MatrixMarket file.
pub fn write_matrix_market(path: &Path, coo: &CooMatrix) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by ge-spmm")?;
    writeln!(f, "{} {} {}", coo.rows, coo.cols, coo.nnz())?;
    for i in 0..coo.nnz() {
        writeln!(
            f,
            "{} {} {}",
            coo.row_idx[i] + 1,
            coo.col_idx[i] + 1,
            coo.values[i]
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn reads_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 4 2\n\
                    1 2 1.5\n\
                    3 4 -2\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!((m.rows, m.cols, m.nnz()), (3, 4, 2));
        assert_eq!(m.to_dense()[0 * 4 + 1], 1.5);
        assert_eq!(m.to_dense()[2 * 4 + 3], -2.0);
    }

    #[test]
    fn reads_pattern_symmetric() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                    3 3 2\n\
                    2 1\n\
                    3 3\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        // (2,1) mirrored to (1,2); diagonal (3,3) not duplicated
        assert_eq!(m.nnz(), 3);
        let d = m.to_dense();
        assert_eq!(d[1 * 3 + 0], 1.0);
        assert_eq!(d[0 * 3 + 1], 1.0);
        assert_eq!(d[2 * 3 + 2], 1.0);
    }

    #[test]
    fn rejects_bad_input() {
        for text in [
            "not a header\n1 1 0\n",
            "%%MatrixMarket matrix array real general\n1 1 0\n",
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 1 1.0\n",
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n",
        ] {
            assert!(
                read_matrix_market_from(Cursor::new(text)).is_err(),
                "should reject: {text}"
            );
        }
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let mut coo = CooMatrix::new(5, 7);
        coo.push(0, 6, 1.0);
        coo.push(4, 0, -3.5);
        coo.push(2, 3, 0.25);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ge_spmm_mmio_test_{}.mtx", std::process::id()));
        write_matrix_market(&path, &coo).unwrap();
        let back = read_matrix_market(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.to_dense(), coo.to_dense());
    }
}
