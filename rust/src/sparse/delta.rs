//! Edge-delta batches — dynamic-graph mutation of the sparse formats.
//!
//! Serving traffic rarely gets an immutable graph: edges arrive and
//! disappear continuously, and the adaptive-selection rules of the
//! source paper are only as good as the features they were computed
//! from ("Heuristic Adaptability to Input Dynamics for SpMM on GPUs",
//! Dai et al. — see PAPERS.md). [`EdgeDelta`] is the mutation unit: a
//! batch of edge insertions and deletions applied atomically to a
//! [`CsrMatrix`], classified as **value-only** (every insertion lands
//! on an existing coordinate, every deletion is a no-op — the sparsity
//! pattern is untouched and prepared layouts can be patched in place)
//! or **structural** (the pattern changes — one O(nnz + batch)
//! merge-rebuild pass, the batched generalization of shifting row
//! slack). The distinction is what [`DeltaReport::structural`] carries
//! upward: `backend::SpmmBackend::prepare_delta` patches prepared
//! state for value-only batches and falls back to a full `prepare`
//! for structural ones, and `coordinator::SpmmEngine::apply_delta`
//! reports which path ran in a [`DeltaOutcome`].
//!
//! Batch semantics (the contract the differential replay harness in
//! `tests/delta_agreement.rs` pins against a rebuild-from-COO oracle):
//!
//! - **Deletes apply first, then inserts.** A delete and an insert at
//!   the same coordinate therefore compose to an update.
//! - **Duplicate inserts are last-wins** per coordinate.
//! - **Deleting an absent edge is a no-op**, not an error.
//! - Inserted values are kept verbatim — an explicit `0.0` stays a
//!   stored non-zero, matching `CooMatrix::canonicalize`.
//!
//! Every batch that changes anything bumps the matrix's mutation
//! epoch, which [`CsrMatrix::fingerprint`] folds in so the serving
//! cache can never alias a mutated matrix with stale prepared state.

use super::csr::CsrMatrix;

/// What one applied [`EdgeDelta`] batch did to the matrix content.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaReport {
    /// Net new edges (insert at a previously absent coordinate).
    pub inserted: usize,
    /// Net removed edges (delete of a present coordinate with no
    /// overriding insert in the same batch).
    pub deleted: usize,
    /// Value rewrites of surviving edges (insert onto a present
    /// coordinate, including delete-then-insert in one batch).
    pub updated: usize,
    /// Whether the sparsity pattern changed (`inserted + deleted > 0`).
    /// Value-only batches admit in-place patching of prepared layouts.
    pub structural: bool,
}

impl DeltaReport {
    /// Total edges the batch actually changed. Zero means the batch
    /// was a no-op (empty, or only deletes of absent edges) and the
    /// epoch was left alone.
    pub fn touched(&self) -> usize {
        self.inserted + self.deleted + self.updated
    }
}

/// Outcome of routing one batch through the serving layer
/// (`coordinator::SpmmEngine::apply_delta`): the content-level
/// [`DeltaReport`] plus what the prepared state and the selectors did
/// about it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaOutcome {
    /// Content-level classification of the applied batch.
    pub report: DeltaReport,
    /// `true` — the backend patched the existing prepared state in
    /// place (`prepare_delta`); `false` — it fell back to a full
    /// re-prepare.
    pub patched: bool,
    /// Matrix mutation epoch after the batch.
    pub epoch: u64,
    /// Whether post-batch features drifted past the reselection
    /// threshold relative to the features the current kernel choices
    /// were made from.
    pub drift: bool,
    /// Whether drift re-ran the static selector decisions (visible as
    /// `delta`-grain entries in the audit log) and reset the matching
    /// online-selector cost buckets.
    pub reselected: bool,
}

/// A batch of edge insertions and deletions against one sparse matrix.
///
/// Build with [`insert`](EdgeDelta::insert) / [`delete`](EdgeDelta::delete)
/// in any order, then [`apply`](EdgeDelta::apply) to a [`CsrMatrix`].
/// The batch itself is immutable under `apply` and can be replayed
/// against multiple matrices (the differential harness applies each
/// batch to both the patched engine and a from-scratch rebuild).
#[derive(Clone, Debug, Default)]
pub struct EdgeDelta {
    ins: Vec<(u32, u32, f32)>,
    dels: Vec<(u32, u32)>,
}

impl EdgeDelta {
    /// Empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue `a[r, c] = v` (inserts the edge, or rewrites its value if
    /// it already exists; last queued wins per coordinate).
    pub fn insert(&mut self, r: usize, c: usize, v: f32) -> &mut Self {
        self.ins.push((r as u32, c as u32, v));
        self
    }

    /// Queue removal of `a[r, c]` (no-op at apply time if absent).
    pub fn delete(&mut self, r: usize, c: usize) -> &mut Self {
        self.dels.push((r as u32, c as u32));
        self
    }

    /// Queued operation count (before per-coordinate normalization).
    pub fn len(&self) -> usize {
        self.ins.len() + self.dels.len()
    }

    /// `true` if no operations are queued.
    pub fn is_empty(&self) -> bool {
        self.ins.is_empty() && self.dels.is_empty()
    }

    /// Apply the batch to `csr` and report what changed. Bumps the
    /// matrix epoch iff the batch touched at least one edge. Panics on
    /// out-of-bounds coordinates (mutations must target the matrix's
    /// existing shape — growing the dimensions is a re-registration,
    /// not a delta).
    ///
    /// Value-only batches patch `csr.values` in place in
    /// O(batch · log max_row); structural batches run one
    /// O(nnz + batch) merge-rebuild of the three CSR arrays.
    pub fn apply(&self, csr: &mut CsrMatrix) -> DeltaReport {
        let (ins, dels) = self.normalized();
        for &(r, c, _) in &ins {
            assert!(
                (r as usize) < csr.rows && (c as usize) < csr.cols,
                "insert ({r}, {c}) out of bounds for {}x{}",
                csr.rows,
                csr.cols
            );
        }
        for &(r, c) in &dels {
            assert!(
                (r as usize) < csr.rows && (c as usize) < csr.cols,
                "delete ({r}, {c}) out of bounds for {}x{}",
                csr.rows,
                csr.cols
            );
        }

        let ins_covers = |r: u32, c: u32| {
            ins.binary_search_by_key(&(r, c), |&(ir, ic, _)| (ir, ic))
                .is_ok()
        };
        let structural = ins
            .iter()
            .any(|&(r, c, _)| find(csr, r, c).is_none())
            || dels
                .iter()
                .any(|&(r, c)| find(csr, r, c).is_some() && !ins_covers(r, c));

        let report = if structural {
            self.apply_structural(csr, &ins, &dels)
        } else {
            // Every insert lands on an existing coordinate and every
            // delete is overridden or absent: rewrite values in place.
            let mut updated = 0;
            for &(r, c, v) in &ins {
                let pos = find(csr, r, c).expect("value-only batch targets present edges");
                csr.values[pos] = v;
                updated += 1;
            }
            DeltaReport {
                inserted: 0,
                deleted: 0,
                updated,
                structural: false,
            }
        };
        if report.touched() > 0 {
            csr.bump_epoch();
        }
        report
    }

    /// Per-coordinate normal form: deletes sorted and deduplicated,
    /// inserts sorted by coordinate with last-wins on duplicates.
    fn normalized(&self) -> (Vec<(u32, u32, f32)>, Vec<(u32, u32)>) {
        let mut ins = self.ins.clone();
        // stable, so the latest queued insert is last within each run
        ins.sort_by_key(|&(r, c, _)| (r, c));
        let mut last_wins: Vec<(u32, u32, f32)> = Vec::with_capacity(ins.len());
        for e in ins {
            match last_wins.last_mut() {
                Some(prev) if prev.0 == e.0 && prev.1 == e.1 => *prev = e,
                _ => last_wins.push(e),
            }
        }
        let mut dels = self.dels.clone();
        dels.sort_unstable();
        dels.dedup();
        (last_wins, dels)
    }

    /// One merge pass over the whole matrix: for each row, merge the
    /// surviving old entries with the row's inserts (both sorted by
    /// column), skipping net-deleted columns. Column order within each
    /// row is preserved by construction.
    fn apply_structural(
        &self,
        csr: &mut CsrMatrix,
        ins: &[(u32, u32, f32)],
        dels: &[(u32, u32)],
    ) -> DeltaReport {
        let mut indptr = Vec::with_capacity(csr.rows + 1);
        indptr.push(0u32);
        let mut indices = Vec::with_capacity(csr.nnz() + ins.len());
        let mut values = Vec::with_capacity(csr.nnz() + ins.len());
        let (mut inserted, mut deleted, mut updated) = (0usize, 0usize, 0usize);
        let (mut ic, mut dc) = (0usize, 0usize); // batch cursors
        for r in 0..csr.rows as u32 {
            let row_ins_start = ic;
            while ic < ins.len() && ins[ic].0 == r {
                ic += 1;
            }
            let row_ins = &ins[row_ins_start..ic];
            let row_del_start = dc;
            while dc < dels.len() && dels[dc].0 == r {
                dc += 1;
            }
            let row_del = &dels[row_del_start..dc];
            let del_covers = |c: u32| row_del.binary_search_by_key(&c, |d| d.1).is_ok();

            let (cols, vals) = csr.row(r as usize);
            let (mut i, mut j) = (0usize, 0usize);
            while i < cols.len() || j < row_ins.len() {
                if j >= row_ins.len() || (i < cols.len() && cols[i] < row_ins[j].1) {
                    // old-only column: survives unless net-deleted
                    if del_covers(cols[i]) {
                        deleted += 1;
                    } else {
                        indices.push(cols[i]);
                        values.push(vals[i]);
                    }
                    i += 1;
                } else if i >= cols.len() || cols[i] > row_ins[j].1 {
                    // insert-only column: net new edge
                    inserted += 1;
                    indices.push(row_ins[j].1);
                    values.push(row_ins[j].2);
                    j += 1;
                } else {
                    // both: the insert rewrites the value (and wins
                    // over any delete at the same coordinate)
                    updated += 1;
                    indices.push(row_ins[j].1);
                    values.push(row_ins[j].2);
                    i += 1;
                    j += 1;
                }
            }
            indptr.push(indices.len() as u32);
        }
        csr.indptr = indptr;
        csr.indices = indices;
        csr.values = values;
        DeltaReport {
            inserted,
            deleted,
            updated,
            structural: true,
        }
    }
}

/// Stream position of `a[r, c]`, if present (binary search within the
/// row — column indices are sorted per the CSR invariant).
fn find(csr: &CsrMatrix, r: u32, c: u32) -> Option<usize> {
    let lo = csr.indptr[r as usize] as usize;
    let hi = csr.indptr[r as usize + 1] as usize;
    csr.indices[lo..hi].binary_search(&c).ok().map(|k| lo + k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    fn small() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(2, 1, 4.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn value_only_batch_patches_in_place() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(0, 0, 9.0).insert(2, 1, -4.0);
        let rep = d.apply(&mut m);
        assert_eq!(
            rep,
            DeltaReport {
                inserted: 0,
                deleted: 0,
                updated: 2,
                structural: false
            }
        );
        assert_eq!(m.indptr, vec![0, 2, 2, 4]);
        assert_eq!(m.indices, vec![0, 2, 0, 1]);
        assert_eq!(m.values, vec![9.0, 2.0, 3.0, -4.0]);
        assert_eq!(m.epoch, 1);
    }

    #[test]
    fn structural_batch_merges_inserts_and_deletes() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(1, 1, 5.0) // net insert into the empty row
            .delete(0, 2) // net delete
            .insert(2, 2, 6.0); // net insert at the row tail
        let rep = d.apply(&mut m);
        assert_eq!(
            rep,
            DeltaReport {
                inserted: 2,
                deleted: 1,
                updated: 0,
                structural: true
            }
        );
        // [[1, 0, 0], [0, 5, 0], [3, 4, 6]]
        assert_eq!(m.indptr, vec![0, 1, 2, 5]);
        assert_eq!(m.indices, vec![0, 1, 0, 1, 2]);
        assert_eq!(m.values, vec![1.0, 5.0, 3.0, 4.0, 6.0]);
        assert_eq!(m.epoch, 1);
    }

    #[test]
    fn delete_of_absent_edge_is_a_noop() {
        let mut m = small();
        let before = m.clone();
        let mut d = EdgeDelta::new();
        d.delete(1, 1).delete(0, 1);
        let rep = d.apply(&mut m);
        assert_eq!(rep.touched(), 0);
        assert!(!rep.structural);
        assert_eq!(m, before, "no-op batch leaves matrix (and epoch) alone");
        assert_eq!(m.epoch, 0);
    }

    #[test]
    fn duplicate_inserts_are_last_wins() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(1, 0, 1.0).insert(1, 0, 2.0).insert(1, 0, 3.0);
        let rep = d.apply(&mut m);
        assert_eq!(rep.inserted, 1);
        let (cols, vals) = m.row(1);
        assert_eq!(cols, &[0]);
        assert_eq!(vals, &[3.0]);
    }

    #[test]
    fn delete_then_insert_composes_to_an_update() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.delete(0, 0).insert(0, 0, 7.0);
        let rep = d.apply(&mut m);
        assert_eq!(
            rep,
            DeltaReport {
                inserted: 0,
                deleted: 0,
                updated: 1,
                structural: false
            }
        );
        assert_eq!(m.row(0).1, &[7.0, 2.0]);
    }

    #[test]
    fn row_can_shrink_to_empty() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.delete(2, 0).delete(2, 1);
        let rep = d.apply(&mut m);
        assert_eq!(rep.deleted, 2);
        assert_eq!(m.row_nnz(2), 0);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.indptr, vec![0, 2, 2, 2]);
    }

    #[test]
    fn explicit_zero_insert_is_a_stored_nonzero() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(1, 2, 0.0);
        let rep = d.apply(&mut m);
        assert_eq!(rep.inserted, 1);
        assert_eq!(m.row_nnz(1), 1);
        assert_eq!(m.row(1).1, &[0.0]);
    }

    #[test]
    fn epoch_bumps_once_per_effective_batch() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(0, 0, 2.0).insert(1, 1, 1.0).delete(2, 0);
        d.apply(&mut m);
        assert_eq!(m.epoch, 1, "one batch, one bump");
        let fp = m.fingerprint();
        d.apply(&mut m);
        assert_eq!(m.epoch, 2);
        assert_ne!(fp, m.fingerprint());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_insert_panics() {
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.insert(0, 3, 1.0);
        d.apply(&mut m);
    }

    #[test]
    fn matches_coo_rebuild_on_a_mixed_batch() {
        // the oracle the robustness property suite replays at scale:
        // apply the batch to a coordinate map, rebuild via COO, compare
        let mut m = small();
        let mut d = EdgeDelta::new();
        d.delete(0, 0) // net delete
            .insert(0, 1, 8.0) // net insert
            .insert(2, 1, -1.0) // update
            .delete(1, 0); // absent: no-op
        d.apply(&mut m);
        let mut model = std::collections::BTreeMap::new();
        model.insert((0u32, 2u32), 2.0f32);
        model.insert((0, 1), 8.0);
        model.insert((2, 0), 3.0);
        model.insert((2, 1), -1.0);
        let mut coo = CooMatrix::new(3, 3);
        for (&(r, c), &v) in &model {
            coo.push(r as usize, c as usize, v);
        }
        let oracle = CsrMatrix::from_coo(&coo);
        assert_eq!(m.indptr, oracle.indptr);
        assert_eq!(m.indices, oracle.indices);
        assert_eq!(m.values, oracle.values);
    }
}
