//! COO (triplet) sparse format — generation and interchange.

use crate::util::prng::Xoshiro256;

/// Coordinate-format sparse matrix. Entries may be unsorted and contain
/// duplicates until [`CooMatrix::canonicalize`] is called (duplicates sum,
/// as is conventional for assembly).
#[derive(Clone, Debug, PartialEq)]
pub struct CooMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CooMatrix {
    /// Empty matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            row_idx: Vec::new(),
            col_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Push one entry (no dedup).
    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols, "entry ({r},{c}) out of bounds");
        self.row_idx.push(r as u32);
        self.col_idx.push(c as u32);
        self.values.push(v);
    }

    /// Number of stored entries (before canonicalization may include dups).
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Sort entries by (row, col) and sum duplicates. Zero-valued entries
    /// are retained (they still occupy a slot in CSR, matching how graph
    /// adjacency matrices keep explicit edges).
    pub fn canonicalize(&mut self) {
        let n = self.nnz();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&i| (self.row_idx[i], self.col_idx[i]));
        let mut row2 = Vec::with_capacity(n);
        let mut col2 = Vec::with_capacity(n);
        let mut val2: Vec<f32> = Vec::with_capacity(n);
        for &i in &order {
            let (r, c, v) = (self.row_idx[i], self.col_idx[i], self.values[i]);
            if let (Some(&lr), Some(&lc)) = (row2.last(), col2.last()) {
                if lr == r && lc == c {
                    *val2.last_mut().unwrap() += v;
                    continue;
                }
            }
            row2.push(r);
            col2.push(c);
            val2.push(v);
        }
        self.row_idx = row2;
        self.col_idx = col2;
        self.values = val2;
    }

    /// Uniform random matrix with an expected `density` in (0, 1]: each
    /// entry is present independently — Erdős–Rényi in matrix form.
    pub fn random_uniform(rows: usize, cols: usize, density: f64, rng: &mut Xoshiro256) -> Self {
        let mut m = Self::new(rows, cols);
        // Sample per-row counts binomially via thinning to avoid O(rows*cols)
        // for large sparse shapes: geometric skipping over the flat index
        // space.
        let total = rows as f64 * cols as f64;
        let expected = (total * density).round() as usize;
        if expected == 0 {
            return m;
        }
        if density > 0.1 || total < 65_536.0 {
            // dense-ish: direct Bernoulli sweep
            for r in 0..rows {
                for c in 0..cols {
                    if rng.chance(density) {
                        m.push(r, c, rng.next_f32() * 2.0 - 1.0);
                    }
                }
            }
        } else {
            // geometric skipping: P(gap = k) = (1-p)^k p
            let p = density;
            let mut pos: f64 = 0.0;
            let lim = total;
            loop {
                // draw gap ~ Geometric(p)
                let u = rng.next_f64().max(1e-300);
                let gap = (u.ln() / (1.0 - p).ln()).floor();
                pos += gap + 1.0;
                if pos > lim {
                    break;
                }
                let flat = (pos - 1.0) as u64;
                let r = (flat / cols as u64) as usize;
                let c = (flat % cols as u64) as usize;
                m.push(r, c, rng.next_f32() * 2.0 - 1.0);
            }
        }
        m
    }

    /// Dense representation (for tests on small matrices).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.nnz() {
            out[self.row_idx[i] as usize * self.cols + self.col_idx[i] as usize] += self.values[i];
        }
        out
    }

    /// Transposed copy (entries swapped; not canonicalized).
    pub fn transposed(&self) -> CooMatrix {
        CooMatrix {
            rows: self.cols,
            cols: self.rows,
            row_idx: self.col_idx.clone(),
            col_idx: self.row_idx.clone(),
            values: self.values.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalize_sorts_and_sums() {
        let mut m = CooMatrix::new(3, 3);
        m.push(2, 1, 1.0);
        m.push(0, 0, 2.0);
        m.push(2, 1, 3.0);
        m.push(0, 2, 4.0);
        m.canonicalize();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_idx, vec![0, 0, 2]);
        assert_eq!(m.col_idx, vec![0, 2, 1]);
        assert_eq!(m.values, vec![2.0, 4.0, 4.0]);
    }

    #[test]
    fn random_uniform_density_is_close() {
        let mut rng = Xoshiro256::seeded(11);
        let m = CooMatrix::random_uniform(200, 200, 0.05, &mut rng);
        let got = m.nnz() as f64 / (200.0 * 200.0);
        assert!((got - 0.05).abs() < 0.01, "density {got}");
    }

    #[test]
    fn geometric_skipping_matches_density_for_sparse() {
        let mut rng = Xoshiro256::seeded(12);
        let m = CooMatrix::random_uniform(2000, 2000, 0.001, &mut rng);
        let got = m.nnz() as f64 / (2000.0 * 2000.0);
        assert!((got - 0.001).abs() < 2e-4, "density {got}");
        // all in bounds, sorted order not required
        assert!(m.row_idx.iter().all(|&r| (r as usize) < 2000));
        assert!(m.col_idx.iter().all(|&c| (c as usize) < 2000));
    }

    #[test]
    fn transpose_roundtrip_dense() {
        let mut rng = Xoshiro256::seeded(13);
        let m = CooMatrix::random_uniform(17, 9, 0.2, &mut rng);
        let d = m.to_dense();
        let t = m.transposed().to_dense();
        for r in 0..17 {
            for c in 0..9 {
                assert_eq!(d[r * 9 + c], t[c * 17 + r]);
            }
        }
    }
}
