//! Fixed-nnz segment format — input to the **workload-balanced** kernels.
//!
//! The paper's workload-balancing principle assigns *a fixed number of
//! non-zeros per warp* instead of whole rows (Fig. 2(b)/(e)). This module
//! materializes that assignment: the CSR stream of non-zeros is cut into
//! `seg_len`-sized segments, and every element carries its row index so the
//! kernel can perform segment reduction across row boundaries (VSR) or
//! carry-out accumulation (SR-WB).

use super::csr::CsrMatrix;

/// Segmented (nnz-split) layout.
///
/// `values/col_idx/row_idx` are the CSR non-zero stream padded to
/// `num_segments * seg_len`; padded slots have value 0 and row/col indices
/// equal to the *last real row/col* (so they merge into an existing segment
/// without affecting sums).
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub seg_len: usize,
    pub num_segments: usize,
    pub values: Vec<f32>,
    pub col_idx: Vec<u32>,
    pub row_idx: Vec<u32>,
    /// true nnz before padding
    pub nnz: usize,
}

impl SegmentedMatrix {
    /// Cut the CSR non-zero stream into segments of `seg_len` elements.
    ///
    /// An empty stream (`nnz == 0`) yields zero segments: fabricating an
    /// all-padding segment would point its row indices at row 0, and the
    /// workload-balanced kernels would then carry a (zero) partial into
    /// `y[0]` — out of bounds when the matrix also has zero rows.
    pub fn from_csr(csr: &CsrMatrix, seg_len: usize) -> Self {
        assert!(seg_len > 0, "segment length must be positive");
        let nnz = csr.nnz();
        let num_segments = nnz.div_ceil(seg_len);
        let padded = num_segments * seg_len;
        let mut values = Vec::with_capacity(padded);
        let mut col_idx = Vec::with_capacity(padded);
        let mut row_idx = Vec::with_capacity(padded);
        for r in 0..csr.rows {
            let (cols, vals) = csr.row(r);
            for k in 0..cols.len() {
                values.push(vals[k]);
                col_idx.push(cols[k]);
                row_idx.push(r as u32);
            }
        }
        // `padded == 0` when the stream is empty, so the fallback pad
        // indices are never materialized.
        let (pad_row, pad_col) = if nnz > 0 {
            (row_idx[nnz - 1], col_idx[nnz - 1])
        } else {
            (0, 0)
        };
        values.resize(padded, 0.0);
        col_idx.resize(padded, pad_col);
        row_idx.resize(padded, pad_row);
        Self {
            rows: csr.rows,
            cols: csr.cols,
            seg_len,
            num_segments,
            values,
            col_idx,
            row_idx,
            nnz,
        }
    }

    /// `(values, cols, rows)` slices of segment `s`.
    #[inline]
    pub fn segment(&self, s: usize) -> (&[f32], &[u32], &[u32]) {
        let lo = s * self.seg_len;
        let hi = lo + self.seg_len;
        (
            &self.values[lo..hi],
            &self.col_idx[lo..hi],
            &self.row_idx[lo..hi],
        )
    }

    /// Number of distinct rows touched by segment `s` — a workload metric
    /// used by the simulator (each distinct row implies one output
    /// update/atomic in the CUDA design).
    pub fn segment_row_span(&self, s: usize) -> usize {
        let (_, _, rows) = self.segment(s);
        if rows.is_empty() {
            return 0;
        }
        let mut distinct = 1;
        for k in 1..rows.len() {
            if rows[k] != rows[k - 1] {
                distinct += 1;
            }
        }
        distinct
    }

    /// Overwrite the segment values from a new CSR value stream with the
    /// same sparsity pattern (`values.len()` must equal the true `nnz`).
    /// The CSR non-zero stream maps 1:1 onto the first `nnz` segment
    /// slots, so a value-only [`crate::sparse::delta::EdgeDelta`] batch
    /// patches this layout without re-cutting segments; the padding tail
    /// keeps its benign zeros.
    pub fn patch_values(&mut self, values: &[f32]) {
        assert_eq!(
            values.len(),
            self.nnz,
            "patched value stream must match nnz"
        );
        self.values[..self.nnz].copy_from_slice(values);
    }

    /// Dense reconstruction (tests only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for i in 0..self.nnz {
            out[self.row_idx[i] as usize * self.cols + self.col_idx[i] as usize] +=
                self.values[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::CooMatrix;
    use crate::util::proptest::run_prop;

    fn skewed() -> CsrMatrix {
        // row 0: 5 nnz, row 1: 1 nnz, row 2: 0, row 3: 2 nnz
        let mut coo = CooMatrix::new(4, 8);
        for c in 0..5 {
            coo.push(0, c, (c + 1) as f32);
        }
        coo.push(1, 7, 6.0);
        coo.push(3, 0, 7.0);
        coo.push(3, 4, 8.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn segments_cover_stream_in_order() {
        let m = SegmentedMatrix::from_csr(&skewed(), 4);
        assert_eq!(m.nnz, 8);
        assert_eq!(m.num_segments, 2);
        let (v0, _, r0) = m.segment(0);
        assert_eq!(v0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r0, &[0, 0, 0, 0]);
        let (v1, _, r1) = m.segment(1);
        assert_eq!(v1, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(r1, &[0, 1, 3, 3]);
    }

    #[test]
    fn padding_is_benign() {
        let m = SegmentedMatrix::from_csr(&skewed(), 5);
        assert_eq!(m.num_segments, 2);
        let (v1, _, r1) = m.segment(1);
        // 3 real + 2 pad entries with value 0 merged into last row
        assert_eq!(v1[3], 0.0);
        assert_eq!(v1[4], 0.0);
        assert_eq!(r1[3], 3);
        assert_eq!(r1[4], 3);
        assert_eq!(m.to_dense(), skewed().to_dense());
    }

    #[test]
    fn row_span_counts_boundaries() {
        let m = SegmentedMatrix::from_csr(&skewed(), 4);
        assert_eq!(m.segment_row_span(0), 1); // all row 0
        assert_eq!(m.segment_row_span(1), 3); // rows 0, 1, 3
    }

    #[test]
    fn dense_roundtrip_property() {
        run_prop("segments dense roundtrip", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            let seg_len = *g.choose(&[1usize, 3, 8, 32]);
            let seg = SegmentedMatrix::from_csr(&csr, seg_len);
            if seg.to_dense() == csr.to_dense() {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} seg_len {seg_len}"))
            }
        });
    }

    #[test]
    fn patch_values_equals_recut_for_value_only_mutation() {
        let csr = skewed();
        let mut seg = SegmentedMatrix::from_csr(&csr, 5);
        // mutate values only (same pattern), as a value-only delta does
        let new_values: Vec<f32> = csr.values.iter().map(|v| v * -2.0).collect();
        let mutated = csr.with_values(new_values.clone());
        seg.patch_values(&new_values);
        assert_eq!(seg, SegmentedMatrix::from_csr(&mutated, 5));
        // padding tail stayed zero
        assert_eq!(seg.values[seg.nnz..], vec![0.0; seg.values.len() - seg.nnz]);
    }

    #[test]
    #[should_panic(expected = "must match nnz")]
    fn patch_values_checks_length() {
        let mut seg = SegmentedMatrix::from_csr(&skewed(), 4);
        seg.patch_values(&[1.0]);
    }

    #[test]
    fn empty_matrix_has_no_segments() {
        // Regression: a fabricated all-padding segment used to point at
        // row 0, making the WB kernels carry a partial into y[0].
        for (rows, cols) in [(3usize, 3usize), (0, 7), (0, 0)] {
            let csr = CsrMatrix::from_coo(&CooMatrix::new(rows, cols));
            let m = SegmentedMatrix::from_csr(&csr, 8);
            assert_eq!(m.num_segments, 0, "{rows}x{cols}");
            assert_eq!(m.nnz, 0);
            assert!(m.values.is_empty() && m.row_idx.is_empty() && m.col_idx.is_empty());
            assert_eq!(m.to_dense(), vec![0.0; rows * cols]);
        }
    }

    #[test]
    fn every_segment_contains_a_real_element() {
        // num_segments = ceil(nnz / seg_len) means s * seg_len < nnz for
        // every segment s — the invariant the WB kernels' first-row carry
        // logic relies on (a worker's first row index is always real).
        run_prop("segments all real", 30, |g| {
            let rows = g.dim();
            let coo = CooMatrix::random_uniform(rows, 16, 0.15, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            let seg_len = *g.choose(&[1usize, 4, 32]);
            let seg = SegmentedMatrix::from_csr(&csr, seg_len);
            for s in 0..seg.num_segments {
                if s * seg_len >= seg.nnz {
                    return Err(format!("segment {s} is all padding"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn workload_balance_invariant() {
        // Every segment except possibly the last handles exactly seg_len
        // real non-zeros — the paper's balancing guarantee.
        run_prop("segment balance", 30, |g| {
            let rows = g.dim() * 2;
            let coo = CooMatrix::random_uniform(rows, 32, 0.2, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            let seg = SegmentedMatrix::from_csr(&csr, 16);
            for s in 0..seg.num_segments.saturating_sub(1) {
                let (v, _, _) = seg.segment(s);
                if v.len() != 16 {
                    return Err(format!("segment {s} has {} slots", v.len()));
                }
            }
            Ok(())
        });
    }
}
