//! ELL (ELLPACK) padded format — input to the **row-split** kernels.
//!
//! Every row is padded to a common width; padded slots carry value 0 and a
//! sentinel column (we reuse column 0 with value 0, which is harmless for
//! SpMM). This gives the static shapes the Pallas kernels require: a
//! `(rows_padded, width)` pair of value/index planes.

use super::csr::CsrMatrix;
use super::DenseMatrix;

/// Padded ELLPACK layout.
///
/// `values[r * width + k]` / `col_idx[r * width + k]` hold the `k`-th
/// non-zero of row `r` (zero-filled past `row_nnz[r]`). `rows_padded` is
/// `rows` rounded up to `row_block`, so kernels can tile rows uniformly.
#[derive(Clone, Debug, PartialEq)]
pub struct EllMatrix {
    pub rows: usize,
    pub cols: usize,
    /// rows rounded up to the row-block granularity
    pub rows_padded: usize,
    /// padded row width (max row nnz rounded up to `width_align`)
    pub width: usize,
    pub values: Vec<f32>,
    pub col_idx: Vec<u32>,
    /// true (unpadded) nnz per row
    pub row_nnz: Vec<u32>,
}

impl EllMatrix {
    /// Convert from CSR, padding rows to `width_align` columns and the row
    /// count to `row_block` rows. `width_align`/`row_block` of 1 mean "no
    /// alignment".
    pub fn from_csr(csr: &CsrMatrix, width_align: usize, row_block: usize) -> Self {
        let width_align = width_align.max(1);
        let row_block = row_block.max(1);
        let max_nnz = (0..csr.rows).map(|r| csr.row_nnz(r)).max().unwrap_or(0);
        let width = max_nnz.div_ceil(width_align).max(1) * width_align;
        let rows_padded = csr.rows.div_ceil(row_block) * row_block;
        let mut values = vec![0f32; rows_padded * width];
        let mut col_idx = vec![0u32; rows_padded * width];
        let mut row_nnz = vec![0u32; rows_padded];
        for r in 0..csr.rows {
            let (cols, vals) = csr.row(r);
            row_nnz[r] = cols.len() as u32;
            let base = r * width;
            values[base..base + vals.len()].copy_from_slice(vals);
            col_idx[base..base + cols.len()].copy_from_slice(cols);
        }
        Self {
            rows: csr.rows,
            cols: csr.cols,
            rows_padded,
            width,
            values,
            col_idx,
            row_nnz,
        }
    }

    /// Stored (padded) element count.
    pub fn padded_len(&self) -> usize {
        self.rows_padded * self.width
    }

    /// True nnz (sum of row_nnz).
    pub fn nnz(&self) -> usize {
        self.row_nnz.iter().map(|&n| n as usize).sum()
    }

    /// Padding overhead ratio `padded/nnz` (∞-safe: returns padded_len when
    /// nnz is zero). The paper's motivation for not always using ELL.
    pub fn padding_ratio(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            self.padded_len() as f64
        } else {
            self.padded_len() as f64 / nnz as f64
        }
    }

    /// Row-split SpMM over the padded planes, gathering only the
    /// `row_nnz[r]` real slots of each row.
    ///
    /// This is the bounding convention every ELL consumer must follow: a
    /// full-width multiply relies on padded slots (value 0, sentinel
    /// column 0) being harmless, but `0.0 * NaN = NaN`, so one non-finite
    /// entry in dense row 0 would corrupt every output row with padding.
    pub fn spmm_bounded(&self, x: &DenseMatrix, y: &mut DenseMatrix) {
        assert_eq!(self.cols, x.rows, "inner dimension mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols), "output shape mismatch");
        let n = x.cols;
        y.data.fill(0.0);
        for r in 0..self.rows {
            let base = r * self.width;
            let out = &mut y.data[r * n..(r + 1) * n];
            for k in 0..self.row_nnz[r] as usize {
                let v = self.values[base + k];
                let xrow = x.row(self.col_idx[base + k] as usize);
                for j in 0..n {
                    out[j] += v * xrow[j];
                }
            }
        }
    }

    /// Dense reconstruction (tests only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for k in 0..self.row_nnz[r] as usize {
                let c = self.col_idx[r * self.width + k] as usize;
                out[r * self.cols + c] += self.values[r * self.width + k];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::CooMatrix;
    use crate::util::proptest::run_prop;

    fn csr_3x4() -> CsrMatrix {
        let mut coo = CooMatrix::new(3, 4);
        coo.push(0, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(0, 0, 0.5);
        coo.push(2, 2, 3.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn from_csr_pads_width_and_rows() {
        let e = EllMatrix::from_csr(&csr_3x4(), 4, 8);
        assert_eq!(e.width, 4); // max nnz 3 -> aligned to 4
        assert_eq!(e.rows_padded, 8);
        assert_eq!(e.nnz(), 4);
        assert_eq!(e.row_nnz[0], 3);
        assert_eq!(e.row_nnz[1], 0);
        assert_eq!(e.row_nnz[2], 1);
        // padded slots are explicit zeros
        assert_eq!(e.values[3], 0.0);
        assert_eq!(e.col_idx[3], 0);
    }

    #[test]
    fn dense_roundtrip() {
        let c = csr_3x4();
        let e = EllMatrix::from_csr(&c, 2, 4);
        assert_eq!(e.to_dense(), c.to_dense());
    }

    #[test]
    fn dense_roundtrip_property() {
        run_prop("ell<->csr dense agree", 40, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            let align = *g.choose(&[1usize, 2, 4, 8]);
            let rb = *g.choose(&[1usize, 4, 16]);
            let ell = EllMatrix::from_csr(&csr, align, rb);
            if ell.to_dense() == csr.to_dense() {
                Ok(())
            } else {
                Err(format!("{rows}x{cols} align {align} rb {rb}"))
            }
        });
    }

    #[test]
    fn padding_ratio_reflects_skew() {
        // one long row + many empty rows => high padding ratio
        let mut coo = CooMatrix::new(32, 64);
        for c in 0..64 {
            coo.push(0, c, 1.0);
        }
        coo.push(1, 0, 1.0);
        let e = EllMatrix::from_csr(&CsrMatrix::from_coo(&coo), 1, 1);
        assert!(e.padding_ratio() > 10.0, "ratio {}", e.padding_ratio());
    }

    #[test]
    fn spmm_bounded_matches_dense_reference() {
        run_prop("ell spmm_bounded vs reference", 30, |g| {
            let rows = g.dim();
            let cols = g.dim();
            let n = *g.choose(&[1usize, 3, 8]);
            let coo = CooMatrix::random_uniform(rows, cols, 0.3, g.rng());
            let csr = CsrMatrix::from_coo(&coo);
            let ell = EllMatrix::from_csr(&csr, 4, 8);
            let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
            let mut want = DenseMatrix::zeros(rows, n);
            crate::kernels::dense::spmm_reference(&csr, &x, &mut want);
            let mut got = DenseMatrix::zeros(rows, n);
            ell.spmm_bounded(&x, &mut got);
            crate::util::proptest::assert_close(&got.data, &want.data, 1e-5, 1e-5)
        });
    }

    #[test]
    fn padding_never_gathers_nan() {
        // Row 1 is empty and row 2 is shorter than the padded width, so
        // both have padded slots pointing at sentinel column 0. A NaN in
        // dense row 0 must only reach output rows that really reference
        // column 0 (here: none).
        let mut coo = CooMatrix::new(3, 4);
        coo.push(0, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(2, 2, 3.0);
        let ell = EllMatrix::from_csr(&CsrMatrix::from_coo(&coo), 4, 1);
        assert!(ell.width > 1, "fixture needs padded slots");
        let mut x = DenseMatrix::from_vec(
            4,
            2,
            vec![f32::NAN, f32::INFINITY, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        );
        let mut y = DenseMatrix::zeros(3, 2);
        ell.spmm_bounded(&x, &mut y);
        assert!(y.data.iter().all(|v| v.is_finite()), "{:?}", y.data);
        assert_eq!(y.row(1), &[0.0, 0.0], "empty row stays zero");
        // ... and a row that does reference column 0 still propagates it
        x.data[0] = f32::NAN;
        let mut coo2 = CooMatrix::new(1, 4);
        coo2.push(0, 0, 1.0);
        let ell2 = EllMatrix::from_csr(&CsrMatrix::from_coo(&coo2), 4, 1);
        let mut y2 = DenseMatrix::zeros(1, 2);
        ell2.spmm_bounded(&x, &mut y2);
        assert!(y2.at(0, 0).is_nan());
    }

    #[test]
    fn empty_matrix_is_safe() {
        let csr = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        let e = EllMatrix::from_csr(&csr, 4, 4);
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.width, 4); // min width respected
        assert_eq!(e.to_dense(), vec![0.0; 16]);
    }
}
