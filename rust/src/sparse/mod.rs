//! Sparse matrix formats and conversions.
//!
//! The kernel designs in the paper consume three layouts:
//!
//! - [`CooMatrix`] — triplet form, the interchange/generation format;
//! - [`CsrMatrix`] — compressed sparse row, the canonical input format
//!   (what cuSPARSE and the paper's kernels take);
//! - [`EllMatrix`] — padded row-major layout used by the **row-split**
//!   Pallas kernels (static shapes);
//! - [`SegmentedMatrix`] — fixed-nnz-per-segment layout used by the
//!   **workload-balanced** kernels (the paper's "assign each warp a fixed
//!   number of non-zeros"), with per-element row indices.
//!
//! [`mmio`] reads/writes MatrixMarket files so external matrices (e.g.
//! downloaded SuiteSparse entries) can be used when available.

pub mod coo;
pub mod csr;
pub mod ell;
pub mod mmio;
pub mod segments;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use ell::EllMatrix;
pub use segments::SegmentedMatrix;

/// Dense row-major matrix with explicit shape — the `X`/`Y` operands.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    /// Zero-filled dense matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// From existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Random dense matrix in `[-scale, scale)`.
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::prng::Xoshiro256) -> Self {
        let mut data = vec![0.0; rows * cols];
        rng.fill_uniform_f32(&mut data, scale);
        Self { rows, cols, data }
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Dense product `self · rhs` (`r×c · c×k → r×k`). Plain triple loop —
    /// the projection matmuls in the GNN layers are tiny next to the
    /// sparse kernels they feed; this is not a BLAS.
    pub fn matmul(&self, rhs: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let mut out = DenseMatrix::zeros(self.rows, rhs.cols);
        for r in 0..self.rows {
            let lhs_row = self.row(r);
            let out_row = &mut out.data[r * rhs.cols..(r + 1) * rhs.cols];
            for (k, &a) in lhs_row.iter().enumerate() {
                let rhs_row = rhs.row(k);
                for j in 0..rhs.cols {
                    out_row[j] += a * rhs_row[j];
                }
            }
        }
        out
    }

    /// Transposed copy (`r×c → c×r`). Used for the `Xᵀ·G` weight-gradient
    /// products in the native GNN trainer.
    pub fn transposed(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_accessors() {
        let mut d = DenseMatrix::zeros(2, 3);
        *d.at_mut(1, 2) = 5.0;
        assert_eq!(d.at(1, 2), 5.0);
        assert_eq!(d.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn from_vec_checks_shape() {
        DenseMatrix::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn matmul_known_product() {
        // [[1, 2], [3, 4]] · [[5, 6], [7, 8]] = [[19, 22], [43, 50]]
        let a = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = DenseMatrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
        // rectangular: (1×2) · (2×3)
        let c = DenseMatrix::from_vec(1, 2, vec![1.0, 2.0]);
        let d = DenseMatrix::from_vec(2, 3, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
        assert_eq!(c.matmul(&d).data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn transposed_round_trips() {
        let a = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = a.transposed();
        assert_eq!((t.rows, t.cols), (3, 2));
        assert_eq!(t.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(t.transposed(), a);
    }
}
