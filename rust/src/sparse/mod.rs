//! Sparse matrix formats and conversions.
//!
//! The kernel designs in the paper consume three layouts:
//!
//! - [`CooMatrix`] — triplet form, the interchange/generation format;
//! - [`CsrMatrix`] — compressed sparse row, the canonical input format
//!   (what cuSPARSE and the paper's kernels take);
//! - [`EllMatrix`] — padded row-major layout used by the **row-split**
//!   Pallas kernels (static shapes);
//! - [`SegmentedMatrix`] — fixed-nnz-per-segment layout used by the
//!   **workload-balanced** kernels (the paper's "assign each warp a fixed
//!   number of non-zeros"), with per-element row indices.
//!
//! [`mmio`] reads/writes MatrixMarket files so external matrices (e.g.
//! downloaded SuiteSparse entries) can be used when available.
//!
//! [`delta`] mutates the formats in place: [`EdgeDelta`] batches of
//! edge insertions/deletions applied to a [`CsrMatrix`] (value-only
//! patch or structural merge-rebuild), with the mutation epoch folded
//! into the content fingerprint so the serving cache invalidates stale
//! prepared state.
//!
//! Dense operands are [`DenseMatrix`] (packed row-major) or
//! [`AlignedDense`] (64-byte aligned allocation, row stride padded to the
//! SIMD lane width); the [`DenseX`] trait lets the kernels gather from
//! either without caring which.

pub mod coo;
pub mod csr;
pub mod delta;
pub mod ell;
pub mod mmio;
pub mod segments;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use delta::{DeltaOutcome, DeltaReport, EdgeDelta};
pub use ell::EllMatrix;
pub use segments::SegmentedMatrix;

/// Dense row-major matrix with explicit shape — the `X`/`Y` operands.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    /// Zero-filled dense matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// From existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Random dense matrix in `[-scale, scale)`.
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::prng::Xoshiro256) -> Self {
        let mut data = vec![0.0; rows * cols];
        rng.fill_uniform_f32(&mut data, scale);
        Self { rows, cols, data }
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Dense product `self · rhs` (`r×c · c×k → r×k`). Plain triple loop —
    /// the projection matmuls in the GNN layers are tiny next to the
    /// sparse kernels they feed; this is not a BLAS.
    pub fn matmul(&self, rhs: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let mut out = DenseMatrix::zeros(self.rows, rhs.cols);
        for r in 0..self.rows {
            let lhs_row = self.row(r);
            let out_row = &mut out.data[r * rhs.cols..(r + 1) * rhs.cols];
            for (k, &a) in lhs_row.iter().enumerate() {
                let rhs_row = rhs.row(k);
                for j in 0..rhs.cols {
                    out_row[j] += a * rhs_row[j];
                }
            }
        }
        out
    }

    /// Transposed copy (`r×c → c×r`). Used for the `Xᵀ·G` weight-gradient
    /// products in the native GNN trainer.
    pub fn transposed(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Copy into the vector-aligned, padded-stride layout
    /// ([`AlignedDense`]) consumed by the SIMD kernel entry points.
    pub fn to_aligned(&self) -> AlignedDense {
        AlignedDense::from_dense(self)
    }
}

/// Read-only dense operand abstraction: what the kernels' gather loops
/// need from an `X`. Implemented by [`DenseMatrix`] (packed rows) and
/// [`AlignedDense`] (aligned, padded rows); the kernels' private generic
/// implementations are instantiated for both, so `row()` semantics are
/// identical for callers regardless of layout.
pub trait DenseX: Sync {
    /// Number of rows.
    fn xrows(&self) -> usize;
    /// Logical row width (excluding any padding).
    fn xcols(&self) -> usize;
    /// Row `r` as a `xcols()`-length slice.
    fn xrow(&self, r: usize) -> &[f32];
}

impl DenseX for DenseMatrix {
    #[inline]
    fn xrows(&self) -> usize {
        self.rows
    }
    #[inline]
    fn xcols(&self) -> usize {
        self.cols
    }
    #[inline]
    fn xrow(&self, r: usize) -> &[f32] {
        self.row(r)
    }
}

/// Dense row-major matrix over a 64-byte aligned allocation with the row
/// stride rounded up to the SIMD lane width
/// ([`crate::kernels::vec8::LANES`]), so an 8-lane vector load issued at
/// any in-row tile offset never straddles a row boundary and row starts
/// never straddle a cache line. The padding tail of each row is
/// zero-filled and excluded from [`AlignedDense::row`] — callers see
/// exactly [`DenseMatrix::row`] semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct AlignedDense {
    /// Number of rows.
    pub rows: usize,
    /// Logical row width.
    pub cols: usize,
    /// Physical row stride in floats (`cols` rounded up to the lane
    /// width; 0 when `cols == 0`).
    pub stride: usize,
    buf: crate::util::aligned::AlignedBuf,
}

impl AlignedDense {
    /// Zero-filled aligned matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let lanes = crate::kernels::vec8::LANES;
        let stride = if cols == 0 { 0 } else { cols.div_ceil(lanes) * lanes };
        Self {
            rows,
            cols,
            stride,
            buf: crate::util::aligned::AlignedBuf::zeros(rows * stride),
        }
    }

    /// Copy a packed [`DenseMatrix`] into the aligned layout.
    pub fn from_dense(src: &DenseMatrix) -> Self {
        let mut out = Self::zeros(src.rows, src.cols);
        for r in 0..src.rows {
            let dst = &mut out.buf[r * out.stride..r * out.stride + out.cols];
            dst.copy_from_slice(src.row(r));
        }
        out
    }

    /// Row slice — same semantics as [`DenseMatrix::row`] (padding
    /// excluded).
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.buf[r * self.stride..r * self.stride + self.cols]
    }

    /// Copy back to the packed layout.
    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            out.data[r * self.cols..(r + 1) * self.cols].copy_from_slice(self.row(r));
        }
        out
    }
}

impl DenseX for AlignedDense {
    #[inline]
    fn xrows(&self) -> usize {
        self.rows
    }
    #[inline]
    fn xcols(&self) -> usize {
        self.cols
    }
    #[inline]
    fn xrow(&self, r: usize) -> &[f32] {
        self.row(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_round_trip_preserves_rows() {
        let mut rng = crate::util::prng::Xoshiro256::seeded(88);
        for (rows, cols) in [(3usize, 5usize), (4, 8), (2, 9), (1, 1), (6, 0), (0, 4)] {
            let d = DenseMatrix::random(rows, cols, 1.0, &mut rng);
            let a = d.to_aligned();
            assert_eq!((a.rows, a.cols), (rows, cols));
            assert_eq!(a.stride % crate::kernels::vec8::LANES.max(1), 0);
            assert!(a.stride >= cols);
            for r in 0..rows {
                assert_eq!(a.row(r), d.row(r), "row {r} ({rows}x{cols})");
            }
            assert_eq!(a.to_dense(), d);
        }
    }

    #[test]
    fn aligned_rows_start_on_lane_boundaries() {
        let d = DenseMatrix::zeros(4, 5);
        let a = d.to_aligned();
        assert_eq!(a.stride, 8);
        // every physical row start is stride-aligned within the buffer,
        // and the buffer base itself is 64-byte aligned
        assert_eq!(a.row(0).as_ptr() as usize % crate::util::aligned::ALIGN, 0);
    }

    #[test]
    fn dense_accessors() {
        let mut d = DenseMatrix::zeros(2, 3);
        *d.at_mut(1, 2) = 5.0;
        assert_eq!(d.at(1, 2), 5.0);
        assert_eq!(d.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn from_vec_checks_shape() {
        DenseMatrix::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn matmul_known_product() {
        // [[1, 2], [3, 4]] · [[5, 6], [7, 8]] = [[19, 22], [43, 50]]
        let a = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = DenseMatrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
        // rectangular: (1×2) · (2×3)
        let c = DenseMatrix::from_vec(1, 2, vec![1.0, 2.0]);
        let d = DenseMatrix::from_vec(2, 3, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
        assert_eq!(c.matmul(&d).data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn transposed_round_trips() {
        let a = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = a.transposed();
        assert_eq!((t.rows, t.cols), (3, 2));
        assert_eq!(t.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(t.transposed(), a);
    }
}
