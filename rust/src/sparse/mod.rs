//! Sparse matrix formats and conversions.
//!
//! The kernel designs in the paper consume three layouts:
//!
//! - [`CooMatrix`] — triplet form, the interchange/generation format;
//! - [`CsrMatrix`] — compressed sparse row, the canonical input format
//!   (what cuSPARSE and the paper's kernels take);
//! - [`EllMatrix`] — padded row-major layout used by the **row-split**
//!   Pallas kernels (static shapes);
//! - [`SegmentedMatrix`] — fixed-nnz-per-segment layout used by the
//!   **workload-balanced** kernels (the paper's "assign each warp a fixed
//!   number of non-zeros"), with per-element row indices.
//!
//! [`mmio`] reads/writes MatrixMarket files so external matrices (e.g.
//! downloaded SuiteSparse entries) can be used when available.

pub mod coo;
pub mod csr;
pub mod ell;
pub mod mmio;
pub mod segments;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use ell::EllMatrix;
pub use segments::SegmentedMatrix;

/// Dense row-major matrix with explicit shape — the `X`/`Y` operands.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    /// Zero-filled dense matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// From existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "dense shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Random dense matrix in `[-scale, scale)`.
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::prng::Xoshiro256) -> Self {
        let mut data = vec![0.0; rows * cols];
        rng.fill_uniform_f32(&mut data, scale);
        Self { rows, cols, data }
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_accessors() {
        let mut d = DenseMatrix::zeros(2, 3);
        *d.at_mut(1, 2) = 5.0;
        assert_eq!(d.at(1, 2), 5.0);
        assert_eq!(d.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn from_vec_checks_shape() {
        DenseMatrix::from_vec(2, 2, vec![0.0; 3]);
    }
}
