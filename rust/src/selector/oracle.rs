//! Oracle selection: profile all four kernels, keep the best.
//!
//! This is the paper's "profile and select the best implementation
//! off-line" mode (§3.1) — the upper bound the rule-based selector is
//! measured against (§3.2 reports the rules lose only 5–12% to it).

use crate::features::MatrixFeatures;
use crate::kernels::KernelKind;
use crate::sim::{simulate, GpuConfig, SimKernel, SimMatrix};

/// Result of an oracle profile: the winner and every candidate's time.
#[derive(Clone, Debug)]
pub struct OracleProfile {
    pub best: KernelKind,
    pub seconds: [(KernelKind, f64); 4],
}

/// Profile the four designs on the simulator; return the winner.
pub fn profile(a: &SimMatrix, n: usize, gpu: &GpuConfig) -> OracleProfile {
    let mut seconds = [(KernelKind::SrRs, 0.0); 4];
    for (i, k) in KernelKind::ALL.iter().enumerate() {
        let r = simulate(SimKernel::from_kind(*k), a, n, gpu);
        seconds[i] = (*k, r.seconds);
    }
    let best = seconds
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
    OracleProfile { best, seconds }
}

impl OracleProfile {
    /// Time of a specific kernel.
    pub fn time_of(&self, k: KernelKind) -> f64 {
        self.seconds.iter().find(|(kk, _)| *kk == k).unwrap().1
    }

    /// Best (oracle) time.
    pub fn best_time(&self) -> f64 {
        self.time_of(self.best)
    }

    /// Relative loss of choosing `k` instead of the oracle (≥ 0).
    pub fn loss_of(&self, k: KernelKind) -> f64 {
        self.time_of(k) / self.best_time() - 1.0
    }
}

/// Convenience: oracle winner for a CSR matrix (builds the SimMatrix).
pub fn best_kernel(
    a: &crate::sparse::CsrMatrix,
    n: usize,
    gpu: &GpuConfig,
) -> (KernelKind, MatrixFeatures) {
    let feats = MatrixFeatures::of(a);
    let sm = SimMatrix::new(a.clone());
    (profile(&sm, n, gpu).best, feats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::prng::Xoshiro256;

    #[test]
    fn profile_orders_consistently() {
        let mut rng = Xoshiro256::seeded(81);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(2000, 2000, 0.004, &mut rng));
        let sm = SimMatrix::new(a);
        let p = profile(&sm, 32, &GpuConfig::v100());
        assert_eq!(p.loss_of(p.best), 0.0);
        for k in KernelKind::ALL {
            assert!(p.loss_of(k) >= 0.0);
            assert!(p.time_of(k) > 0.0);
        }
    }

    #[test]
    fn best_kernel_returns_features_too() {
        let mut rng = Xoshiro256::seeded(82);
        let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(500, 500, 0.01, &mut rng));
        let (k, f) = best_kernel(&a, 1, &GpuConfig::rtx3090());
        assert!(KernelKind::ALL.contains(&k));
        assert!(f.nnz > 0);
    }
}
