//! The Fig. 4 rule tree.
//!
//! ```text
//! N ≤ 4 (incl. SpMV)  ──►  parallel reduction (with VDL)
//!     avg_row < T_avg      ──►  PR-WB (VSR)    # short rows idle PR lanes
//!     else                 ──►  PR-RS
//! N > 4               ──►  sequential reduction (with CSC)
//!     stdv/avg > T_cv      ──►  SR-WB          # skew needs balancing
//!     else                 ──►  SR-RS
//! ```
//!
//! Insight 1 picks the reduction family from N; Insight 2 applies
//! balancing on skew (`stdv_row/avg_row`); Insight 3 tempers it — a large
//! `avg_row` means a large total workload whose waves hide imbalance,
//! which is why the *ratio* (not raw stdv) is the metric.

use crate::features::MatrixFeatures;
use crate::kernels::{KernelKind, Traversal};

/// One selector decision with everything needed to reproduce it: the
/// chosen kernel, the thresholds consulted (by name and value), and a
/// statement of the rule that fired. The engine and the sharded backend
/// turn these into `crate::obs::AuditEntry`s; the selectors themselves
/// stay observability-free.
#[derive(Clone, Debug)]
pub struct Decision {
    /// The chosen kernel design.
    pub kernel: KernelKind,
    /// Thresholds consulted, by name — replaying the rule on the same
    /// features against these values must reproduce `kernel`.
    pub thresholds: Vec<(&'static str, f64)>,
    /// Human-readable statement of the rule that fired.
    pub rule: String,
}

/// Rule-based selector with the paper's two empirical thresholds, plus
/// the orthogonal row-traversal threshold for the SR family (`DESIGN.md`
/// §Vectorization).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveSelector {
    /// N at or below which parallel reduction is used (paper: 4).
    pub n_threshold: usize,
    /// PR balancing: use VSR when `avg_row` is below this.
    pub t_avg: f64,
    /// SR balancing: use SR-WB when `stdv_row/avg_row` exceeds this.
    pub t_cv: f64,
    /// SR traversal: walk rows merge-path style when `stdv_row/avg_row`
    /// exceeds this (extreme skew, where even blocked row chunks
    /// serialize a worker). Deliberately above `t_cv`: moderate skew is
    /// answered by the WB layout first, merge-path only by heavy tails.
    pub t_mp: f64,
}

impl Default for AdaptiveSelector {
    /// Paper defaults; [`super::calibrate`] refines `t_avg`/`t_cv` against
    /// simulator profiles (`t_mp` is not calibrated — it only gates the
    /// traversal, not the kernel design).
    fn default() -> Self {
        Self {
            n_threshold: 4,
            t_avg: 12.0,
            t_cv: 1.5,
            t_mp: 4.0,
        }
    }
}

impl AdaptiveSelector {
    /// Pick a kernel for a matrix with features `f` and dense width `n`.
    pub fn select(&self, f: &MatrixFeatures, n: usize) -> KernelKind {
        if n.max(1) <= self.n_threshold {
            if f.avg_row < self.t_avg {
                KernelKind::PrWb
            } else {
                KernelKind::PrRs
            }
        } else if f.cv_row > self.t_cv {
            KernelKind::SrWb
        } else {
            KernelKind::SrRs
        }
    }

    /// [`AdaptiveSelector::select`] plus the audit trail: which
    /// thresholds were consulted and which rule fired, including the SR
    /// traversal sub-decision (`t_mp`) for the sequential family, where
    /// the backend will additionally resolve blocked vs. merge-path.
    pub fn decide(&self, f: &MatrixFeatures, n: usize) -> Decision {
        let kernel = self.select(f, n);
        let rule = if n.max(1) <= self.n_threshold {
            if f.avg_row < self.t_avg {
                format!(
                    "n={} <= t_n and avg_row={:.2} < t_avg -> pr_wb",
                    n, f.avg_row
                )
            } else {
                format!(
                    "n={} <= t_n and avg_row={:.2} >= t_avg -> pr_rs",
                    n, f.avg_row
                )
            }
        } else {
            let traversal = self.sr_traversal(f);
            let branch = if f.cv_row > self.t_cv {
                format!("n={} > t_n and cv_row={:.2} > t_cv -> sr_wb", n, f.cv_row)
            } else {
                format!("n={} > t_n and cv_row={:.2} <= t_cv -> sr_rs", n, f.cv_row)
            };
            format!(
                "{branch}; sr traversal cv_row {} t_mp -> {}",
                if f.cv_row > self.t_mp { ">" } else { "<=" },
                traversal.label()
            )
        };
        Decision {
            kernel,
            thresholds: vec![
                ("t_n", self.n_threshold as f64),
                ("t_avg", self.t_avg),
                ("t_cv", self.t_cv),
                ("t_mp", self.t_mp),
            ],
            rule,
        }
    }

    /// Row-traversal decision for the SR kernels: merge-path when the
    /// row-length skew is extreme (`cv_row > t_mp`), blocked otherwise.
    /// Orthogonal to [`AdaptiveSelector::select`] — the reduction order
    /// per row is unchanged, only the worker partitioning differs.
    pub fn sr_traversal(&self, f: &MatrixFeatures) -> Traversal {
        if f.cv_row > self.t_mp {
            Traversal::MergePath
        } else {
            Traversal::Blocked
        }
    }

    /// One decision per shard feature set — the Fig. 4 rules applied at
    /// the row-partition grain (`crate::shard`). A skewed head shard and a
    /// uniform tail shard of the same matrix can legitimately pick
    /// different kernels here; that is the point of sharded adaptivity.
    pub fn select_shards(&self, shards: &[MatrixFeatures], n: usize) -> Vec<KernelKind> {
        shards.iter().map(|f| self.select(f, n)).collect()
    }

    /// Human-readable explanation of a decision (used by the CLI).
    pub fn explain(&self, f: &MatrixFeatures, n: usize) -> String {
        let k = self.select(f, n);
        let family = if n.max(1) <= self.n_threshold {
            format!(
                "N={} ≤ {} → parallel reduction; avg_row={:.1} {} T_avg={:.1}",
                n,
                self.n_threshold,
                f.avg_row,
                if f.avg_row < self.t_avg { "<" } else { "≥" },
                self.t_avg
            )
        } else {
            format!(
                "N={} > {} → sequential reduction; stdv/avg={:.2} {} T_cv={:.2}",
                n,
                self.n_threshold,
                f.cv_row,
                if f.cv_row > self.t_cv { ">" } else { "≤" },
                self.t_cv
            )
        };
        format!("{} ⇒ {}", family, k.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::prng::Xoshiro256;

    fn features(rows: usize, avg: usize, skew: bool, seed: u64) -> MatrixFeatures {
        let mut rng = Xoshiro256::seeded(seed);
        let mut coo = CooMatrix::random_uniform(rows, rows, avg as f64 / rows as f64, &mut rng);
        if skew {
            for c in 0..rows / 2 {
                coo.push(0, c, 1.0);
            }
        }
        MatrixFeatures::of(&CsrMatrix::from_coo(&coo))
    }

    #[test]
    fn small_n_selects_parallel_reduction() {
        let sel = AdaptiveSelector::default();
        let f = features(500, 32, false, 1);
        for n in [1, 2, 4] {
            assert!(sel.select(&f, n).is_parallel_reduction(), "n={n}");
        }
        for n in [5, 8, 32, 128] {
            assert!(!sel.select(&f, n).is_parallel_reduction(), "n={n}");
        }
    }

    #[test]
    fn short_rows_balance_pr() {
        let sel = AdaptiveSelector::default();
        let short = features(2000, 3, false, 2);
        assert_eq!(sel.select(&short, 1), KernelKind::PrWb);
        let long = features(500, 64, false, 3);
        assert_eq!(sel.select(&long, 1), KernelKind::PrRs);
    }

    #[test]
    fn skew_balances_sr() {
        let sel = AdaptiveSelector::default();
        let flat = features(500, 16, false, 4);
        assert_eq!(sel.select(&flat, 32), KernelKind::SrRs);
        let skewed = features(500, 4, true, 5);
        assert!(skewed.cv_row > 1.5, "cv {}", skewed.cv_row);
        assert_eq!(sel.select(&skewed, 32), KernelKind::SrWb);
    }

    #[test]
    fn n0_treated_as_spmv() {
        let sel = AdaptiveSelector::default();
        let f = features(500, 4, false, 6);
        assert!(sel.select(&f, 0).is_parallel_reduction());
    }

    #[test]
    fn per_shard_selection_can_diverge() {
        let sel = AdaptiveSelector::default();
        let head = features(2000, 3, false, 8); // short rows -> PR-WB at small N
        let tail = features(500, 64, false, 9); // long rows -> PR-RS at small N
        assert_eq!(
            sel.select_shards(&[head, tail], 1),
            vec![KernelKind::PrWb, KernelKind::PrRs]
        );
        assert!(sel.select_shards(&[], 1).is_empty());
    }

    #[test]
    fn extreme_skew_flips_the_traversal() {
        let sel = AdaptiveSelector::default();
        let flat = features(500, 16, false, 10);
        assert_eq!(sel.sr_traversal(&flat), Traversal::Blocked);
        // one row holding most of the nnz drives cv_row far past t_mp
        let mut coo = CooMatrix::new(4000, 4000);
        for c in 0..3000 {
            coo.push(0, c, 1.0);
        }
        for r in 0..200 {
            coo.push(r + 1, r, 1.0);
        }
        let spiked = MatrixFeatures::of(&CsrMatrix::from_coo(&coo));
        assert!(spiked.cv_row > sel.t_mp, "cv {}", spiked.cv_row);
        assert_eq!(sel.sr_traversal(&spiked), Traversal::MergePath);
    }

    #[test]
    fn decide_reproduces_select_and_names_thresholds() {
        let sel = AdaptiveSelector::default();
        for (f, n) in [
            (features(500, 16, false, 11), 32usize),
            (features(500, 4, true, 12), 32),
            (features(2000, 3, false, 13), 1),
            (features(500, 64, false, 14), 2),
        ] {
            let d = sel.decide(&f, n);
            assert_eq!(d.kernel, sel.select(&f, n));
            assert!(d.rule.contains(d.kernel.label()), "{}", d.rule);
            let names: Vec<&str> = d.thresholds.iter().map(|(k, _)| *k).collect();
            assert_eq!(names, ["t_n", "t_avg", "t_cv", "t_mp"]);
            // the recorded thresholds are the selector's live values
            assert_eq!(d.thresholds[2].1, sel.t_cv);
        }
        // SR decisions carry the traversal sub-decision
        let d = sel.decide(&features(500, 16, false, 15), 64);
        assert!(d.rule.contains("sr traversal"), "{}", d.rule);
    }

    #[test]
    fn explain_mentions_decision() {
        let sel = AdaptiveSelector::default();
        let f = features(500, 16, false, 7);
        let e = sel.explain(&f, 64);
        assert!(e.contains("sequential"), "{e}");
        assert!(e.contains("sr_"), "{e}");
    }
}
