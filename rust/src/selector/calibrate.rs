//! Threshold calibration: fit `T_avg` / `T_cv` against simulator profiles
//! of the benchmark collection.
//!
//! The paper "empirically decides the threshold" from profiles on a large
//! matrix benchmark; this module reproduces that procedure: grid-search
//! the two thresholds, minimizing the geometric-mean slowdown of the
//! rule-selected kernel relative to the oracle over (matrix × N) pairs.

use super::oracle::OracleProfile;
use super::rules::AdaptiveSelector;
use crate::features::MatrixFeatures;
use crate::sim::GpuConfig;
use crate::util::stats;

/// One calibration sample: a matrix's features plus its oracle profile at
/// a given N.
#[derive(Clone, Debug)]
pub struct Sample {
    pub features: MatrixFeatures,
    pub n: usize,
    pub profile: OracleProfile,
}

/// Calibration outcome.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub selector: AdaptiveSelector,
    /// geometric-mean slowdown vs oracle at the chosen thresholds
    pub mean_loss: f64,
    /// candidate grid evaluated, with per-candidate loss (for reports)
    pub grid: Vec<(f64, f64, f64)>,
}

/// Default search grids (log-ish spacing around plausible regimes).
pub const T_AVG_GRID: [f64; 6] = [4.0, 8.0, 12.0, 16.0, 24.0, 48.0];
pub const T_CV_GRID: [f64; 6] = [0.25, 0.5, 1.0, 1.5, 2.5, 4.0];

/// Mean (geometric) slowdown of a selector over samples.
pub fn selector_loss(sel: &AdaptiveSelector, samples: &[Sample]) -> f64 {
    let ratios: Vec<f64> = samples
        .iter()
        .map(|s| {
            let k = sel.select(&s.features, s.n);
            s.profile.time_of(k) / s.profile.best_time()
        })
        .collect();
    stats::geomean(&ratios)
}

/// Grid-search the two thresholds; `n_threshold` is kept at the paper's 4
/// (it is structural: it is where VDL's sector economy runs out).
pub fn calibrate(samples: &[Sample]) -> Calibration {
    let mut best = AdaptiveSelector::default();
    let mut best_loss = f64::INFINITY;
    let mut grid = Vec::new();
    for &t_avg in &T_AVG_GRID {
        for &t_cv in &T_CV_GRID {
            let sel = AdaptiveSelector {
                n_threshold: 4,
                t_avg,
                t_cv,
                ..AdaptiveSelector::default()
            };
            let loss = selector_loss(&sel, samples);
            grid.push((t_avg, t_cv, loss));
            if loss < best_loss {
                best_loss = loss;
                best = sel;
            }
        }
    }
    Calibration {
        selector: best,
        mean_loss: best_loss,
        grid,
    }
}

/// Build calibration samples from a set of matrices (simulator profiles
/// at each dense width).
pub fn collect_samples(
    matrices: &[crate::sparse::CsrMatrix],
    n_values: &[usize],
    gpu: &GpuConfig,
) -> Vec<Sample> {
    use crate::sim::SimMatrix;
    let mut out = Vec::new();
    for a in matrices {
        let features = MatrixFeatures::of(a);
        let sm = SimMatrix::new(a.clone());
        for &n in n_values {
            out.push(Sample {
                features,
                n,
                profile: super::oracle::profile(&sm, n, gpu),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::powerlaw::PowerLawConfig;
    use crate::sparse::{CooMatrix, CsrMatrix};
    use crate::util::prng::Xoshiro256;

    fn small_suite() -> Vec<CsrMatrix> {
        let mut rng = Xoshiro256::seeded(91);
        let mut out = Vec::new();
        out.push(CsrMatrix::from_coo(&CooMatrix::random_uniform(
            3000, 3000, 0.002, &mut rng,
        )));
        out.push(CsrMatrix::from_coo(&CooMatrix::random_uniform(
            2000, 2000, 0.02, &mut rng,
        )));
        let cfg = PowerLawConfig {
            rows: 3000,
            cols: 3000,
            alpha: 1.6,
            min_row: 1,
            max_row: 1500,
        };
        out.push(CsrMatrix::from_coo(&cfg.generate(&mut rng)));
        out
    }

    #[test]
    fn calibration_beats_or_matches_default() {
        let samples = collect_samples(&small_suite(), &[1, 32], &GpuConfig::v100());
        let cal = calibrate(&samples);
        let default_loss = selector_loss(&AdaptiveSelector::default(), &samples);
        assert!(
            cal.mean_loss <= default_loss + 1e-12,
            "calibrated {} vs default {}",
            cal.mean_loss,
            default_loss
        );
        assert!(cal.mean_loss >= 1.0, "loss is a slowdown ratio ≥ 1");
        assert_eq!(cal.grid.len(), T_AVG_GRID.len() * T_CV_GRID.len());
    }

    #[test]
    fn calibrated_loss_is_the_grid_minimum_on_measured_shaped_samples() {
        // Measured profiles are arbitrary positive timings (no simulator
        // structure), so pin the invariant on random ones: `calibrate`
        // never returns thresholds whose loss exceeds any grid point's.
        use crate::kernels::KernelKind;
        use crate::util::proptest::run_prop;
        run_prop("calibrate picks the grid argmin", 40, |g| {
            let nsamples = g.usize_in(1, 10);
            let samples: Vec<Sample> = (0..nsamples)
                .map(|_| {
                    let avg_row = g.f64_in(0.5, 80.0);
                    let cv_row = g.f64_in(0.0, 4.0);
                    let mut seconds = [(KernelKind::SrRs, 0.0f64); 4];
                    for (i, k) in KernelKind::ALL.iter().enumerate() {
                        seconds[i] = (*k, g.f64_in(1e-6, 1e-3));
                    }
                    let best = seconds
                        .iter()
                        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap()
                        .0;
                    Sample {
                        features: MatrixFeatures {
                            rows: 1000,
                            cols: 1000,
                            nnz: (avg_row * 1000.0) as usize,
                            avg_row,
                            stdv_row: avg_row * cv_row,
                            cv_row,
                            max_row: 500,
                            empty_frac: 0.0,
                            gini_row: 0.0,
                        },
                        n: *g.choose(&[1usize, 2, 4, 8, 32, 128]),
                        profile: OracleProfile { best, seconds },
                    }
                })
                .collect();
            let cal = calibrate(&samples);
            let grid_min = cal
                .grid
                .iter()
                .map(|&(_, _, loss)| loss)
                .fold(f64::INFINITY, f64::min);
            if (cal.mean_loss - grid_min).abs() > 1e-9 {
                return Err(format!(
                    "returned loss {} but grid minimum is {grid_min}",
                    cal.mean_loss
                ));
            }
            let direct = selector_loss(&cal.selector, &samples);
            if (direct - cal.mean_loss).abs() > 1e-9 {
                return Err(format!(
                    "reported loss {} but selector evaluates to {direct}",
                    cal.mean_loss
                ));
            }
            if cal.mean_loss < 1.0 - 1e-12 {
                return Err(format!("loss {} below the oracle bound", cal.mean_loss));
            }
            Ok(())
        });
    }

    #[test]
    fn selector_loss_of_oracle_picks_is_one() {
        // a selector that always matched the oracle would have loss 1;
        // sanity-check the bound with per-sample inspection
        let samples = collect_samples(&small_suite()[..1], &[1], &GpuConfig::v100());
        for s in &samples {
            assert!(s.profile.best_time() > 0.0);
            assert_eq!(s.profile.loss_of(s.profile.best), 0.0);
        }
    }
}
