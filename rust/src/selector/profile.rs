//! Persisted hardware profiles: calibrated selector thresholds as a JSON
//! artifact a deployment writes once and loads at every startup.
//!
//! `ge-spmm calibrate --measured --profile <path>` fits `T_avg`/`T_cv`
//! against wallclock kernel timings ([`super::measured`]) and writes the
//! result here; `ge-spmm serve --profile <path>` (or the
//! `GE_SPMM_PROFILE` environment variable) loads it so the serving
//! engine boots with thresholds fitted to its own machine instead of the
//! paper's GPU defaults. See `DESIGN.md` §Measured calibration.

use super::rules::AdaptiveSelector;
use crate::kernels::{KernelKind, SparseOp};
use crate::util::json::{num, obj, s, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Environment variable consulted by [`HardwareProfile::autoload`].
pub const PROFILE_ENV: &str = "GE_SPMM_PROFILE";

/// Format version written into every profile (bump on breaking changes).
///
/// Version history: v1 carried thresholds only; v2 adds the optional
/// `variants` winner table from `ge-spmm tune`. v1 documents still load
/// (an absent table simply means "canonical variants everywhere").
pub const PROFILE_VERSION: u64 = 2;

/// One tuned variant winner: for traffic in `bucket` whose family rule
/// picks `family`, the measured-cheapest generated variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ProfileVariant {
    /// Which op the winner applies to.
    pub op: SparseOp,
    /// Cost bucket (SpMM: `feature_bucket`, SDDMM: `sddmm_bucket`).
    pub bucket: usize,
    /// Reduction/balancing family the rule layer picks.
    pub family: KernelKind,
    /// Canonical variant label within the family (e.g. `"sr_rs.t4"`).
    pub label: String,
    /// Measured cost (seconds per flop) of the winner; informational.
    pub cost: f64,
}

/// A calibration outcome persisted for reuse: the fitted thresholds plus
/// enough provenance to judge whether the fit still applies.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareProfile {
    /// The fitted selector thresholds.
    pub selector: AdaptiveSelector,
    /// Geometric-mean slowdown vs the profile-everything oracle at the
    /// fitted thresholds (1.0 = matches the oracle everywhere).
    pub mean_loss: f64,
    /// Where the profile came from: `"measured"` (wallclock) or
    /// `"simulated"` (`sim::GpuConfig`).
    pub source: String,
    /// Name of the backend the timings were taken on (e.g. `"native"`).
    pub backend: String,
    /// Number of `(matrix × N)` samples the fit saw.
    pub samples: usize,
    /// Dense widths profiled.
    pub n_values: Vec<usize>,
    /// Best-effort host label (hostname or `"unknown"`); informational.
    pub host: String,
    /// Seconds since the Unix epoch at fit time; informational.
    pub created_unix: u64,
    /// Tuned per-bucket variant winners (`ge-spmm tune`); empty means
    /// canonical variants everywhere — the pre-v2 behavior.
    pub variants: Vec<ProfileVariant>,
}

impl HardwareProfile {
    /// Assemble a profile from a calibration outcome, stamping host and
    /// creation time.
    pub fn new(
        cal: &super::calibrate::Calibration,
        source: &str,
        backend: &str,
        samples: usize,
        n_values: &[usize],
    ) -> Self {
        Self {
            selector: cal.selector,
            mean_loss: cal.mean_loss,
            source: source.to_string(),
            backend: backend.to_string(),
            samples,
            n_values: n_values.to_vec(),
            host: crate::bench::record::hostname(),
            created_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            variants: Vec::new(),
        }
    }

    /// Attach tuned variant winners (builder-style, for `ge-spmm tune`).
    pub fn with_variants(mut self, variants: Vec<ProfileVariant>) -> Self {
        self.variants = variants;
        self
    }

    /// Serialize as the on-disk JSON document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", num(PROFILE_VERSION as f64)),
            (
                "selector",
                obj(vec![
                    ("n_threshold", num(self.selector.n_threshold as f64)),
                    ("t_avg", num(self.selector.t_avg)),
                    ("t_cv", num(self.selector.t_cv)),
                    ("t_mp", num(self.selector.t_mp)),
                ]),
            ),
            ("mean_loss", num(self.mean_loss)),
            ("source", s(&self.source)),
            ("backend", s(&self.backend)),
            ("samples", num(self.samples as f64)),
            ("n_values", Json::Arr(self.n_values.iter().map(|&n| num(n as f64)).collect())),
            ("host", s(&self.host)),
            ("created_unix", num(self.created_unix as f64)),
            (
                "variants",
                Json::Arr(
                    self.variants
                        .iter()
                        .map(|v| {
                            obj(vec![
                                ("op", s(v.op.label())),
                                ("bucket", num(v.bucket as f64)),
                                ("family", s(v.family.label())),
                                ("variant", s(&v.label)),
                                ("cost", num(v.cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse and validate the on-disk JSON document.
    pub fn from_json(json: &Json) -> Result<Self> {
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("profile missing 'version'"))?;
        if version as u64 > PROFILE_VERSION {
            return Err(anyhow!(
                "profile version {version} is newer than supported {PROFILE_VERSION}"
            ));
        }
        let sel = json
            .get("selector")
            .ok_or_else(|| anyhow!("profile missing 'selector'"))?;
        let field = |name: &str| -> Result<f64> {
            sel.get(name)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("profile selector missing '{name}'"))
        };
        let selector = AdaptiveSelector {
            n_threshold: sel
                .get("n_threshold")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("profile selector missing 'n_threshold'"))?,
            t_avg: field("t_avg")?,
            t_cv: field("t_cv")?,
            // added after version 1 profiles shipped: absent in older
            // documents, so default rather than reject
            t_mp: sel
                .get("t_mp")
                .and_then(Json::as_f64)
                .unwrap_or(AdaptiveSelector::default().t_mp),
        };
        if !(selector.t_avg.is_finite() && selector.t_avg > 0.0)
            || !(selector.t_cv.is_finite() && selector.t_cv > 0.0)
            || !(selector.t_mp.is_finite() && selector.t_mp > 0.0)
        {
            return Err(anyhow!(
                "profile thresholds out of range: t_avg={} t_cv={} t_mp={}",
                selector.t_avg,
                selector.t_cv,
                selector.t_mp
            ));
        }
        // n_threshold is structural (the paper's 4: where VDL's sector
        // economy runs out) and the online machinery's feature buckets
        // split at it; a wild value would silently degrade refinement,
        // so reject anything outside a plausible band instead.
        if !(1..=64).contains(&selector.n_threshold) {
            return Err(anyhow!(
                "profile n_threshold {} out of range (expected 1..=64, structurally 4)",
                selector.n_threshold
            ));
        }
        Ok(Self {
            selector,
            mean_loss: json.get("mean_loss").and_then(Json::as_f64).unwrap_or(f64::NAN),
            source: json
                .get("source")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            backend: json
                .get("backend")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            samples: json.get("samples").and_then(Json::as_usize).unwrap_or(0),
            n_values: json
                .get("n_values")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            host: json
                .get("host")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            created_unix: json.get("created_unix").and_then(Json::as_usize).unwrap_or(0) as u64,
            // absent in v1 documents (and tolerated if individually
            // malformed): an unreadable winner degrades to "canonical",
            // never to a load failure
            variants: json
                .get("variants")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| {
                            let op = match v.get("op").and_then(Json::as_str)? {
                                "spmm" => SparseOp::Spmm,
                                "sddmm" => SparseOp::Sddmm,
                                _ => return None,
                            };
                            Some(ProfileVariant {
                                op,
                                bucket: v.get("bucket").and_then(Json::as_usize)?,
                                family: KernelKind::from_label(
                                    v.get("family").and_then(Json::as_str)?,
                                )?,
                                label: v.get("variant").and_then(Json::as_str)?.to_string(),
                                cost: v.get("cost").and_then(Json::as_f64).unwrap_or(f64::NAN),
                            })
                        })
                        .collect()
                })
                .unwrap_or_default(),
        })
    }

    /// Write the profile to `path` (pretty-printed, trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing hardware profile {}", path.display()))
    }

    /// Load and validate a profile from `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading hardware profile {}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("parsing hardware profile {}: {e}", path.display()))?;
        Self::from_json(&json).with_context(|| format!("validating {}", path.display()))
    }

    /// Load the profile named by the `GE_SPMM_PROFILE` environment
    /// variable, if set. Returns the path alongside the profile for
    /// logging; a set-but-unloadable path is an error (a deployment that
    /// points at a profile wants to know it did not take effect).
    pub fn autoload() -> Result<Option<(std::path::PathBuf, Self)>> {
        match std::env::var(PROFILE_ENV) {
            Ok(p) if !p.is_empty() => {
                let path = std::path::PathBuf::from(p);
                let profile = Self::load(&path)?;
                Ok(Some((path, profile)))
            }
            _ => Ok(None),
        }
    }

    /// One-line summary for startup logs.
    pub fn summary(&self) -> String {
        format!(
            "thresholds T_avg={} T_cv={} (n_threshold={}, source={}, backend={}, \
             {} samples, loss {:.3}, {} tuned variants)",
            self.selector.t_avg,
            self.selector.t_cv,
            self.selector.n_threshold,
            self.source,
            self.backend,
            self.samples,
            self.mean_loss,
            self.variants.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selector::calibrate::Calibration;

    fn cal() -> Calibration {
        Calibration {
            selector: AdaptiveSelector {
                n_threshold: 4,
                t_avg: 16.0,
                t_cv: 0.5,
                ..AdaptiveSelector::default()
            },
            mean_loss: 1.07,
            grid: vec![(16.0, 0.5, 1.07)],
        }
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let p = HardwareProfile::new(&cal(), "measured", "native", 24, &[1, 4, 32]);
        let back = HardwareProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("ge_spmm_profile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        let p = HardwareProfile::new(&cal(), "measured", "native", 3, &[1]);
        p.save(&path).unwrap();
        let loaded = HardwareProfile::load(&path).unwrap();
        assert_eq!(loaded, p);
        assert!(loaded.summary().contains("T_avg=16"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(HardwareProfile::from_json(&Json::parse("{}").unwrap()).is_err());
        // future version
        let newer = r#"{"version": 999, "selector": {"n_threshold": 4, "t_avg": 1, "t_cv": 1}}"#;
        assert!(HardwareProfile::from_json(&Json::parse(newer).unwrap()).is_err());
        // non-positive / non-finite thresholds
        for bad in [
            r#"{"version": 1, "selector": {"n_threshold": 4, "t_avg": 0, "t_cv": 1}}"#,
            r#"{"version": 1, "selector": {"n_threshold": 4, "t_avg": 12, "t_cv": -1}}"#,
            r#"{"version": 1, "selector": {"n_threshold": 4, "t_avg": 12}}"#,
            r#"{"version": 1, "selector": {"n_threshold": 0, "t_avg": 12, "t_cv": 1}}"#,
            r#"{"version": 1, "selector": {"n_threshold": 4096, "t_avg": 12, "t_cv": 1}}"#,
            r#"{"version": 1, "selector": {"n_threshold": 4, "t_avg": 12, "t_cv": 1, "t_mp": 0}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(HardwareProfile::from_json(&j).is_err(), "{bad}");
        }
        assert!(HardwareProfile::load(Path::new("/nonexistent/p.json")).is_err());
    }

    #[test]
    fn minimal_valid_document_fills_defaults() {
        let j = Json::parse(
            r#"{"version": 1, "selector": {"n_threshold": 4, "t_avg": 8.0, "t_cv": 1.5}}"#,
        )
        .unwrap();
        let p = HardwareProfile::from_json(&j).unwrap();
        assert_eq!(p.selector.t_avg, 8.0);
        // t_mp absent in pre-traversal documents → default, not an error
        assert_eq!(p.selector.t_mp, AdaptiveSelector::default().t_mp);
        assert_eq!(p.source, "unknown");
        assert_eq!(p.samples, 0);
        assert!(p.n_values.is_empty());
        // v1 documents have no variant table: canonical everywhere
        assert!(p.variants.is_empty());
    }

    #[test]
    fn variant_winners_round_trip_and_bad_entries_degrade() {
        let p = HardwareProfile::new(&cal(), "measured", "native", 12, &[1, 32]).with_variants(
            vec![
                ProfileVariant {
                    op: SparseOp::Spmm,
                    bucket: 8,
                    family: KernelKind::SrRs,
                    label: "sr_rs.t4".to_string(),
                    cost: 0.25,
                },
                ProfileVariant {
                    op: SparseOp::Sddmm,
                    bucket: 2,
                    family: KernelKind::PrWb,
                    label: "pr_wb.s64".to_string(),
                    cost: 0.5,
                },
            ],
        );
        let back = HardwareProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert!(back.summary().contains("2 tuned variants"), "{}", back.summary());
        // malformed winner entries are skipped, never a load failure
        let j = Json::parse(
            r#"{"version": 2,
                "selector": {"n_threshold": 4, "t_avg": 8.0, "t_cv": 1.5},
                "variants": [
                  {"op": "spmm", "bucket": 3, "family": "sr_wb", "variant": "sr_wb.s64", "cost": 1.0},
                  {"op": "conv", "bucket": 3, "family": "sr_wb", "variant": "x", "cost": 1.0},
                  {"op": "spmm", "family": "sr_wb", "variant": "no_bucket"},
                  {"op": "spmm", "bucket": 1, "family": "not_a_family", "variant": "x"}
                ]}"#,
        )
        .unwrap();
        let lenient = HardwareProfile::from_json(&j).unwrap();
        assert_eq!(lenient.variants.len(), 1);
        assert_eq!(lenient.variants[0].label, "sr_wb.s64");
    }
}
