//! Online selector refinement: learn kernel-choice thresholds from the
//! latencies live traffic is already producing.
//!
//! [`super::measured`] calibrates off-line against a benchmark suite;
//! this module keeps calibrating *on-line*. [`OnlineSelector`] wraps an
//! [`AdaptiveSelector`] and
//!
//! 1. **observes**: every execution reports `(features, N, kernel,
//!    latency)`; the normalized cost (seconds per flop) lands in the
//!    per-`(feature bucket, kernel)` EWMA table in
//!    [`Metrics`](crate::coordinator::metrics::Metrics);
//! 2. **explores**: every `explore_every`-th decision runs the sibling
//!    kernel of the rule's choice (same reduction family, opposite
//!    workload-balancing), so the EWMA table also has data for the road
//!    not taken — without exploration the refit could never contradict
//!    the current thresholds;
//! 3. **refits**: every `refit_every`-th observation re-runs the
//!    calibration grid search against the EWMA table. The Fig.-4 rule
//!    tree is separable — `T_avg` only affects small-N (parallel
//!    reduction) decisions and `T_cv` only large-N (sequential
//!    reduction) ones — so each threshold is refit independently, and
//!    only when its own family has measured evidence.
//!
//! Wired into [`crate::shard::ShardedBackend`] (per-shard decisions) and
//! [`crate::coordinator::SpmmEngine`] (request-level decisions on the
//! unsharded path) via `ShardedBackend::online` /
//! `SpmmEngine::serving_online`. See `DESIGN.md` §Measured calibration.

use super::calibrate::{T_AVG_GRID, T_CV_GRID};
use super::rules::{AdaptiveSelector, Decision};
use super::sddmm::{SddmmSelector, SDDMM_T_CV_GRID};
use crate::coordinator::metrics::{Metrics, COST_BUCKETS, COST_EWMA_ALPHA};
use crate::features::MatrixFeatures;
use crate::kernels::generator::{family_index, registry};
use crate::kernels::{KernelKind, SparseOp, VariantEntry};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Map `(features, N)` to a cost-table bucket: reduction family from N
/// (the paper's structural `n_threshold = 4`), three `avg_row` bins and
/// two `cv_row` bins. Coarse on purpose — the bucket count bounds how
/// much per-cell traffic the EWMAs need before they mean anything.
pub fn feature_bucket(f: &MatrixFeatures, n: usize) -> usize {
    let fam = usize::from(n.max(1) > 4);
    let avg = if f.avg_row < 8.0 {
        0
    } else if f.avg_row < 32.0 {
        1
    } else {
        2
    };
    let cv = usize::from(f.cv_row > 1.0);
    fam * 6 + avg * 2 + cv
}

/// Number of SDDMM cost buckets: 3 `avg_row` bins × 2 `cv_row` bins. No
/// family split — SDDMM's family switch (`d_threshold`) is structural
/// (where a dot window fills the lanes), so the refit only learns the
/// balance threshold. The table lives inside [`OnlineSelector`] rather
/// than [`Metrics`]: mixing the two ops' costs in one table would
/// corrupt both refits.
pub const SDDMM_BUCKETS: usize = 6;

/// Map SDDMM observation features to a cost bucket (same `avg_row` bins
/// as [`feature_bucket`], same `cv` split).
pub fn sddmm_bucket(f: &MatrixFeatures) -> usize {
    let avg = if f.avg_row < 8.0 {
        0
    } else if f.avg_row < 32.0 {
        1
    } else {
        2
    };
    let cv = usize::from(f.cv_row > 1.0);
    avg * 2 + cv
}

/// One SDDMM cost cell: EWMA of normalized cost plus its observation
/// count (0 = empty).
#[derive(Clone, Copy, Debug, Default)]
struct SddmmCostCell {
    ewma: f64,
    obs: u64,
}

/// The sibling design of `k`: same reduction family, opposite
/// workload-balancing — the exploration alternative whose cost a refit
/// needs to compare against.
pub fn sibling_kernel(k: KernelKind) -> KernelKind {
    match k {
        KernelKind::SrRs => KernelKind::SrWb,
        KernelKind::SrWb => KernelKind::SrRs,
        KernelKind::PrRs => KernelKind::PrWb,
        KernelKind::PrWb => KernelKind::PrRs,
    }
}

/// Exploration and refit cadence.
#[derive(Clone, Copy, Debug)]
pub struct OnlineConfig {
    /// Every `explore_every`-th decision runs the sibling kernel instead
    /// of the rule choice (0 disables exploration). The default spends
    /// ~6% of traffic on exploration.
    pub explore_every: u64,
    /// Re-fit thresholds every `refit_every` observations (0 disables
    /// refitting — the selector still observes, useful for warm-up).
    pub refit_every: u64,
    /// Minimum observations an EWMA cell needs before a refit trusts it.
    pub min_observations: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        Self {
            explore_every: 16,
            refit_every: 256,
            min_observations: 3,
        }
    }
}

/// Running per-bucket feature centroid, so a refit evaluates candidate
/// thresholds against the features traffic actually has (bucket-midpoint
/// representatives would mis-place workloads near a bin edge).
#[derive(Clone, Copy, Debug, Default)]
struct Centroid {
    count: f64,
    sum_avg: f64,
    sum_cv: f64,
    sum_n: f64,
    sum_nnz: f64,
}

/// One refit-ready bucket: centroid features plus its traffic weight.
struct BucketView {
    bucket: usize,
    features: MatrixFeatures,
    n: usize,
    weight: f64,
}

/// Thread-safe online-refined selector. Share one instance (via `Arc`)
/// between every decision point that should learn jointly — the serving
/// engine installs the same instance at the request grain and inside the
/// sharded backend.
pub struct OnlineSelector {
    metrics: Arc<Metrics>,
    config: OnlineConfig,
    state: Mutex<AdaptiveSelector>,
    centroids: Mutex<[Centroid; COST_BUCKETS]>,
    /// SDDMM refinement state: thresholds, private cost table (per-op —
    /// see [`SDDMM_BUCKETS`]) and its bucket centroids.
    sddmm_state: Mutex<SddmmSelector>,
    sddmm_costs: Mutex<[[SddmmCostCell; 4]; SDDMM_BUCKETS]>,
    sddmm_centroids: Mutex<[Centroid; SDDMM_BUCKETS]>,
    /// Learned per-`(bucket, family)` variant preference, keyed by the
    /// family's canonical variant id (globally unique per `(op, family)`,
    /// so SpMM and SDDMM buckets never collide) and holding the id of
    /// the cheapest measured variant in that family.
    variant_prefs: Mutex<HashMap<(usize, usize), usize>>,
    decisions: AtomicU64,
    variant_decisions: AtomicU64,
    observations: AtomicU64,
    sddmm_observations: AtomicU64,
    explorations: AtomicU64,
    variant_explorations: AtomicU64,
    refits: AtomicU64,
    sddmm_refits: AtomicU64,
}

impl OnlineSelector {
    /// Start from `base` thresholds (paper defaults, or a loaded
    /// [`super::profile::HardwareProfile`]), recording into `metrics`.
    /// The SDDMM thresholds start at their defaults; override with
    /// [`OnlineSelector::with_sddmm_base`].
    pub fn new(base: AdaptiveSelector, metrics: Arc<Metrics>, config: OnlineConfig) -> Self {
        Self {
            metrics,
            config,
            state: Mutex::new(base),
            centroids: Mutex::new([Centroid::default(); COST_BUCKETS]),
            sddmm_state: Mutex::new(SddmmSelector::default()),
            sddmm_costs: Mutex::new([[SddmmCostCell::default(); 4]; SDDMM_BUCKETS]),
            sddmm_centroids: Mutex::new([Centroid::default(); SDDMM_BUCKETS]),
            variant_prefs: Mutex::new(HashMap::new()),
            decisions: AtomicU64::new(0),
            variant_decisions: AtomicU64::new(0),
            observations: AtomicU64::new(0),
            sddmm_observations: AtomicU64::new(0),
            explorations: AtomicU64::new(0),
            variant_explorations: AtomicU64::new(0),
            refits: AtomicU64::new(0),
            sddmm_refits: AtomicU64::new(0),
        }
    }

    /// Seed the SDDMM thresholds (e.g. from an off-line
    /// [`super::sddmm::calibrate_sddmm`] fit).
    pub fn with_sddmm_base(self, base: SddmmSelector) -> Self {
        *self.sddmm_state.lock().unwrap() = base;
        self
    }

    /// Snapshot of the current thresholds.
    pub fn current(&self) -> AdaptiveSelector {
        *self.state.lock().unwrap()
    }

    /// Snapshot of the current SDDMM thresholds.
    pub fn current_sddmm(&self) -> SddmmSelector {
        *self.sddmm_state.lock().unwrap()
    }

    /// Row-traversal decision for SR kernels under the current
    /// thresholds (delegates to [`AdaptiveSelector::sr_traversal`];
    /// `t_mp` is not refit online — it gates the traversal, not the
    /// kernel design the EWMA table scores).
    pub fn traversal(&self, f: &MatrixFeatures) -> crate::kernels::Traversal {
        self.current().sr_traversal(f)
    }

    /// The metrics instance the EWMA observations land in.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Snapshot the selector-regret counters this selector has been
    /// folding into: how much realized cost its choices left on the
    /// table versus the best measured competing variant, per
    /// `(op, feature bucket)` (see [`crate::obs::regret`]).
    pub fn regret_report(&self) -> crate::obs::RegretReport {
        self.metrics.regret().report()
    }

    /// Pick a kernel: the current rule choice, except that every
    /// `explore_every`-th decision runs the sibling design instead.
    pub fn select(&self, f: &MatrixFeatures, n: usize) -> KernelKind {
        self.decide(f, n).0.kernel
    }

    /// [`OnlineSelector::select`] with the audit trail: the rule
    /// decision under the *current* (refined) thresholds, the sibling
    /// override noted in the rule text when this decision explores, and
    /// the exploration flag. Carries the same side effects as `select`
    /// (decision counter, exploration cadence) — call one or the other,
    /// not both.
    pub fn decide(&self, f: &MatrixFeatures, n: usize) -> (Decision, bool) {
        let mut dec = self.current().decide(f, n);
        let every = self.config.explore_every;
        let d = self.decisions.fetch_add(1, Ordering::Relaxed);
        let explored = every > 0 && (d + 1) % every == 0;
        if explored {
            self.explorations.fetch_add(1, Ordering::Relaxed);
            let sib = sibling_kernel(dec.kernel);
            dec.rule = format!(
                "{}; exploration overrides {} -> {}",
                dec.rule,
                dec.kernel.label(),
                sib.label()
            );
            dec.kernel = sib;
        }
        (dec, explored)
    }

    /// [`OnlineSelector::decide`] resolved down to a concrete generated
    /// variant: the family decision first (same counters, same sibling
    /// exploration), then the bucket's learned within-family preference
    /// — canonical when nothing is learned yet. A second, independent
    /// cadence (same `explore_every` period) swaps in one of the
    /// family's non-preferred variants so their cost cells accumulate
    /// evidence; the returned flag covers both kinds of exploration.
    pub fn decide_variant(
        &self,
        f: &MatrixFeatures,
        n: usize,
    ) -> (Decision, &'static VariantEntry, bool) {
        let (dec, explored) = self.decide(f, n);
        let bucket = feature_bucket(f, n);
        self.resolve_variant(SparseOp::Spmm, bucket, dec, explored)
    }

    /// SDDMM analogue of [`OnlineSelector::decide_variant`], sharing the
    /// family decision counter and the variant-exploration cadence.
    pub fn decide_sddmm_variant(
        &self,
        f: &MatrixFeatures,
        d: usize,
    ) -> (Decision, &'static VariantEntry, bool) {
        let (dec, explored) = self.decide_sddmm(f, d);
        let bucket = sddmm_bucket(f);
        self.resolve_variant(SparseOp::Sddmm, bucket, dec, explored)
    }

    /// Shared tail of the variant decisions: preference lookup plus the
    /// sibling-variant exploration cadence. Family explorations return
    /// the explored family's canonical point (its preference may be
    /// unmeasured noise) and do not consume the variant cadence.
    fn resolve_variant(
        &self,
        op: SparseOp,
        bucket: usize,
        mut dec: Decision,
        explored: bool,
    ) -> (Decision, &'static VariantEntry, bool) {
        let reg = registry();
        let canonical = reg.canonical(op, dec.kernel);
        if explored {
            return (dec, canonical, true);
        }
        let preferred = self
            .variant_pref(op, bucket, dec.kernel)
            .unwrap_or(canonical);
        let every = self.config.explore_every;
        let d = self.variant_decisions.fetch_add(1, Ordering::Relaxed);
        if every > 0 && (d + 1) % every == 0 {
            let alts: Vec<&'static VariantEntry> = reg
                .family_variants(op, dec.kernel)
                .into_iter()
                .filter(|e| e.id != preferred.id)
                .collect();
            if !alts.is_empty() {
                // cycle deterministically so every alternative gets a turn
                let pick = alts[((d / every) as usize) % alts.len()];
                self.variant_explorations.fetch_add(1, Ordering::Relaxed);
                dec.rule = format!(
                    "{}; variant exploration overrides {} -> {}",
                    dec.rule, preferred.label, pick.label
                );
                return (dec, pick, true);
            }
        }
        (dec, preferred, false)
    }

    /// The learned variant preference for `(op, bucket, family)`, if one
    /// has been measured or installed. Stale or cross-family ids (e.g.
    /// from a registry grown since a profile was written) resolve to
    /// `None` rather than a wrong entry.
    pub fn variant_pref(
        &self,
        op: SparseOp,
        bucket: usize,
        family: KernelKind,
    ) -> Option<&'static VariantEntry> {
        let reg = registry();
        let vid = *self
            .variant_prefs
            .lock()
            .unwrap()
            .get(&(bucket, reg.canonical_id(op, family)))?;
        reg.get(vid)
            .filter(|e| e.variant.op == op && e.variant.family == family)
    }

    /// Re-derive the `(op, bucket, family)` preference from the measured
    /// variant cells: the cheapest variant with at least
    /// `min_observations` observations wins; ties and no-evidence leave
    /// the preference alone (canonical by default).
    fn update_variant_pref(&self, op: SparseOp, bucket: usize, family: KernelKind) {
        let reg = registry();
        let best = reg
            .family_variants(op, family)
            .into_iter()
            .filter(|e| {
                self.metrics.cost_observations_variant(bucket, e.id) >= self.config.min_observations
            })
            .filter_map(|e| self.metrics.cost_variant(bucket, e.id).map(|c| (e.id, c)))
            .min_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((vid, _)) = best {
            self.variant_prefs
                .lock()
                .unwrap()
                .insert((bucket, reg.canonical_id(op, family)), vid);
        }
    }

    /// Seed the per-bucket variant preferences from tuned winners (e.g.
    /// a loaded [`super::profile::HardwareProfile`]): `(op, bucket,
    /// variant label)` triples. Unknown labels and out-of-range buckets
    /// are skipped; returns how many preferences were installed.
    pub fn install_variant_winners<'a>(
        &self,
        winners: impl IntoIterator<Item = (SparseOp, usize, &'a str)>,
    ) -> usize {
        let reg = registry();
        let mut installed = 0;
        let mut prefs = self.variant_prefs.lock().unwrap();
        for (op, bucket, label) in winners {
            let limit = match op {
                SparseOp::Spmm => COST_BUCKETS,
                SparseOp::Sddmm => SDDMM_BUCKETS,
            };
            if bucket >= limit {
                continue;
            }
            let Some(entry) = reg.by_label(op, label) else {
                continue;
            };
            prefs.insert((bucket, reg.canonical_id(op, entry.variant.family)), entry.id);
            installed += 1;
        }
        installed
    }

    /// Report one finished execution. Normalizes the latency by the
    /// cell's flop count, feeds the EWMA table and the bucket centroid,
    /// and triggers a refit on cadence. Family-level reports land on the
    /// family's canonical variant cell — the cell the family cost view
    /// aggregates over — so pre-variant callers keep working unchanged.
    pub fn observe(&self, f: &MatrixFeatures, n: usize, kernel: KernelKind, latency: Duration) {
        self.observe_variant(f, n, registry().canonical(SparseOp::Spmm, kernel), latency);
    }

    /// Variant-resolved [`OnlineSelector::observe`]: the cost lands on
    /// the *variant's* EWMA cell (the family view sees it through
    /// aggregation), and the family's per-bucket variant preference is
    /// re-derived from the measured cells. Accepts entries of either op;
    /// SDDMM entries take the SDDMM bookkeeping path (`n` is `d` there).
    pub fn observe_variant(
        &self,
        f: &MatrixFeatures,
        n: usize,
        entry: &VariantEntry,
        latency: Duration,
    ) {
        let flops = (2.0 * f.nnz as f64 * n.max(1) as f64).max(1.0);
        let cost = latency.as_secs_f64().max(1e-9) / flops;
        match entry.variant.op {
            SparseOp::Spmm => {
                let bucket = feature_bucket(f, n);
                self.metrics.observe_cost_variant(bucket, entry.id, cost);
                // fold selector regret: this realized cost against the
                // cheapest known cell among the op's variants in the same
                // bucket (the chosen variant's just-updated EWMA included,
                // so an always-optimal selector folds exactly zero)
                let best = registry()
                    .op_variants(SparseOp::Spmm)
                    .iter()
                    .filter_map(|e| self.metrics.cost_variant(bucket, e.id))
                    .fold(cost, f64::min);
                self.metrics.regret().fold(SparseOp::Spmm, bucket, entry.id, cost, best);
                // backfill the realized cost onto the matching audit
                // entry (a miss just means the decision ring already
                // wrapped past it)
                self.metrics
                    .audit()
                    .note_cost(SparseOp::Spmm, entry.variant.family, f.nnz, cost);
                {
                    let mut cents = self.centroids.lock().unwrap();
                    let c = &mut cents[bucket];
                    c.count += 1.0;
                    c.sum_avg += f.avg_row;
                    c.sum_cv += f.cv_row;
                    c.sum_n += n.max(1) as f64;
                    c.sum_nnz += f.nnz as f64;
                }
                self.update_variant_pref(SparseOp::Spmm, bucket, entry.variant.family);
                let o = self.observations.fetch_add(1, Ordering::Relaxed) + 1;
                if self.config.refit_every > 0 && o % self.config.refit_every == 0 {
                    self.refit();
                }
            }
            SparseOp::Sddmm => self.observe_sddmm_entry(f, n, entry, cost),
        }
    }

    /// Pick an SDDMM kernel: the current rule choice, with the same
    /// sibling-exploration cadence as [`OnlineSelector::select`] (the
    /// decision counter is shared across ops, so a mixed traffic stream
    /// spends one exploration budget, not two).
    pub fn select_sddmm(&self, f: &MatrixFeatures, d: usize) -> KernelKind {
        self.decide_sddmm(f, d).0.kernel
    }

    /// [`OnlineSelector::select_sddmm`] with the audit trail — the SDDMM
    /// analogue of [`OnlineSelector::decide`], sharing its decision
    /// counter and exploration budget.
    pub fn decide_sddmm(&self, f: &MatrixFeatures, d: usize) -> (Decision, bool) {
        let mut dec = self.current_sddmm().decide(f, d);
        let every = self.config.explore_every;
        let c = self.decisions.fetch_add(1, Ordering::Relaxed);
        let explored = every > 0 && (c + 1) % every == 0;
        if explored {
            self.explorations.fetch_add(1, Ordering::Relaxed);
            let sib = sibling_kernel(dec.kernel);
            dec.rule = format!(
                "{}; exploration overrides {} -> {}",
                dec.rule,
                dec.kernel.label(),
                sib.label()
            );
            dec.kernel = sib;
        }
        (dec, explored)
    }

    /// Report one finished SDDMM execution: normalized cost
    /// (seconds per flop, `2·nnz·d` flops) into the op's private EWMA
    /// table, centroid upkeep, and a refit on the same cadence as SpMM.
    /// Family-level reports land on the canonical variant's cell.
    pub fn observe_sddmm(
        &self,
        f: &MatrixFeatures,
        d: usize,
        kernel: KernelKind,
        latency: Duration,
    ) {
        self.observe_variant(f, d, registry().canonical(SparseOp::Sddmm, kernel), latency);
    }

    /// SDDMM half of [`OnlineSelector::observe_variant`]: the family
    /// EWMA table drives the threshold refit as before, while the
    /// variant cell in [`Metrics`] drives the within-family preference.
    fn observe_sddmm_entry(&self, f: &MatrixFeatures, d: usize, entry: &VariantEntry, cost: f64) {
        if !cost.is_finite() || cost <= 0.0 {
            return;
        }
        let kernel = entry.variant.family;
        self.metrics.audit().note_cost(SparseOp::Sddmm, kernel, f.nnz, cost);
        let bucket = sddmm_bucket(f);
        let idx = family_index(kernel);
        {
            let mut costs = self.sddmm_costs.lock().unwrap();
            let cell = &mut costs[bucket][idx];
            cell.ewma = if cell.obs == 0 {
                cost
            } else {
                cell.ewma + COST_EWMA_ALPHA * (cost - cell.ewma)
            };
            cell.obs += 1;
        }
        self.metrics.observe_cost_variant(bucket, entry.id, cost);
        // fold selector regret against the cheapest competing SDDMM cell
        // (see the SpMM branch of `observe_variant`)
        let best = registry()
            .op_variants(SparseOp::Sddmm)
            .iter()
            .filter_map(|e| self.metrics.cost_variant(bucket, e.id))
            .fold(cost, f64::min);
        self.metrics.regret().fold(SparseOp::Sddmm, bucket, entry.id, cost, best);
        self.update_variant_pref(SparseOp::Sddmm, bucket, kernel);
        {
            let mut cents = self.sddmm_centroids.lock().unwrap();
            let c = &mut cents[bucket];
            c.count += 1.0;
            c.sum_avg += f.avg_row;
            c.sum_cv += f.cv_row;
            c.sum_n += d.max(1) as f64;
            c.sum_nnz += f.nnz as f64;
        }
        let o = self.sddmm_observations.fetch_add(1, Ordering::Relaxed) + 1;
        if self.config.refit_every > 0 && o % self.config.refit_every == 0 {
            self.refit_sddmm();
        }
    }

    /// Re-fit the SDDMM balance threshold against the op's cost table
    /// now. `d_threshold` never moves (structural — see
    /// [`super::sddmm`]); `t_cv` moves only when some bucket has at
    /// least two measured kernels and a grid candidate strictly beats
    /// the current value. Returns whether the threshold changed.
    pub fn refit_sddmm(&self) -> bool {
        self.sddmm_refits.fetch_add(1, Ordering::Relaxed);
        let current = self.current_sddmm();
        let costs = *self.sddmm_costs.lock().unwrap();
        let cents = *self.sddmm_centroids.lock().unwrap();
        // refit-ready bucket views: centroid features + traffic weight
        let views: Vec<(usize, MatrixFeatures, usize, f64)> = (0..SDDMM_BUCKETS)
            .filter(|&b| cents[b].count > 0.0)
            .map(|b| {
                let c = cents[b];
                let avg = c.sum_avg / c.count;
                let cv = c.sum_cv / c.count;
                let features = MatrixFeatures {
                    rows: 0,
                    cols: 0,
                    nnz: (c.sum_nnz / c.count).round().max(0.0) as usize,
                    avg_row: avg,
                    stdv_row: avg * cv,
                    cv_row: cv,
                    max_row: 0,
                    empty_frac: 0.0,
                    gini_row: 0.0,
                };
                let d = (c.sum_n / c.count).round().max(1.0) as usize;
                (b, features, d, c.count)
            })
            .collect();
        let loss = |sel: &SddmmSelector| -> Option<f64> {
            let mut log_sum = 0.0;
            let mut weight = 0.0;
            for (b, f, d, w) in &views {
                let mut measured: Vec<(KernelKind, f64)> = Vec::new();
                for (i, &k) in KernelKind::ALL.iter().enumerate() {
                    let cell = costs[*b][i];
                    if cell.obs >= self.config.min_observations {
                        measured.push((k, cell.ewma));
                    }
                }
                if measured.len() < 2 {
                    continue; // nothing to trade off yet
                }
                let best = measured.iter().map(|&(_, c)| c).fold(f64::INFINITY, f64::min);
                let worst = measured.iter().map(|&(_, c)| c).fold(0.0, f64::max);
                let chosen = sel.select(f, *d);
                // unmeasured choices score at the worst measured cost —
                // same pessimism as the SpMM refit
                let cost = measured
                    .iter()
                    .find(|&&(k, _)| k == chosen)
                    .map(|&(_, c)| c)
                    .unwrap_or(worst);
                log_sum += *w * (cost / best).ln();
                weight += *w;
            }
            if weight == 0.0 {
                None
            } else {
                Some((log_sum / weight).exp())
            }
        };
        let Some(mut best_loss) = loss(&current) else {
            return false;
        };
        let mut best = current;
        for &cand in &SDDMM_T_CV_GRID {
            let sel = SddmmSelector { t_cv: cand, ..current };
            if let Some(l) = loss(&sel) {
                if l < best_loss - 1e-12 {
                    best_loss = l;
                    best = sel;
                }
            }
        }
        if best != current {
            *self.sddmm_state.lock().unwrap() = best;
            true
        } else {
            false
        }
    }

    /// SDDMM observations consumed so far.
    pub fn sddmm_observations(&self) -> u64 {
        self.sddmm_observations.load(Ordering::Relaxed)
    }

    /// SDDMM refits performed (on cadence or explicit).
    pub fn sddmm_refits(&self) -> u64 {
        self.sddmm_refits.load(Ordering::Relaxed)
    }

    /// Decisions taken so far (exploration included).
    pub fn decisions(&self) -> u64 {
        self.decisions.load(Ordering::Relaxed)
    }

    /// Observations consumed so far.
    pub fn observations(&self) -> u64 {
        self.observations.load(Ordering::Relaxed)
    }

    /// Decisions that ran the exploration sibling.
    pub fn explorations(&self) -> u64 {
        self.explorations.load(Ordering::Relaxed)
    }

    /// Variant decisions that ran a non-preferred sibling variant.
    pub fn variant_explorations(&self) -> u64 {
        self.variant_explorations.load(Ordering::Relaxed)
    }

    /// Learned (or installed) variant preferences currently held.
    pub fn variant_prefs_len(&self) -> usize {
        self.variant_prefs.lock().unwrap().len()
    }

    /// Refits performed (on cadence or explicit).
    pub fn refits(&self) -> u64 {
        self.refits.load(Ordering::Relaxed)
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let cur = self.current();
        let sd = self.current_sddmm();
        format!(
            "online[T_avg={} T_cv={} decisions={} explored={} observations={} refits={} \
             sddmm_T_cv={} sddmm_observations={} sddmm_refits={}] \
             variants[prefs={} explored={}]",
            cur.t_avg,
            cur.t_cv,
            self.decisions(),
            self.explorations(),
            self.observations(),
            self.refits(),
            sd.t_cv,
            self.sddmm_observations(),
            self.sddmm_refits(),
            self.variant_prefs_len(),
            self.variant_explorations()
        )
    }

    /// Forget the learned cost state a drifted matrix would consult.
    ///
    /// A dynamic-graph delta that moves a matrix's features across a
    /// bucket boundary leaves the EWMA cells it used to feed describing
    /// a workload that no longer exists; blending pre- and post-drift
    /// costs in one cell would poison the next refit. The engine calls
    /// this from `apply_delta` when drift is detected: every SpMM bucket
    /// the old or new features map to (both reduction families, so
    /// small-N and large-N traffic both restart) and both ops' centroids
    /// are zeroed, and the SDDMM buckets likewise. Thresholds already
    /// refit from the old evidence are *kept* — they are still the best
    /// known rule until fresh observations argue otherwise.
    ///
    /// Returns the number of distinct cost buckets reset (SpMM + SDDMM).
    pub fn reset_for_drift(&self, old: &MatrixFeatures, new: &MatrixFeatures) -> usize {
        let mut buckets: Vec<usize> = Vec::new();
        for f in [old, new] {
            for n in [1usize, 32] {
                buckets.push(feature_bucket(f, n));
            }
        }
        buckets.sort_unstable();
        buckets.dedup();
        {
            let mut cents = self.centroids.lock().unwrap();
            for &b in &buckets {
                self.metrics.reset_cost_bucket(b);
                cents[b] = Centroid::default();
            }
        }
        let mut sd = vec![sddmm_bucket(old), sddmm_bucket(new)];
        sd.sort_unstable();
        sd.dedup();
        {
            let mut costs = self.sddmm_costs.lock().unwrap();
            let mut cents = self.sddmm_centroids.lock().unwrap();
            for &b in &sd {
                costs[b] = [SddmmCostCell::default(); 4];
                cents[b] = Centroid::default();
            }
        }
        // drop the variant preferences the cleared buckets had learned —
        // they summarize exactly the cells that were just zeroed
        {
            let reg = registry();
            let mut prefs = self.variant_prefs.lock().unwrap();
            prefs.retain(|&(b, canon), _| match reg.get(canon).map(|e| e.variant.op) {
                Some(SparseOp::Spmm) => !buckets.contains(&b),
                Some(SparseOp::Sddmm) => !sd.contains(&b),
                None => false,
            });
        }
        buckets.len() + sd.len()
    }

    /// Re-fit both thresholds against the EWMA table now. Each threshold
    /// moves only if its own reduction family has refit-ready buckets
    /// (at least two measured kernels) and a grid candidate strictly
    /// beats the current value's predicted loss. Returns whether any
    /// threshold changed.
    pub fn refit(&self) -> bool {
        self.refits.fetch_add(1, Ordering::Relaxed);
        let current = self.current();
        let views = self.bucket_views();
        let pr: Vec<&BucketView> = views.iter().filter(|b| b.bucket < 6).collect();
        let sr: Vec<&BucketView> = views.iter().filter(|b| b.bucket >= 6).collect();
        let mut next = current;
        next.t_avg = self.fit_threshold(current, current.t_avg, &pr, &T_AVG_GRID, |sel, v| {
            AdaptiveSelector { t_avg: v, ..sel }
        });
        next.t_cv = self.fit_threshold(current, current.t_cv, &sr, &T_CV_GRID, |sel, v| {
            AdaptiveSelector { t_cv: v, ..sel }
        });
        if next != current {
            *self.state.lock().unwrap() = next;
            true
        } else {
            false
        }
    }

    /// 1-D threshold search: evaluate the current value and every grid
    /// candidate over the family's refit-ready buckets; keep the current
    /// value unless a candidate is strictly better.
    fn fit_threshold(
        &self,
        current: AdaptiveSelector,
        current_value: f64,
        buckets: &[&BucketView],
        grid: &[f64],
        apply: impl Fn(AdaptiveSelector, f64) -> AdaptiveSelector,
    ) -> f64 {
        let Some(mut best_loss) = self.candidate_loss(&current, buckets) else {
            // no ready buckets in this family: leave the threshold alone
            return current_value;
        };
        let mut best_value = current_value;
        for &cand in grid {
            let sel = apply(current, cand);
            if let Some(loss) = self.candidate_loss(&sel, buckets) {
                if loss < best_loss - 1e-12 {
                    best_loss = loss;
                    best_value = cand;
                }
            }
        }
        best_value
    }

    /// Weighted geometric-mean slowdown of `sel`'s choices vs the best
    /// measured kernel, over `buckets`. `None` if no bucket is ready.
    fn candidate_loss(&self, sel: &AdaptiveSelector, buckets: &[&BucketView]) -> Option<f64> {
        let mut log_sum = 0.0;
        let mut weight = 0.0;
        for b in buckets {
            let measured: Vec<(KernelKind, f64)> = KernelKind::ALL
                .iter()
                .filter(|&&k| {
                    self.metrics.cost_observations(b.bucket, k) >= self.config.min_observations
                })
                .filter_map(|&k| self.metrics.cost(b.bucket, k).map(|c| (k, c)))
                .collect();
            if measured.len() < 2 {
                continue; // nothing to trade off yet
            }
            let best = measured.iter().map(|&(_, c)| c).fold(f64::INFINITY, f64::min);
            let worst = measured.iter().map(|&(_, c)| c).fold(0.0, f64::max);
            let chosen = sel.select(&b.features, b.n);
            // an unmeasured choice is scored at the worst measured cost —
            // pessimistic, so refits never chase kernels they know
            // nothing about
            let cost = measured
                .iter()
                .find(|&&(k, _)| k == chosen)
                .map(|&(_, c)| c)
                .unwrap_or(worst);
            log_sum += b.weight * (cost / best).ln();
            weight += b.weight;
        }
        if weight == 0.0 {
            None
        } else {
            Some((log_sum / weight).exp())
        }
    }

    /// Snapshot the bucket centroids as refit inputs.
    fn bucket_views(&self) -> Vec<BucketView> {
        let cents = self.centroids.lock().unwrap();
        (0..COST_BUCKETS)
            .filter(|&b| cents[b].count > 0.0)
            .map(|b| {
                let c = cents[b];
                let avg = c.sum_avg / c.count;
                let cv = c.sum_cv / c.count;
                let nnz = (c.sum_nnz / c.count).round().max(0.0) as usize;
                BucketView {
                    bucket: b,
                    features: MatrixFeatures {
                        rows: 0,
                        cols: 0,
                        nnz,
                        avg_row: avg,
                        stdv_row: avg * cv,
                        cv_row: cv,
                        max_row: 0,
                        empty_frac: 0.0,
                        gini_row: 0.0,
                    },
                    n: (c.sum_n / c.count).round().max(1.0) as usize,
                    weight: c.count,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn features(avg_row: f64, cv_row: f64, nnz: usize) -> MatrixFeatures {
        MatrixFeatures {
            rows: 1000,
            cols: 1000,
            nnz,
            avg_row,
            stdv_row: avg_row * cv_row,
            cv_row,
            max_row: 100,
            empty_frac: 0.0,
            gini_row: 0.0,
        }
    }

    fn selector(config: OnlineConfig) -> OnlineSelector {
        OnlineSelector::new(
            AdaptiveSelector::default(),
            Arc::new(Metrics::default()),
            config,
        )
    }

    #[test]
    fn buckets_cover_the_index_space() {
        let mut seen = [false; COST_BUCKETS];
        for n in [1usize, 32] {
            for avg in [2.0, 16.0, 64.0] {
                for cv in [0.2, 2.0] {
                    let b = feature_bucket(&features(avg, cv, 4000), n);
                    assert!(b < COST_BUCKETS);
                    seen[b] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn sibling_flips_balancing_only() {
        for k in KernelKind::ALL {
            let s = sibling_kernel(k);
            assert_ne!(s, k);
            assert_eq!(s.is_parallel_reduction(), k.is_parallel_reduction());
            assert_ne!(s.is_balanced(), k.is_balanced());
            assert_eq!(sibling_kernel(s), k);
        }
    }

    #[test]
    fn exploration_runs_on_cadence() {
        let sel = selector(OnlineConfig {
            explore_every: 4,
            refit_every: 0,
            min_observations: 1,
        });
        let f = features(16.0, 0.3, 16000);
        let rule = AdaptiveSelector::default().select(&f, 32);
        let picks: Vec<KernelKind> = (0..8).map(|_| sel.select(&f, 32)).collect();
        for (i, &p) in picks.iter().enumerate() {
            if (i + 1) % 4 == 0 {
                assert_eq!(p, sibling_kernel(rule), "decision {i} explores");
            } else {
                assert_eq!(p, rule, "decision {i} exploits");
            }
        }
        assert_eq!(sel.explorations(), 2);
        assert_eq!(sel.decisions(), 8);

        let off = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            min_observations: 1,
        });
        assert!((0..32).all(|_| off.select(&f, 32) == rule));
        assert_eq!(off.explorations(), 0);
    }

    #[test]
    fn converges_to_the_measured_winner_on_a_skewed_workload() {
        // Workload: cv = 1.2 sits below the default T_cv = 1.5, so the
        // rule picks SR-RS — but the measured costs say SR-WB is 5x
        // faster (a skew the default threshold underestimates).
        let sel = selector(OnlineConfig {
            explore_every: 4,
            refit_every: 32,
            min_observations: 2,
        });
        let f = features(16.0, 1.2, 16000);
        assert_eq!(sel.current().select(&f, 32), KernelKind::SrRs);
        for _ in 0..32 {
            sel.observe(&f, 32, KernelKind::SrRs, Duration::from_micros(500));
            sel.observe(&f, 32, KernelKind::SrWb, Duration::from_micros(100));
        }
        assert!(sel.refits() >= 1, "refit cadence fired");
        let cur = sel.current();
        assert!(cur.t_cv <= 1.0, "T_cv dropped below the workload's cv: {cur:?}");
        assert_eq!(cur.select(&f, 32), KernelKind::SrWb, "choice shifted");
        // ... and T_avg did not move: no small-N traffic was observed
        assert_eq!(cur.t_avg, AdaptiveSelector::default().t_avg);
        assert_eq!(cur.n_threshold, 4, "structural threshold untouched");
    }

    #[test]
    fn refit_without_evidence_changes_nothing() {
        let sel = selector(OnlineConfig::default());
        assert!(!sel.refit(), "no observations, no movement");
        assert_eq!(sel.current(), AdaptiveSelector::default());
        // one kernel alone is not evidence of a trade-off
        let f = features(4.0, 0.5, 8000);
        for _ in 0..8 {
            sel.observe(&f, 1, KernelKind::PrWb, Duration::from_micros(50));
        }
        assert!(!sel.refit());
        assert_eq!(sel.current(), AdaptiveSelector::default());
        assert!(sel.summary().contains("refits=2"));
    }

    #[test]
    fn sddmm_buckets_cover_the_index_space() {
        let mut seen = [false; SDDMM_BUCKETS];
        for avg in [2.0, 16.0, 64.0] {
            for cv in [0.2, 2.0] {
                let b = sddmm_bucket(&features(avg, cv, 4000));
                assert!(b < SDDMM_BUCKETS);
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn sddmm_selection_explores_on_the_shared_cadence() {
        let sel = selector(OnlineConfig {
            explore_every: 4,
            refit_every: 0,
            min_observations: 1,
        });
        let f = features(16.0, 0.3, 16000);
        let rule = SddmmSelector::default().select(&f, 8);
        let picks: Vec<KernelKind> = (0..8).map(|_| sel.select_sddmm(&f, 8)).collect();
        for (i, &p) in picks.iter().enumerate() {
            if (i + 1) % 4 == 0 {
                assert_eq!(p, sibling_kernel(rule), "decision {i} explores");
            } else {
                assert_eq!(p, rule, "decision {i} exploits");
            }
        }
        assert_eq!(sel.decisions(), 8, "ops share one decision counter");
    }

    #[test]
    fn sddmm_refit_tightens_the_balance_threshold_on_evidence() {
        // cv = 0.3 sits below the SDDMM default t_cv = 0.5, so the rule
        // picks SR-RS — but measured costs say SR-WB is 5x faster.
        let sel = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            min_observations: 2,
        });
        let f = features(16.0, 0.3, 16000);
        assert_eq!(sel.current_sddmm().select(&f, 8), KernelKind::SrRs);
        assert!(!sel.refit_sddmm(), "no evidence, no movement");
        for _ in 0..6 {
            sel.observe_sddmm(&f, 8, KernelKind::SrRs, Duration::from_micros(500));
            sel.observe_sddmm(&f, 8, KernelKind::SrWb, Duration::from_micros(100));
        }
        assert_eq!(sel.sddmm_observations(), 12);
        assert!(sel.refit_sddmm(), "evidence moves t_cv");
        let cur = sel.current_sddmm();
        assert!(cur.t_cv < 0.3, "{cur:?}");
        assert_eq!(cur.select(&f, 8), KernelKind::SrWb, "choice shifted");
        assert_eq!(cur.d_threshold, SddmmSelector::default().d_threshold);
        // ...and the SpMM thresholds were untouched: per-op tables
        assert_eq!(sel.current(), AdaptiveSelector::default());
        assert!(sel.summary().contains("sddmm_T_cv=0.25"), "{}", sel.summary());
    }

    #[test]
    fn sddmm_refit_fires_on_the_observation_cadence() {
        let sel = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 8,
            min_observations: 2,
        });
        let f = features(16.0, 0.3, 16000);
        for _ in 0..4 {
            sel.observe_sddmm(&f, 8, KernelKind::SrRs, Duration::from_micros(500));
            sel.observe_sddmm(&f, 8, KernelKind::SrWb, Duration::from_micros(100));
        }
        assert!(sel.sddmm_refits() >= 1, "cadence fired");
        assert_eq!(sel.current_sddmm().select(&f, 8), KernelKind::SrWb);
    }

    #[test]
    fn decide_flags_exploration_and_observe_backfills_the_audit() {
        let sel = selector(OnlineConfig {
            explore_every: 2,
            refit_every: 0,
            min_observations: 1,
        });
        let f = features(16.0, 0.3, 16000);
        let rule = AdaptiveSelector::default().select(&f, 32);
        let (first, explored1) = sel.decide(&f, 32);
        assert!(!explored1);
        assert_eq!(first.kernel, rule);
        let (second, explored2) = sel.decide(&f, 32);
        assert!(explored2, "second decision explores at cadence 2");
        assert_eq!(second.kernel, sibling_kernel(rule));
        assert!(second.rule.contains("exploration overrides"), "{}", second.rule);
        // push the decision into the audit log the way the engine does,
        // then observe: the realized cost must land on the entry
        let metrics = sel.metrics();
        metrics.audit().push(crate::obs::AuditEntry {
            seq: 0,
            op: SparseOp::Spmm,
            grain: "request",
            shard: None,
            selector: "online",
            matrix: Some(0),
            features: f,
            n: 32,
            thresholds: first.thresholds.clone(),
            rule: first.rule.clone(),
            kernel: first.kernel,
            variant: None,
            explored: false,
            realized_cost: None,
        });
        sel.observe(&f, 32, first.kernel, Duration::from_micros(200));
        assert_eq!(metrics.audit().realized(), 1);
        let entry = &metrics.audit().entries()[0];
        assert!(entry.realized_cost.unwrap() > 0.0);
        // replaying the recorded thresholds reproduces the decision
        assert_eq!(entry.threshold("t_cv"), Some(AdaptiveSelector::default().t_cv));
    }

    #[test]
    fn reset_for_drift_clears_the_matrix_buckets_but_keeps_thresholds() {
        let sel = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            min_observations: 2,
        });
        // learn a non-default SpMM threshold from skewed evidence first
        let f_old = features(16.0, 1.2, 16000);
        for _ in 0..8 {
            sel.observe(&f_old, 32, KernelKind::SrRs, Duration::from_micros(500));
            sel.observe(&f_old, 32, KernelKind::SrWb, Duration::from_micros(100));
        }
        assert!(sel.refit());
        let refined = sel.current();
        assert_ne!(refined, AdaptiveSelector::default());
        // SDDMM evidence in the old bucket too
        for _ in 0..4 {
            sel.observe_sddmm(&f_old, 8, KernelKind::SrRs, Duration::from_micros(500));
        }
        // unrelated bucket: different avg bin, must survive the reset
        let f_other = features(2.0, 0.2, 2000);
        sel.observe(&f_other, 32, KernelKind::PrRs, Duration::from_micros(200));
        let b_old = feature_bucket(&f_old, 32);
        let b_other = feature_bucket(&f_other, 32);
        assert_ne!(b_old, b_other);
        let metrics = sel.metrics();
        assert!(metrics.cost(b_old, KernelKind::SrRs).is_some());
        assert!(metrics.cost(b_other, KernelKind::PrRs).is_some());

        // drift: avg_row bin moves (16 -> 64)
        let f_new = features(64.0, 1.2, 64000);
        let cleared = sel.reset_for_drift(&f_old, &f_new);
        assert!(cleared >= 3, "old+new spmm buckets plus sddmm: {cleared}");
        for n in [1usize, 32] {
            for f in [&f_old, &f_new] {
                let b = feature_bucket(f, n);
                for k in KernelKind::ALL {
                    assert!(metrics.cost(b, k).is_none(), "bucket {b} kernel {k:?}");
                    assert_eq!(metrics.cost_observations(b, k), 0);
                }
            }
        }
        assert!(metrics.cost(b_other, KernelKind::PrRs).is_some(), "bystander kept");
        let costs = sel.sddmm_costs.lock().unwrap();
        for cell in &costs[sddmm_bucket(&f_old)] {
            assert_eq!(cell.obs, 0, "sddmm cells cleared");
        }
        drop(costs);
        // thresholds survive: still the best known rule until re-learned
        assert_eq!(sel.current(), refined);
        // ...and the cleared bucket accepts fresh evidence
        sel.observe(&f_new, 32, KernelKind::SrWb, Duration::from_micros(80));
        assert!(metrics.cost(feature_bucket(&f_new, 32), KernelKind::SrWb).is_some());
    }

    #[test]
    fn refit_moves_t_avg_on_small_n_evidence() {
        // avg_row = 4 < default T_avg = 12 → rule picks PR-WB, but PR-RS
        // measures 4x faster; T_avg must drop to at most 4.
        let sel = selector(OnlineConfig {
            explore_every: 2,
            refit_every: 0,
            min_observations: 2,
        });
        let f = features(4.0, 0.5, 4000);
        assert_eq!(sel.current().select(&f, 1), KernelKind::PrWb);
        for _ in 0..8 {
            sel.observe(&f, 1, KernelKind::PrWb, Duration::from_micros(400));
            sel.observe(&f, 1, KernelKind::PrRs, Duration::from_micros(100));
        }
        assert!(sel.refit());
        let cur = sel.current();
        assert_eq!(cur.select(&f, 1), KernelKind::PrRs, "{cur:?}");
        assert_eq!(cur.t_cv, AdaptiveSelector::default().t_cv, "SR untouched");
    }

    #[test]
    fn variant_observations_shift_the_within_family_preference() {
        let sel = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            min_observations: 2,
        });
        let f = features(16.0, 0.3, 16000);
        let reg = registry();
        let (dec, entry, explored) = sel.decide_variant(&f, 32);
        assert!(!explored);
        assert_eq!(dec.kernel, KernelKind::SrRs);
        assert_eq!(entry.id, reg.canonical_id(SparseOp::Spmm, dec.kernel), "no evidence -> canonical");
        // measure the tiled variant 5x cheaper than the canonical point
        let canon = reg.canonical(SparseOp::Spmm, KernelKind::SrRs);
        let fast = reg.by_label(SparseOp::Spmm, "sr_rs.t4").unwrap();
        for _ in 0..4 {
            sel.observe_variant(&f, 32, canon, Duration::from_micros(500));
            sel.observe_variant(&f, 32, fast, Duration::from_micros(100));
        }
        let (dec2, entry2, explored2) = sel.decide_variant(&f, 32);
        assert!(!explored2);
        assert_eq!(dec2.kernel, KernelKind::SrRs, "family decision unchanged");
        assert_eq!(entry2.label, "sr_rs.t4", "preference follows the measured winner");
        assert!(sel.summary().contains("variants[prefs=1"), "{}", sel.summary());
    }

    #[test]
    fn variant_exploration_cycles_non_preferred_siblings() {
        let sel = selector(OnlineConfig {
            explore_every: 4,
            refit_every: 0,
            min_observations: 1,
        });
        let f = features(16.0, 0.3, 16000);
        let mut picks = Vec::new();
        for _ in 0..8 {
            let (dec, entry, explored) = sel.decide_variant(&f, 32);
            picks.push((dec, entry, explored));
        }
        // i = 0..2 exploit the canonical preference
        for (dec, entry, explored) in &picks[0..3] {
            assert!(!explored);
            assert_eq!(dec.kernel, KernelKind::SrRs);
            assert_eq!(entry.label, "sr_rs");
        }
        // i = 3: family exploration wins and lands on the sibling
        // family's canonical point (variant cadence not consumed)
        assert!(picks[3].2);
        assert_eq!(picks[3].0.kernel, KernelKind::SrWb);
        assert_eq!(picks[3].1.label, "sr_wb");
        // i = 4 is the 4th non-family-explored decision: the variant
        // cadence fires and cycles to the first non-preferred sibling
        assert!(picks[4].2);
        assert_eq!(picks[4].0.kernel, KernelKind::SrRs, "family stays put");
        assert_eq!(picks[4].1.label, "sr_rs.t1");
        assert!(
            picks[4].0.rule.contains("variant exploration overrides"),
            "{}",
            picks[4].0.rule
        );
        assert_eq!(sel.variant_explorations(), 1);
        assert_eq!(sel.explorations(), 2, "family cadence untouched");
    }

    #[test]
    fn installed_winners_steer_variant_decisions_until_drift_resets_them() {
        let sel = selector(OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            min_observations: 2,
        });
        let f = features(16.0, 0.3, 16000);
        let b = feature_bucket(&f, 32);
        let sb = sddmm_bucket(&f);
        let installed = sel.install_variant_winners([
            (SparseOp::Spmm, b, "sr_rs.mp"),
            (SparseOp::Sddmm, sb, "sr_rs.t1"),
            (SparseOp::Spmm, b, "no_such_variant"), // unknown label skipped
            (SparseOp::Spmm, COST_BUCKETS, "sr_rs.t4"), // bucket out of range skipped
        ]);
        assert_eq!(installed, 2);
        let (dec, entry, explored) = sel.decide_variant(&f, 32);
        assert!(!explored);
        assert_eq!(dec.kernel, KernelKind::SrRs);
        assert_eq!(entry.label, "sr_rs.mp", "installed SpMM winner honored");
        let (sdec, sentry, sexplored) = sel.decide_sddmm_variant(&f, 8);
        assert!(!sexplored);
        assert_eq!(sdec.kernel, KernelKind::SrRs);
        assert_eq!(sentry.label, "sr_rs.t1", "installed SDDMM winner honored");
        // drift through the bucket drops the installed preferences with
        // the cost cells they summarize
        let f_new = features(64.0, 0.3, 64000);
        sel.reset_for_drift(&f, &f_new);
        assert!(sel.variant_pref(SparseOp::Spmm, b, KernelKind::SrRs).is_none());
        assert!(sel.variant_pref(SparseOp::Sddmm, sb, KernelKind::SrRs).is_none());
        let (_, e2, _) = sel.decide_variant(&f, 32);
        assert_eq!(e2.label, "sr_rs", "back to canonical after the reset");
    }
}
