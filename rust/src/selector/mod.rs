//! Adaptive kernel selection — the paper's second contribution (§2.2).
//!
//! [`rules`] implements the Fig. 4 decision tree over low-cost row-length
//! statistics; [`calibrate`] fits its two thresholds against profiles of
//! the benchmark collection (the paper "empirically decides the
//! threshold") — fed either by the analytical simulator
//! ([`calibrate::collect_samples`]) or by wallclock timings of the real
//! kernels ([`measured::collect_samples`]); [`oracle`] is the
//! profile-everything upper bound the paper calls "select the best
//! implementation off-line". [`profile`] persists a fit as a JSON
//! [`HardwareProfile`] deployments load at startup, and [`online`] keeps
//! refining the thresholds against live-traffic latency EWMAs.
//!
//! The rules run at two grains: per request in
//! [`crate::coordinator::SpmmEngine`], and per row shard inside
//! [`crate::shard::ShardedBackend`] (`DESIGN.md` §Sharded execution and
//! §Measured calibration).
//!
//! [`sddmm`] applies the same methodology to the second sparse op: the
//! dot length `d` takes the dense width's place as the family switch and
//! the balance threshold tightens (SDDMM has no dense-row reuse to hide
//! imbalance behind) — mirroring the paper's SpMV-vs-SpMM feature split.
//! See `DESIGN.md` §SDDMM.

pub mod calibrate;
pub mod measured;
pub mod online;
pub mod oracle;
pub mod profile;
pub mod rules;
pub mod sddmm;

pub use crate::kernels::KernelKind;
pub use online::{OnlineConfig, OnlineSelector};
pub use profile::HardwareProfile;
pub use rules::{AdaptiveSelector, Decision};
pub use sddmm::SddmmSelector;
