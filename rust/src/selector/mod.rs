//! Adaptive kernel selection — the paper's second contribution (§2.2).
//!
//! [`rules`] implements the Fig. 4 decision tree over low-cost row-length
//! statistics; [`calibrate`] fits its two thresholds against simulator
//! profiles of the benchmark collection (the paper "empirically decides
//! the threshold"); [`oracle`] is the profile-everything upper bound the
//! paper calls "select the best implementation off-line".
//!
//! The rules run at two grains: per request in
//! [`crate::coordinator::SpmmEngine`], and per row shard inside
//! [`crate::shard::ShardedBackend`] (`DESIGN.md` §Sharded execution).

pub mod calibrate;
pub mod oracle;
pub mod rules;

pub use crate::kernels::KernelKind;
pub use rules::AdaptiveSelector;
