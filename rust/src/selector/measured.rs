//! Wallclock profiling of the four kernel designs through a
//! [`SpmmBackend`] — measured counterpart of the simulator-backed
//! [`super::oracle::profile`].
//!
//! The paper "empirically decides the threshold" from profiles taken on
//! real hardware; [`super::calibrate`] reproduces the fitting procedure
//! but was previously only ever fed analytical `sim::GpuConfig` profiles.
//! This module closes that gap: [`profile_measured`] times all four
//! kernels on an actual backend and packages the medians as an
//! [`OracleProfile`], and [`collect_samples`] builds the same
//! `(matrix × N)` sample set [`super::calibrate::calibrate`] consumes —
//! so the grid search runs unchanged on real timings. The fitted
//! thresholds can be persisted as a [`super::profile::HardwareProfile`]
//! (`ge-spmm calibrate --measured --profile <path>`) and loaded at
//! deployment startup.

use super::calibrate::Sample;
use super::online::{feature_bucket, sddmm_bucket};
use super::oracle::OracleProfile;
use super::profile::ProfileVariant;
use crate::backend::SpmmBackend;
use crate::bench::harness::{bench_fn_with, BenchConfig};
use crate::features::MatrixFeatures;
use crate::kernels::generator::registry;
use crate::kernels::{KernelKind, SparseOp, VariantEntry};
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::prng::Xoshiro256;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::time::Duration;

/// Measurement budget for one (matrix, N, kernel) cell.
///
/// The defaults are sized for calibration (many cells, each needing only
/// a stable median), not for publication-grade benchmarking — tighten
/// via [`MeasureConfig::with_budget_ms`] for CI smokes or loosen for a
/// quiet dedicated machine.
#[derive(Clone, Copy, Debug)]
pub struct MeasureConfig {
    /// Warmup budget before the timed iterations.
    pub warmup: Duration,
    /// Timed-measurement budget.
    pub measure: Duration,
    /// Iteration floor (median needs a few samples even for slow cells).
    pub min_iters: usize,
    /// Iteration ceiling (bounds tiny-matrix cells).
    pub max_iters: usize,
    /// Seed for the dense operand.
    pub seed: u64,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(40),
            min_iters: 3,
            max_iters: 200,
            seed: 0x6e5f,
        }
    }
}

impl MeasureConfig {
    /// Scale the per-cell budget: `ms` of measurement with a quarter of
    /// it as warmup. `ms = 0` is clamped to 1.
    pub fn with_budget_ms(mut self, ms: u64) -> Self {
        let ms = ms.max(1);
        self.measure = Duration::from_millis(ms);
        self.warmup = Duration::from_millis(ms.div_ceil(4));
        self
    }

    fn bench_config(&self) -> BenchConfig {
        BenchConfig {
            warmup: self.warmup,
            measure: self.measure,
            min_iters: self.min_iters,
            max_iters: self.max_iters,
        }
    }
}

/// Time all four kernels on `backend` for one `(matrix, N)` cell and
/// return the winner plus every candidate's median seconds — the same
/// shape the simulator oracle produces, so downstream calibration cannot
/// tell measured and simulated profiles apart.
///
/// The backend must honor the explicit `KernelKind` (true of
/// `NativeBackend` and fixed-mode `ShardedBackend`). Do not profile
/// through a per-shard-adaptive backend: it re-selects internally and
/// would attribute one kernel's time to another.
pub fn profile_measured(
    backend: &dyn SpmmBackend,
    csr: &CsrMatrix,
    n: usize,
    cfg: &MeasureConfig,
) -> Result<OracleProfile> {
    if csr.nnz() == 0 || csr.rows == 0 {
        bail!("cannot profile an empty matrix ({}x{})", csr.rows, csr.cols);
    }
    let operand = backend.prepare(csr)?;
    let mut rng = Xoshiro256::seeded(cfg.seed);
    let x = DenseMatrix::random(csr.cols, n.max(1), 1.0, &mut rng);
    let bench_cfg = cfg.bench_config();
    let mut seconds = [(KernelKind::SrRs, 0.0f64); 4];
    for (i, k) in KernelKind::ALL.iter().enumerate() {
        // fail fast (and don't time error paths) if the backend cannot
        // run this cell at all
        backend.execute(&operand, &x, *k)?;
        let stats = bench_fn_with(k.label(), bench_cfg, || {
            let exec = backend.execute(&operand, &x, *k).expect("profiled execute");
            std::hint::black_box(&exec.y.data);
        });
        // Instant is monotonic but coarse clocks can report 0 for a tiny
        // cell; clamp so OracleProfile ratios stay finite.
        seconds[i] = (*k, stats.median_s().max(1e-9));
    }
    let best = seconds
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
    Ok(OracleProfile { best, seconds })
}

/// Time all four SDDMM designs on `backend` for one `(matrix, d)` cell —
/// the SDDMM counterpart of [`profile_measured`], feeding
/// [`super::sddmm::calibrate_sddmm`]. Same backend constraint: profile
/// only through backends that honor the explicit `KernelKind`.
pub fn profile_measured_sddmm(
    backend: &dyn SpmmBackend,
    csr: &CsrMatrix,
    d: usize,
    cfg: &MeasureConfig,
) -> Result<OracleProfile> {
    if csr.nnz() == 0 || csr.rows == 0 {
        bail!("cannot profile an empty matrix ({}x{})", csr.rows, csr.cols);
    }
    let operand = backend.prepare(csr)?;
    let mut rng = Xoshiro256::seeded(cfg.seed);
    let u = DenseMatrix::random(csr.rows, d.max(1), 1.0, &mut rng);
    let v = DenseMatrix::random(csr.cols, d.max(1), 1.0, &mut rng);
    let bench_cfg = cfg.bench_config();
    let mut seconds = [(KernelKind::SrRs, 0.0f64); 4];
    for (i, k) in KernelKind::ALL.iter().enumerate() {
        backend.execute_sddmm(&operand, &u, &v, *k)?;
        let stats = bench_fn_with(k.label(), bench_cfg, || {
            let exec = backend
                .execute_sddmm(&operand, &u, &v, *k)
                .expect("profiled sddmm execute");
            std::hint::black_box(&exec.values);
        });
        seconds[i] = (*k, stats.median_s().max(1e-9));
    }
    let best = seconds
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
    Ok(OracleProfile { best, seconds })
}

/// Build measured SDDMM calibration samples over `matrices × d_values`
/// (the sample's `n` field carries `d`); consumed by
/// [`super::sddmm::calibrate_sddmm`]. Empty matrices are skipped.
pub fn collect_sddmm_samples(
    matrices: &[CsrMatrix],
    d_values: &[usize],
    backend: &dyn SpmmBackend,
    cfg: &MeasureConfig,
) -> Result<Vec<Sample>> {
    let mut out = Vec::with_capacity(matrices.len() * d_values.len());
    for a in matrices {
        if a.nnz() == 0 || a.rows == 0 {
            continue;
        }
        let features = MatrixFeatures::of(a);
        for &d in d_values {
            out.push(Sample {
                features,
                n: d,
                profile: profile_measured_sddmm(backend, a, d, cfg)?,
            });
        }
    }
    Ok(out)
}

/// Build measured calibration samples over `matrices × n_values` —
/// drop-in replacement for [`super::calibrate::collect_samples`] with
/// wallclock in place of the simulator. Empty matrices are skipped (they
/// have no kernel-choice consequence and cannot be timed meaningfully).
pub fn collect_samples(
    matrices: &[CsrMatrix],
    n_values: &[usize],
    backend: &dyn SpmmBackend,
    cfg: &MeasureConfig,
) -> Result<Vec<Sample>> {
    let mut out = Vec::with_capacity(matrices.len() * n_values.len());
    for a in matrices {
        if a.nnz() == 0 || a.rows == 0 {
            continue;
        }
        let features = MatrixFeatures::of(a);
        for &n in n_values {
            out.push(Sample {
                features,
                n,
                profile: profile_measured(backend, a, n, cfg)?,
            });
        }
    }
    Ok(out)
}

/// Outcome of a budgeted [`tune_variants`] run: the per-`(op, bucket,
/// family)` winners plus how many `(variant × round)` cells were timed.
#[derive(Debug)]
pub struct TuneReport {
    /// Cheapest measured variant per `(op, bucket, family)`, sorted for
    /// stable output. Ready for
    /// [`super::profile::HardwareProfile::with_variants`].
    pub winners: Vec<ProfileVariant>,
    /// Total timed measurement cells across every halving round.
    pub cells_timed: usize,
}

impl TuneReport {
    /// Winners that are *not* the family's canonical point — the count
    /// that tells a tuning run whether it found anything the fixed
    /// four-kernel default would miss.
    pub fn non_canonical(&self) -> usize {
        let reg = registry();
        self.winners
            .iter()
            .filter(|w| {
                reg.by_label(w.op, &w.label)
                    .is_some_and(|e| !e.variant.is_canonical())
            })
            .count()
    }
}

/// Successive halving over one family's variants: each round times every
/// surviving candidate on a `budget / (2 · survivors)` slice and keeps
/// the cheaper half, then the finalist gets a half-budget confirmation
/// run. Total spend per family is roughly `(rounds + 1) / 2 ×
/// cfg.measure` — sub-linear in the variant count, which is the point:
/// the budget buys depth on the contenders instead of breadth on losers.
fn halve_family(
    cfg: &MeasureConfig,
    mut candidates: Vec<&'static VariantEntry>,
    mut time_cell: impl FnMut(&'static VariantEntry, BenchConfig) -> Result<f64>,
    cells: &mut usize,
) -> Result<Option<(&'static VariantEntry, f64)>> {
    if candidates.is_empty() {
        return Ok(None);
    }
    while candidates.len() > 1 {
        let share = 2 * candidates.len() as u32;
        let round_cfg = BenchConfig {
            warmup: cfg.warmup / share,
            measure: cfg.measure / share,
            min_iters: cfg.min_iters,
            max_iters: cfg.max_iters,
        };
        let mut scored: Vec<(&'static VariantEntry, f64)> = Vec::new();
        for e in candidates {
            let sec = time_cell(e, round_cfg)?;
            *cells += 1;
            scored.push((e, sec));
        }
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        scored.truncate(scored.len().div_ceil(2));
        candidates = scored.into_iter().map(|(e, _)| e).collect();
    }
    let finalist = candidates[0];
    let final_cfg = BenchConfig {
        warmup: cfg.warmup / 2,
        measure: cfg.measure / 2,
        min_iters: cfg.min_iters,
        max_iters: cfg.max_iters,
    };
    let sec = time_cell(finalist, final_cfg)?;
    *cells += 1;
    Ok(Some((finalist, sec)))
}

/// Budgeted variant search over `matrices × n_values` (SpMM) and
/// `matrices × d_values` (SDDMM): for every cost bucket the workloads
/// touch and every kernel family, run successive halving over the
/// family's generated variants and keep the cheapest (normalized to
/// seconds per flop, so workloads sharing a bucket merge by `min`).
/// Backend constraint as for [`profile_measured`]: only profile through
/// backends that honor the explicit variant. Empty matrices are skipped.
pub fn tune_variants(
    backend: &dyn SpmmBackend,
    matrices: &[CsrMatrix],
    n_values: &[usize],
    d_values: &[usize],
    cfg: &MeasureConfig,
) -> Result<TuneReport> {
    let reg = registry();
    let mut best: HashMap<(SparseOp, usize, KernelKind), (String, f64)> = HashMap::new();
    let mut cells = 0usize;
    let mut rng = Xoshiro256::seeded(cfg.seed);
    for a in matrices {
        if a.nnz() == 0 || a.rows == 0 {
            continue;
        }
        let features = MatrixFeatures::of(a);
        let operand = backend.prepare(a)?;
        for &n in n_values {
            let n = n.max(1);
            let x = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
            let bucket = feature_bucket(&features, n);
            let flops = (2.0 * a.nnz() as f64 * n as f64).max(1.0);
            for family in KernelKind::ALL {
                let won = halve_family(
                    cfg,
                    reg.family_variants(SparseOp::Spmm, family),
                    |entry, bc| {
                        // fail fast (and untimed) if the cell cannot run
                        backend.execute_variant(&operand, &x, entry)?;
                        let stats = bench_fn_with(entry.label, bc, || {
                            let exec = backend
                                .execute_variant(&operand, &x, entry)
                                .expect("tuned execute");
                            std::hint::black_box(&exec.y.data);
                        });
                        Ok(stats.median_s().max(1e-9))
                    },
                    &mut cells,
                )?;
                if let Some((entry, sec)) = won {
                    let cost = sec / flops;
                    let slot = best
                        .entry((SparseOp::Spmm, bucket, family))
                        .or_insert_with(|| (entry.label.to_string(), cost));
                    if cost < slot.1 {
                        *slot = (entry.label.to_string(), cost);
                    }
                }
            }
        }
        for &d in d_values {
            let d = d.max(1);
            let u = DenseMatrix::random(a.rows, d, 1.0, &mut rng);
            let v = DenseMatrix::random(a.cols, d, 1.0, &mut rng);
            let bucket = sddmm_bucket(&features);
            let flops = (2.0 * a.nnz() as f64 * d as f64).max(1.0);
            for family in KernelKind::ALL {
                let won = halve_family(
                    cfg,
                    reg.family_variants(SparseOp::Sddmm, family),
                    |entry, bc| {
                        backend.execute_sddmm_variant(&operand, &u, &v, entry)?;
                        let stats = bench_fn_with(entry.label, bc, || {
                            let exec = backend
                                .execute_sddmm_variant(&operand, &u, &v, entry)
                                .expect("tuned sddmm execute");
                            std::hint::black_box(&exec.values);
                        });
                        Ok(stats.median_s().max(1e-9))
                    },
                    &mut cells,
                )?;
                if let Some((entry, sec)) = won {
                    let cost = sec / flops;
                    let slot = best
                        .entry((SparseOp::Sddmm, bucket, family))
                        .or_insert_with(|| (entry.label.to_string(), cost));
                    if cost < slot.1 {
                        *slot = (entry.label.to_string(), cost);
                    }
                }
            }
        }
    }
    let mut winners: Vec<ProfileVariant> = best
        .into_iter()
        .map(|((op, bucket, family), (label, cost))| ProfileVariant {
            op,
            bucket,
            family,
            label,
            cost,
        })
        .collect();
    winners.sort_by(|a, b| {
        (a.op.label(), a.bucket, a.family.label()).cmp(&(b.op.label(), b.bucket, b.family.label()))
    });
    Ok(TuneReport {
        winners,
        cells_timed: cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::selector::calibrate;
    use crate::sparse::CooMatrix;

    fn tiny_cfg() -> MeasureConfig {
        MeasureConfig {
            warmup: Duration::from_micros(200),
            measure: Duration::from_millis(2),
            min_iters: 2,
            max_iters: 20,
            seed: 11,
        }
    }

    fn small(seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256::seeded(seed);
        CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 100, 0.05, &mut rng))
    }

    #[test]
    fn measured_profile_is_positive_and_consistent() {
        let backend = NativeBackend::serial();
        let p = profile_measured(&backend, &small(21), 4, &tiny_cfg()).unwrap();
        for k in KernelKind::ALL {
            assert!(p.time_of(k) > 0.0, "{k:?}");
            assert!(p.loss_of(k) >= 0.0, "{k:?}");
        }
        assert_eq!(p.loss_of(p.best), 0.0);
    }

    #[test]
    fn empty_matrices_are_rejected_or_skipped() {
        let backend = NativeBackend::serial();
        let empty = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        assert!(profile_measured(&backend, &empty, 1, &tiny_cfg()).is_err());
        let matrices = [empty, small(22)];
        let samples = collect_samples(&matrices, &[1, 8], &backend, &tiny_cfg()).unwrap();
        assert_eq!(samples.len(), 2, "only the non-empty matrix is sampled");
    }

    #[test]
    fn calibrate_runs_unchanged_on_measured_samples() {
        let backend = NativeBackend::serial();
        let samples = collect_samples(&[small(23)], &[1, 32], &backend, &tiny_cfg()).unwrap();
        let cal = calibrate::calibrate(&samples);
        assert!(cal.mean_loss >= 1.0);
        assert_eq!(
            cal.grid.len(),
            calibrate::T_AVG_GRID.len() * calibrate::T_CV_GRID.len()
        );
        // the returned thresholds are no worse than any grid point
        for &(_, _, loss) in &cal.grid {
            assert!(cal.mean_loss <= loss + 1e-12);
        }
    }

    #[test]
    fn measured_sddmm_profile_feeds_the_sddmm_fit() {
        use crate::selector::sddmm::{calibrate_sddmm, sddmm_selector_loss, SddmmSelector};
        let backend = NativeBackend::serial();
        let empty = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        assert!(profile_measured_sddmm(&backend, &empty, 8, &tiny_cfg()).is_err());
        let p = profile_measured_sddmm(&backend, &small(24), 8, &tiny_cfg()).unwrap();
        for k in KernelKind::ALL {
            assert!(p.time_of(k) > 0.0, "{k:?}");
        }
        assert_eq!(p.loss_of(p.best), 0.0);
        let samples =
            collect_sddmm_samples(&[empty, small(25)], &[4, 32], &backend, &tiny_cfg()).unwrap();
        assert_eq!(samples.len(), 2, "only the non-empty matrix is sampled");
        let cal = calibrate_sddmm(&samples);
        assert!(cal.mean_loss >= 1.0);
        assert!(
            cal.mean_loss <= sddmm_selector_loss(&SddmmSelector::default(), &samples) + 1e-12
        );
    }

    #[test]
    fn tune_variants_covers_both_ops_with_resolvable_winners() {
        let backend = NativeBackend::serial();
        let report = tune_variants(&backend, &[small(31)], &[8], &[8], &tiny_cfg()).unwrap();
        // one bucket per op × four families
        assert_eq!(report.winners.len(), 8, "{:?}", report.winners);
        let reg = registry();
        for w in &report.winners {
            let entry = reg.by_label(w.op, &w.label).expect("winner label resolves");
            assert_eq!(entry.variant.family, w.family);
            assert!(w.cost > 0.0 && w.cost.is_finite(), "{w:?}");
            let limit = match w.op {
                SparseOp::Spmm => crate::coordinator::metrics::COST_BUCKETS,
                SparseOp::Sddmm => crate::selector::online::SDDMM_BUCKETS,
            };
            assert!(w.bucket < limit, "{w:?}");
        }
        // the halving ladder times losers on small slices before the
        // finalist's confirmation run: more cells than winners
        assert!(report.cells_timed > report.winners.len());
        assert!(report.non_canonical() <= report.winners.len());
        // winners are unique per (op, bucket, family) and sorted
        let keys: Vec<_> = report
            .winners
            .iter()
            .map(|w| (w.op.label(), w.bucket, w.family.label()))
            .collect();
        let mut deduped = keys.clone();
        deduped.dedup();
        assert_eq!(deduped, keys);
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(sorted, keys);
        // empty matrices contribute nothing
        let empty = CsrMatrix::from_coo(&CooMatrix::new(4, 4));
        let none = tune_variants(&backend, &[empty], &[8], &[8], &tiny_cfg()).unwrap();
        assert!(none.winners.is_empty());
        assert_eq!(none.cells_timed, 0);
    }

    #[test]
    fn budget_scaling() {
        let cfg = MeasureConfig::default().with_budget_ms(8);
        assert_eq!(cfg.measure, Duration::from_millis(8));
        assert_eq!(cfg.warmup, Duration::from_millis(2));
        let floor = MeasureConfig::default().with_budget_ms(0);
        assert_eq!(cfg.min_iters, MeasureConfig::default().min_iters);
        assert!(floor.measure >= Duration::from_millis(1));
    }
}
