//! SDDMM kernel selection — the Fig.-4 methodology applied to the second
//! sparse op.
//!
//! The SpMM rules don't transfer unchanged, because SDDMM's structure
//! differs on both axes (the same reasoning that makes the paper split
//! its features between SpMV and SpMM):
//!
//! - **Reduction family.** SpMM's reduction axis is the row's non-zero
//!   stream, so the dense width N decides between PR and SR (Insight 1).
//!   SDDMM's reduction axis is the *dot length* `d`, shared by every
//!   non-zero — so `d` takes N's place: lane-parallel dots
//!   ([`crate::sddmm::pr_rs`]/[`pr_wb`](crate::sddmm::pr_wb)) only pay
//!   when `d` fills the lanes ([`SddmmSelector::d_threshold`],
//!   structurally `WARP` — below it, lanes idle exactly like PR lanes on
//!   short SpMM rows).
//! - **Balance sensitivity.** In SpMM, a large per-row workload partially
//!   hides imbalance behind dense-row reuse (Insight 3), which is why the
//!   SpMM threshold on `stdv/avg` is a lenient 1.5. SDDMM has no such
//!   cushion: per-nnz cost is exactly `d` multiply-adds, so row-split
//!   runtime is *proportional* to the worker's nnz share and nnz-split
//!   balances it exactly. The default [`SddmmSelector::t_cv`] is
//!   therefore much tighter (0.5).
//!
//! [`calibrate_sddmm`] reproduces the paper's empirical-threshold fit for
//! the new op over measured profiles
//! ([`super::measured::collect_sddmm_samples`]), and
//! [`super::online::OnlineSelector`] keeps refining `t_cv` under live
//! traffic.

use super::calibrate::Sample;
use crate::features::MatrixFeatures;
use crate::kernels::{KernelKind, WARP};
use crate::util::stats;

/// Rule-based SDDMM selector: `d` picks the dot family, row-length skew
/// picks the partitioning.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SddmmSelector {
    /// Dot length at or above which lane-parallel dots are used
    /// (structurally `WARP`: where a window first fills the lanes).
    pub d_threshold: usize,
    /// Use nnz-balanced partitioning when `stdv_row/avg_row` exceeds
    /// this. Tighter than SpMM's `T_cv` — see the module docs.
    pub t_cv: f64,
}

impl Default for SddmmSelector {
    fn default() -> Self {
        Self {
            d_threshold: WARP,
            t_cv: 0.5,
        }
    }
}

/// Candidate grid for the balance threshold (same span as the SpMM grid —
/// the metric is the same `stdv/avg` statistic).
pub const SDDMM_T_CV_GRID: [f64; 6] = [0.25, 0.5, 1.0, 1.5, 2.5, 4.0];

impl SddmmSelector {
    /// Pick a kernel for a matrix with features `f` at dot length `d`.
    pub fn select(&self, f: &MatrixFeatures, d: usize) -> KernelKind {
        let balanced = f.cv_row > self.t_cv;
        if d.max(1) >= self.d_threshold {
            if balanced {
                KernelKind::PrWb
            } else {
                KernelKind::PrRs
            }
        } else if balanced {
            KernelKind::SrWb
        } else {
            KernelKind::SrRs
        }
    }

    /// [`SddmmSelector::select`] plus the audit trail: thresholds
    /// consulted and the rule that fired (see
    /// [`super::rules::Decision`]).
    pub fn decide(&self, f: &MatrixFeatures, d: usize) -> super::rules::Decision {
        let kernel = self.select(f, d);
        let family = if d.max(1) >= self.d_threshold {
            format!("d={d} >= t_d (lane-parallel dots)")
        } else {
            format!("d={d} < t_d (sequential dots)")
        };
        let rule = format!(
            "{family} and cv_row={:.2} {} t_cv -> {}",
            f.cv_row,
            if f.cv_row > self.t_cv { ">" } else { "<=" },
            kernel.label()
        );
        super::rules::Decision {
            kernel,
            thresholds: vec![("t_d", self.d_threshold as f64), ("t_cv", self.t_cv)],
            rule,
        }
    }

    /// One decision per shard feature set — the per-shard grain of
    /// `crate::shard::ShardedBackend::execute_sddmm`.
    pub fn select_shards(&self, shards: &[MatrixFeatures], d: usize) -> Vec<KernelKind> {
        shards.iter().map(|f| self.select(f, d)).collect()
    }

    /// Human-readable explanation of a decision (used by the CLI).
    pub fn explain(&self, f: &MatrixFeatures, d: usize) -> String {
        let k = self.select(f, d);
        let family = if d.max(1) >= self.d_threshold {
            format!("d={d} ≥ {} → lane-parallel dots", self.d_threshold)
        } else {
            format!("d={d} < {} → sequential dots", self.d_threshold)
        };
        format!(
            "{family}; stdv/avg={:.2} {} T_cv={:.2} ⇒ {}",
            f.cv_row,
            if f.cv_row > self.t_cv { ">" } else { "≤" },
            self.t_cv,
            k.label()
        )
    }
}

/// SDDMM calibration outcome.
#[derive(Clone, Debug)]
pub struct SddmmCalibration {
    /// The fitted selector.
    pub selector: SddmmSelector,
    /// Geometric-mean slowdown vs the oracle at the fitted threshold.
    pub mean_loss: f64,
}

/// Geometric-mean slowdown of `sel` over SDDMM samples (each sample's
/// `n` field carries the dot length `d`).
pub fn sddmm_selector_loss(sel: &SddmmSelector, samples: &[Sample]) -> f64 {
    let ratios: Vec<f64> = samples
        .iter()
        .map(|s| {
            let k = sel.select(&s.features, s.n);
            s.profile.time_of(k) / s.profile.best_time()
        })
        .collect();
    stats::geomean(&ratios)
}

/// Grid-search `t_cv` against measured SDDMM profiles; `d_threshold`
/// stays at the structural `WARP` (it marks where a dot window first
/// fills the lanes, not an empirical trade-off — the SDDMM analogue of
/// keeping `n_threshold` at the paper's 4).
pub fn calibrate_sddmm(samples: &[Sample]) -> SddmmCalibration {
    let mut best = SddmmSelector::default();
    let mut best_loss = sddmm_selector_loss(&best, samples);
    for &t_cv in &SDDMM_T_CV_GRID {
        let cand = SddmmSelector {
            t_cv,
            ..SddmmSelector::default()
        };
        let loss = sddmm_selector_loss(&cand, samples);
        if loss < best_loss {
            best_loss = loss;
            best = cand;
        }
    }
    SddmmCalibration {
        selector: best,
        mean_loss: best_loss,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selector::oracle::OracleProfile;

    fn features(avg_row: f64, cv_row: f64) -> MatrixFeatures {
        MatrixFeatures {
            rows: 1000,
            cols: 1000,
            nnz: (avg_row * 1000.0) as usize,
            avg_row,
            stdv_row: avg_row * cv_row,
            cv_row,
            max_row: 100,
            empty_frac: 0.0,
            gini_row: 0.0,
        }
    }

    #[test]
    fn d_picks_the_dot_family() {
        let sel = SddmmSelector::default();
        let flat = features(16.0, 0.2);
        for d in [0usize, 1, 4, 31] {
            assert!(!sel.select(&flat, d).is_parallel_reduction(), "d={d}");
        }
        for d in [32usize, 64, 256] {
            assert!(sel.select(&flat, d).is_parallel_reduction(), "d={d}");
        }
    }

    #[test]
    fn skew_picks_balancing_at_a_tighter_threshold() {
        let sel = SddmmSelector::default();
        // cv = 0.8 balances here but would NOT under SpMM's default 1.5
        let skewed = features(8.0, 0.8);
        assert_eq!(sel.select(&skewed, 8), KernelKind::SrWb);
        assert_eq!(sel.select(&skewed, 64), KernelKind::PrWb);
        let flat = features(8.0, 0.3);
        assert_eq!(sel.select(&flat, 8), KernelKind::SrRs);
        assert_eq!(sel.select(&flat, 64), KernelKind::PrRs);
    }

    #[test]
    fn shard_selection_diverges() {
        let sel = SddmmSelector::default();
        assert_eq!(
            sel.select_shards(&[features(8.0, 2.0), features(8.0, 0.1)], 64),
            vec![KernelKind::PrWb, KernelKind::PrRs]
        );
        assert!(sel.select_shards(&[], 1).is_empty());
    }

    #[test]
    fn decide_reproduces_select_and_names_thresholds() {
        let sel = SddmmSelector::default();
        for (f, d) in [
            (features(8.0, 2.0), 4usize),
            (features(8.0, 0.1), 64),
            (features(16.0, 0.8), 32),
        ] {
            let dec = sel.decide(&f, d);
            assert_eq!(dec.kernel, sel.select(&f, d));
            assert!(dec.rule.contains(dec.kernel.label()), "{}", dec.rule);
            assert_eq!(dec.thresholds[0], ("t_d", WARP as f64));
            assert_eq!(dec.thresholds[1], ("t_cv", sel.t_cv));
        }
    }

    #[test]
    fn explain_names_both_axes() {
        let sel = SddmmSelector::default();
        let e = sel.explain(&features(8.0, 2.0), 4);
        assert!(e.contains("sequential"), "{e}");
        assert!(e.contains("sr_wb"), "{e}");
    }

    #[test]
    fn calibration_fits_the_grid_argmin() {
        // synthetic profiles where WB is 4x faster on the skewed half
        // even at cv = 0.3: the fit must tighten t_cv to the grid minimum
        let mk = |cv: f64, wb_fast: bool| {
            let slow = 4e-4;
            let fast = 1e-4;
            let t = |balanced: bool| if balanced == wb_fast { fast } else { slow };
            Sample {
                features: features(8.0, cv),
                n: 8,
                profile: OracleProfile {
                    best: if wb_fast {
                        KernelKind::SrWb
                    } else {
                        KernelKind::SrRs
                    },
                    seconds: [
                        (KernelKind::SrRs, t(false)),
                        (KernelKind::SrWb, t(true)),
                        (KernelKind::PrRs, t(false)),
                        (KernelKind::PrWb, t(true)),
                    ],
                },
            }
        };
        let samples = vec![mk(0.3, true), mk(0.4, true), mk(0.1, false)];
        let cal = calibrate_sddmm(&samples);
        assert_eq!(cal.selector.t_cv, 0.25, "{:?}", cal.selector);
        assert!(cal.mean_loss < sddmm_selector_loss(&SddmmSelector::default(), &samples));
        assert_eq!(cal.selector.d_threshold, WARP, "structural axis untouched");
    }
}
