// `std::simd` backends for kernels::vec8 — nightly-only, advisory CI
// job; the stable `simd` feature uses hand-tiled blocks instead.
#![cfg_attr(feature = "portable_simd", feature(portable_simd))]

//! # ge-spmm — adaptive workload-balanced / parallel-reduction sparse kernels
//!
//! Reproduction of *"Efficient Sparse Matrix Kernels based on Adaptive
//! Workload-Balancing and Parallel-Reduction"* (Huang et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (build time, Python): the paper's four kernel designs as
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! - **Layer 2** (build time, Python): a GCN forward/backward in JAX calling
//!   the Layer-1 kernels.
//! - **Layer 3** (this crate): the coordinator — sparse formats, feature
//!   extraction, the adaptive kernel selector, pluggable execution
//!   backends behind the [`backend::SpmmBackend`] trait, native CPU
//!   kernel ports, and a GPU cost simulator that regenerates the paper's
//!   evaluation figures.
//!
//! Execution is backend-agnostic: [`backend::NativeBackend`] (the CPU
//! kernel ports, always available, the default), [`backend::ShardedBackend`]
//! (nnz-balanced row fan-out with per-shard adaptive selection),
//! [`backend::RoutedBackend`] (registration-time size routing between the
//! two), and `backend::PjrtBackend` (the PJRT runtime executing the AOT
//! artifacts, behind the `pjrt` cargo feature — off by default because it
//! needs libxla). The `runtime` module and the artifact packing/training
//! paths are gated with it.
//!
//! The engine executes **two sparse ops** over one prepared-matrix state:
//! SpMM (`Y = A·X`) and, since the [`sddmm`] subsystem, SDDMM
//! (`S = sample(A, U·Vᵀ)`) — the FusedMM pair behind attention-style
//! GNNs. [`gnn::attention`] runs the fused SDDMM→softmax→SpMM dataflow
//! end to end through the engine on the default native build.
//!
//! On top sits the [`coordinator`] serving layer: a prepared-matrix cache
//! (content-fingerprinted, byte-budgeted LRU) and a multi-worker server
//! with per-matrix request routing, width batching, an admission bound
//! and graceful shutdown — `ge-spmm serve` drives it from the CLI.
//!
//! The whole request path is observable through the [`obs`] subsystem:
//! per-request span traces into a flight-recorder ring, lock-free
//! log-bucketed latency histograms behind every quantile in
//! `coordinator::Metrics`, a replayable selector decision audit, and
//! Prometheus/JSON exposition (`ge-spmm stats`,
//! `ge-spmm serve --stats-file`). See `DESIGN.md` §Observability.
//!
//! The native kernels' inner loops run through the [`kernels::vec8`]
//! microkernel layer: scalar by default, explicitly 8-lane tiled under
//! the `simd` cargo feature (stable), or `std::simd` under
//! `portable_simd` (nightly). The SR kernels additionally support a
//! merge-path row traversal for extreme skew, selected per matrix (or
//! per shard) by [`backend::TraversalMode`]. See `DESIGN.md`
//! §Vectorization.
//!
//! See `DESIGN.md` for the full system inventory and the experiment
//! index, and `BENCHMARKS.md` for the bench harness and the recording
//! convention.
//!
//! ## Quick start
//!
//! ```no_run
//! use ge_spmm::sparse::CsrMatrix;
//! use ge_spmm::gen::rmat::RmatConfig;
//! use ge_spmm::features::MatrixFeatures;
//! use ge_spmm::selector::{AdaptiveSelector, KernelKind};
//!
//! // Generate a power-law matrix, extract features, pick a kernel.
//! let mut rng = ge_spmm::util::prng::Xoshiro256::seeded(42);
//! let coo = RmatConfig::new(12, 8.0).generate(&mut rng);
//! let csr = CsrMatrix::from_coo(&coo);
//! let feats = MatrixFeatures::of(&csr);
//! let kernel = AdaptiveSelector::default().select(&feats, /*n=*/ 32);
//! assert!(matches!(kernel, KernelKind::SrRs | KernelKind::SrWb));
//! ```

pub mod backend;
pub mod bench;
pub mod coordinator;
pub mod features;
pub mod gen;
pub mod gnn;
pub mod kernels;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sddmm;
pub mod selector;
pub mod shard;
pub mod sim;
pub mod sparse;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
