//! # ge-spmm — adaptive workload-balanced / parallel-reduction sparse kernels
//!
//! Reproduction of *"Efficient Sparse Matrix Kernels based on Adaptive
//! Workload-Balancing and Parallel-Reduction"* (Huang et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (build time, Python): the paper's four kernel designs as
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! - **Layer 2** (build time, Python): a GCN forward/backward in JAX calling
//!   the Layer-1 kernels.
//! - **Layer 3** (this crate): the coordinator — sparse formats, feature
//!   extraction, the adaptive kernel selector, a PJRT runtime that executes
//!   the AOT artifacts, native CPU reference kernels, and a GPU cost
//!   simulator that regenerates the paper's evaluation figures.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.
//!
//! ## Quick start
//!
//! ```no_run
//! use ge_spmm::sparse::CsrMatrix;
//! use ge_spmm::gen::rmat::RmatConfig;
//! use ge_spmm::features::MatrixFeatures;
//! use ge_spmm::selector::{AdaptiveSelector, KernelKind};
//!
//! // Generate a power-law matrix, extract features, pick a kernel.
//! let mut rng = ge_spmm::util::prng::Xoshiro256::seeded(42);
//! let coo = RmatConfig::new(12, 8.0).generate(&mut rng);
//! let csr = CsrMatrix::from_coo(&coo);
//! let feats = MatrixFeatures::of(&csr);
//! let kernel = AdaptiveSelector::default().select(&feats, /*n=*/ 32);
//! assert!(matches!(kernel, KernelKind::SrRs | KernelKind::SrWb));
//! ```

pub mod bench;
pub mod coordinator;
pub mod features;
pub mod gen;
pub mod gnn;
pub mod kernels;
pub mod runtime;
pub mod selector;
pub mod sim;
pub mod sparse;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
