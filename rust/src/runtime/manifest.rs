//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! and the Rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// SpMM-specific: kernel variant label (sr_rs / sr_wb / pr_rs / pr_wb)
    pub variant: Option<String>,
    /// SpMM-specific: bucket name and dense width
    pub bucket: Option<String>,
    pub n: Option<usize>,
    /// bucket parameters (m_pad, k, width, nseg, seg_len) / GCN dims
    pub params: std::collections::BTreeMap<String, usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Bucket parameter accessor.
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (unit-testable without files).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_str = |k: &str| a.get(k).and_then(Json::as_str).map(|s| s.to_string());
            let name = get_str("name").ok_or_else(|| anyhow!("artifact missing name"))?;
            let file = get_str("file").ok_or_else(|| anyhow!("artifact missing file"))?;
            let kind = get_str("kind").ok_or_else(|| anyhow!("artifact missing kind"))?;
            let mut params = std::collections::BTreeMap::new();
            if let Some(p) = a.get("params").and_then(Json::as_obj) {
                for (k, v) in p {
                    if let Some(u) = v.as_usize() {
                        params.insert(k.clone(), u);
                    }
                }
            }
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                n: a.get("n").and_then(Json::as_usize),
                variant: get_str("variant"),
                bucket: get_str("bucket"),
                inputs: parse_tensors("inputs")?,
                outputs: parse_tensors("outputs")?,
                name,
                file,
                kind,
                params,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Find an artifact by name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All SpMM artifacts for a kernel variant, sorted by (bucket size, n).
    pub fn spmm_variants(&self, variant: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "spmm" && a.variant.as_deref() == Some(variant))
            .collect();
        v.sort_by_key(|a| (a.param("m_pad").unwrap_or(0), a.n.unwrap_or(0)));
        v
    }

    /// Select the smallest SpMM bucket fitting (rows, cols, width/segments)
    /// at dense width `n`.
    pub fn route_spmm(
        &self,
        variant: &str,
        n: usize,
        rows: usize,
        cols: usize,
        ell_width: usize,
        num_segments: usize,
    ) -> Option<&ArtifactSpec> {
        self.spmm_variants(variant)
            .into_iter()
            .filter(|a| a.n == Some(n))
            .find(|a| {
                let m_ok = a.param("m_pad").is_some_and(|m| rows <= m);
                let k_ok = a.param("k").is_some_and(|k| cols <= k);
                let fits = if variant.ends_with("_rs") {
                    a.param("width").is_some_and(|w| ell_width <= w)
                } else {
                    a.param("nseg").is_some_and(|s| num_segments <= s)
                };
                m_ok && k_ok && fits
            })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "spmm_sr_rs_s_n4", "file": "a.hlo.txt", "kind": "spmm",
         "variant": "sr_rs", "bucket": "s", "n": 4,
         "params": {"m_pad": 512, "k": 512, "width": 32, "nseg": 512, "seg_len": 32},
         "inputs": [{"name": "a_values", "shape": [512, 32], "dtype": "f32"}],
         "outputs": [{"name": "y", "shape": [512, 4], "dtype": "f32"}]},
        {"name": "spmm_sr_rs_m_n4", "file": "b.hlo.txt", "kind": "spmm",
         "variant": "sr_rs", "bucket": "m", "n": 4,
         "params": {"m_pad": 4096, "k": 4096, "width": 64, "nseg": 4096, "seg_len": 32},
         "inputs": [{"name": "a_values", "shape": [4096, 64], "dtype": "f32"}],
         "outputs": [{"name": "y", "shape": [4096, 4], "dtype": "f32"}]},
        {"name": "gcn_step", "file": "g.hlo.txt", "kind": "gcn_step",
         "params": {"nodes": 2752},
         "inputs": [{"name": "w1", "shape": [64, 32], "dtype": "f32"}],
         "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.by_name("spmm_sr_rs_s_n4").unwrap();
        assert_eq!(a.param("m_pad"), Some(512));
        assert_eq!(a.inputs[0].elements(), 512 * 32);
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn routing_picks_smallest_fitting_bucket() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let small = m.route_spmm("sr_rs", 4, 300, 300, 16, 100).unwrap();
        assert_eq!(small.bucket.as_deref(), Some("s"));
        let big = m.route_spmm("sr_rs", 4, 2000, 2000, 48, 100).unwrap();
        assert_eq!(big.bucket.as_deref(), Some("m"));
        // too wide for any bucket
        assert!(m.route_spmm("sr_rs", 4, 300, 300, 100, 100).is_none());
        // wrong n
        assert!(m.route_spmm("sr_rs", 8, 300, 300, 16, 100).is_none());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"artifacts": []}"#).is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    #[test]
    fn variants_sorted_by_bucket() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let v = m.spmm_variants("sr_rs");
        assert_eq!(v.len(), 2);
        assert!(v[0].param("m_pad").unwrap() < v[1].param("m_pad").unwrap());
    }
}
