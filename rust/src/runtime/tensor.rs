//! Host tensors and their conversion to/from PJRT literals.

use anyhow::{bail, Result};

/// A host-side tensor: f32 or i32 data plus a shape. This is what the
//  coordinator builds from sparse formats and dense operands.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    /// f32 tensor with shape validation.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape, data }
    }

    /// i32 tensor with shape validation.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape, data }
    }

    /// Scalar f32.
    pub fn scalar(v: f32) -> Self {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    /// dtype label matching the manifest ("f32"/"i32").
    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
        }
    }

    /// f32 data (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a PJRT literal back into a host tensor (f32 only — all our
    /// artifact outputs are f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::f32(dims, data))
    }

    /// Check this tensor against a manifest spec.
    pub fn matches(&self, spec: &super::manifest::TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input '{}': shape {:?} != expected {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!(
                "input '{}': dtype {} != expected {}",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_dtype() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), "f32");
        assert!(t.as_f32().is_ok());
        let i = Tensor::i32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(i.dtype(), "i32");
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn bad_shape_panics() {
        Tensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn spec_matching() {
        use crate::runtime::manifest::TensorSpec;
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: "f32".into(),
        };
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).matches(&spec).is_ok());
        assert!(Tensor::f32(vec![3, 2], vec![0.0; 6]).matches(&spec).is_err());
        assert!(Tensor::i32(vec![2, 3], vec![0; 6]).matches(&spec).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
