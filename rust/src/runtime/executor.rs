//! The PJRT execution engine: compile cache + typed execute.
//!
//! Wraps the `xla` crate exactly as the reference loader does
//! (/opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are compiled lazily on first use and cached for the process
//! lifetime (compilation is milliseconds-to-seconds; execution is the hot
//! path).

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with shape-checked inputs; returns the output tensors
    /// (the AOT path lowers with `return_tuple=True`, so the single
    /// result literal is a tuple we decompose).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact {}: {} inputs supplied, {} expected",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            ));
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            t.matches(spec)
                .with_context(|| format!("artifact {}", self.spec.name))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literals(&refs)
    }

    /// Execute with pre-converted literals (the hot path: callers cache
    /// the conversion of operands that repeat across requests, e.g. the
    /// packed sparse planes — see `coordinator::engine`).
    pub fn run_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        if literals.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact {}: {} inputs supplied, {} expected",
                self.spec.name,
                literals.len(),
                self.spec.inputs.len()
            ));
        }
        let result = self.exe.execute::<&xla::Literal>(literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// Process-wide engine: one PJRT client + a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedArtifact>>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedArtifact>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}' in manifest"))?
            .clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        let loaded = std::sync::Arc::new(LoadedArtifact { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Number of artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Convenience: load + run.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run(inputs)
    }
}

// Integration tests that need real artifacts live in rust/tests/.
