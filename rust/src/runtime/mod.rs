//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them from the Rust request path.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! bridge to the compiled kernels: [`manifest`] describes the artifact
//! library, [`executor`] wraps `PjRtClient` → `HloModuleProto::from_text`
//! → compile → execute, and [`tensor`] converts between Rust buffers and
//! PJRT literals.

pub mod executor;
pub mod manifest;
pub mod tensor;

pub use executor::{Engine, LoadedArtifact};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
