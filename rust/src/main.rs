//! `ge-spmm` — the coordinator CLI.
//!
//! Subcommands:
//!   info        print backend/artifact/platform diagnostics
//!   features    print row-length features for a matrix (.mtx or synth:)
//!   select      show the adaptive kernel decision for a matrix and N
//!   spmm        run one SpMM through the coordinator with adaptive routing
//!               (--backend native|pjrt; native is the default)
//!   sddmm       run one SDDMM (S = sample(A, U·Vᵀ)) through the coordinator
//!               with the second-op adaptive rules (native backend;
//!               --shards N for per-shard selection)
//!   churn       replay an R-MAT edge-churn stream through the dynamic
//!               delta path (`apply_delta`: in-place patch or re-prepare,
//!               drift-triggered reselection), verifying every batch
//!               against the serial reference (--shards N for the
//!               sharded path)
//!   serve       drive a synthetic workload through the concurrent serving
//!               layer (worker threads + prepared-matrix cache + size
//!               routing) and report throughput and metrics; `--stats-every`
//!               / `--stats-file` dump live metrics periodically; `--slo`
//!               installs burn-rate monitors on latency/queue objectives
//!   stats       render engine metrics (latency histograms, roofline
//!               workload accounting, selector audit, flight-recorder
//!               traces) as Prometheus text and JSON; `--regret` prints the
//!               selector-regret table, `--format chrome` exports traces as
//!               Chrome trace-event JSON
//!   simulate    run the GPU cost model for all kernels on a matrix
//!   calibrate   fit selector thresholds against simulator profiles
//!   tune        budgeted search over the generated variant registry
//!               (successive halving under --budget-ms); winners land in a
//!               hardware profile that `serve --profile` installs
//!   perfgate    measure normalized kernel/reference latency ratios on a
//!               pinned workload and fail on regression vs a baseline JSON
//!               (exit 3 = VACUOUS: nothing was actually compared)
//!   train-gcn   end-to-end GCN training (needs the `pjrt` feature)
//!   suite       list the synthetic benchmark collection
//!
//! Matrices are given as a path to a MatrixMarket file or a synthetic
//! spec `synth:<name>` from the collection (see `suite`).

use anyhow::{anyhow, bail, Result};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::gen::Collection;
#[cfg(feature = "pjrt")]
use ge_spmm::gnn::{GcnTrainer, GraphConfig, SyntheticGraph};
#[cfg(feature = "pjrt")]
use ge_spmm::runtime::Engine;
use ge_spmm::selector::{calibrate, AdaptiveSelector};
use ge_spmm::sim::{simulate, GpuConfig, SimKernel, SimMatrix};
use ge_spmm::sparse::{mmio, CsrMatrix, DenseMatrix};
use ge_spmm::util::cli::{split_subcommand, Args, CliError, Command};
use ge_spmm::util::prng::Xoshiro256;
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = split_subcommand(argv);
    let code = match run(sub.as_deref(), rest) {
        Ok(()) => 0,
        Err(e) => {
            if let Some(CliError::Help(h)) = e.downcast_ref::<CliError>() {
                println!("{h}");
                0
            } else {
                eprintln!("error: {e:#}");
                1
            }
        }
    };
    std::process::exit(code);
}

fn run(sub: Option<&str>, rest: Vec<String>) -> Result<()> {
    match sub {
        Some("info") => cmd_info(rest),
        Some("features") => cmd_features(rest),
        Some("select") => cmd_select(rest),
        Some("spmm") => cmd_spmm(rest),
        Some("sddmm") => cmd_sddmm(rest),
        Some("churn") => cmd_churn(rest),
        Some("serve") => cmd_serve(rest),
        Some("stats") => cmd_stats(rest),
        Some("simulate") => cmd_simulate(rest),
        Some("calibrate") => cmd_calibrate(rest),
        Some("tune") => cmd_tune(rest),
        Some("perfgate") => cmd_perfgate(rest),
        Some("train-gcn") => cmd_train_gcn(rest),
        Some("suite") => cmd_suite(rest),
        Some(other) => bail!("unknown subcommand '{other}' (try: info, features, select, spmm, sddmm, churn, serve, stats, simulate, calibrate, tune, perfgate, train-gcn, suite)"),
        None => {
            println!(
                "ge-spmm {} — adaptive workload-balanced/parallel-reduction sparse kernels\n\
                 subcommands: info, features, select, spmm, sddmm, churn, serve, stats, simulate, calibrate, tune, perfgate, train-gcn, suite\n\
                 use `ge-spmm <subcommand> --help` for options",
                ge_spmm::version()
            );
            Ok(())
        }
    }
}

/// Load a matrix from a path or a `synth:<name>` collection spec.
fn load_matrix(arg: &str) -> Result<CsrMatrix> {
    if let Some(name) = arg.strip_prefix("synth:") {
        let spec = Collection::suite()
            .into_iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("no synthetic matrix named '{name}' (see `ge-spmm suite`)"))?;
        Ok(spec.build())
    } else {
        Ok(CsrMatrix::from_coo(&mmio::read_matrix_market(Path::new(
            arg,
        ))?))
    }
}

fn matrix_arg(args: &ge_spmm::util::cli::Args) -> Result<String> {
    args.positional()
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("expected a matrix argument (.mtx path or synth:<name>)"))
}

#[cfg(feature = "pjrt")]
fn cmd_info(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("info", "backend, artifact and platform diagnostics")
        .opt("artifacts", "artifact directory", Some("artifacts"));
    let args = cmd.parse(&rest)?;
    println!("backends: native, pjrt");
    let engine = Engine::new(Path::new(args.get_or("artifacts", "artifacts")))?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest.artifacts.len());
    for a in &engine.manifest.artifacts {
        println!(
            "  {:<24} kind={:<9} bucket={:<4} n={:<4} file={}",
            a.name,
            a.kind,
            a.bucket.as_deref().unwrap_or("-"),
            a.n.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
            a.file
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("info", "backend diagnostics");
    let _args = cmd.parse(&rest)?;
    println!("backends: native (pjrt disabled at compile time)");
    println!(
        "artifact diagnostics need the `pjrt` feature — rebuild with \
         `cargo build --features pjrt`"
    );
    Ok(())
}

fn cmd_features(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("features", "row-length features of a matrix");
    let args = cmd.parse(&rest)?;
    let m = load_matrix(&matrix_arg(&args)?)?;
    println!("{}", MatrixFeatures::of(&m).summary());
    Ok(())
}

fn cmd_select(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("select", "show the adaptive kernel decision")
        .opt("n", "dense-matrix width", Some("32"));
    let args = cmd.parse(&rest)?;
    let m = load_matrix(&matrix_arg(&args)?)?;
    let n: usize = args.parse_or("n", 32);
    let f = MatrixFeatures::of(&m);
    let sel = AdaptiveSelector::default();
    println!("{}", f.summary());
    println!("{}", sel.explain(&f, n));
    Ok(())
}

/// Build the engine a CLI command asked for (`--backend native|pjrt`,
/// `--shards N` for nnz-balanced row fan-out on the native backend).
fn build_engine(args: &Args) -> Result<SpmmEngine> {
    let shards = args.parse_positive("shards", 1);
    match args.get_or("backend", "native") {
        "native" if shards > 1 => Ok(SpmmEngine::sharded(shards)),
        "native" => Ok(SpmmEngine::native()),
        "pjrt" if shards > 1 => bail!(
            "--shards is only supported on the native backend (the artifact \
             library is compiled for whole-matrix buckets)"
        ),
        #[cfg(feature = "pjrt")]
        "pjrt" => SpmmEngine::new(Path::new(args.get_or("artifacts", "artifacts"))),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "this build has no PJRT support — rebuild with `cargo build --features pjrt`"
        ),
        other => bail!("unknown backend '{other}' (expected: native, pjrt)"),
    }
}

fn cmd_spmm(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("spmm", "run one SpMM through the coordinator")
        .opt("n", "dense-matrix width", Some("4"))
        .opt("backend", "execution backend: native | pjrt", Some("native"))
        .opt("artifacts", "artifact directory (pjrt backend)", Some("artifacts"))
        .opt("shards", "nnz-balanced row shards, native backend (1 = unsharded)", Some("1"))
        .opt("seed", "dense operand seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let m = load_matrix(&matrix_arg(&args)?)?;
    let n: usize = args.parse_or("n", 4);
    let engine = build_engine(&args)?;
    let h = engine.register(m.clone())?;
    let mut rng = Xoshiro256::seeded(args.parse_or("seed", 42));
    let x = DenseMatrix::random(m.cols, n, 1.0, &mut rng);
    let resp = engine.spmm(h, &x)?;
    println!(
        "backend={} kernel={} artifact={} latency={:?}",
        engine.backend_name(),
        resp.kernel.label(),
        resp.artifact,
        resp.latency
    );
    // cross-check vs the native reference
    let mut want = DenseMatrix::zeros(m.rows, n);
    ge_spmm::kernels::dense::spmm_reference(&m, &x, &mut want);
    let max_err = resp
        .y
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |err| vs native reference: {max_err:.2e}");
    println!("{}", engine.metrics.summary());
    Ok(())
}

fn cmd_sddmm(rest: Vec<String>) -> Result<()> {
    use ge_spmm::selector::SddmmSelector;

    let cmd = Command::new(
        "sddmm",
        "run one SDDMM (S = sample(A, U·Vᵀ)) through the coordinator",
    )
    .opt("d", "dot-product (embedding) width", Some("32"))
    .opt(
        "shards",
        "nnz-balanced row shards with per-shard adaptive selection (1 = unsharded)",
        Some("1"),
    )
    .opt("seed", "dense operand seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let m = load_matrix(&matrix_arg(&args)?)?;
    let d: usize = args.parse_or("d", 32);
    let shards = args.parse_positive("shards", 1);
    let engine = if shards > 1 {
        SpmmEngine::sharded(shards)
    } else {
        SpmmEngine::native()
    };
    let h = engine.register(m.clone())?;
    let mut rng = Xoshiro256::seeded(args.parse_or("seed", 42));
    let u = DenseMatrix::random(m.rows, d, 1.0, &mut rng);
    let v = DenseMatrix::random(m.cols, d, 1.0, &mut rng);
    let f = MatrixFeatures::of(&m);
    println!("{}", f.summary());
    println!("{}", SddmmSelector::default().explain(&f, d));
    let resp = engine.sddmm(h, &u, &v)?;
    println!(
        "backend={} kernel={} artifact={} latency={:?}",
        engine.backend_name(),
        resp.kernel.label(),
        resp.artifact,
        resp.latency
    );
    // cross-check vs the dense reference — and actually fail on mismatch:
    // the SDDMM designs are bit-for-bit equal to the reference by
    // construction, so this command doubles as a CI smoke that bites.
    let mut want = vec![0f32; m.nnz()];
    ge_spmm::kernels::dense::sddmm_reference(&m, &u, &v, &mut want);
    let max_err = resp
        .values
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |err| vs dense reference: {max_err:.2e}");
    anyhow::ensure!(
        max_err == 0.0,
        "SDDMM output diverged from the dense reference (max |err| = {max_err:.2e})"
    );
    println!("{}", engine.metrics.summary());
    Ok(())
}

fn cmd_churn(rest: Vec<String>) -> Result<()> {
    use ge_spmm::gen::rmat::RmatConfig;
    use ge_spmm::gen::{ChurnConfig, ChurnStream};
    use ge_spmm::kernels::dense::spmm_reference;

    let cmd = Command::new(
        "churn",
        "replay an R-MAT edge-churn stream through the dynamic delta path, \
         verifying every batch against the serial reference",
    )
    .opt("batches", "churn batches to replay", Some("32"))
    .opt("scale", "log2 dimension of the R-MAT base matrix", Some("8"))
    .opt("edge-factor", "average nnz per row of the base", Some("8"))
    .opt("inserts", "new edges per batch (R-MAT-skewed)", Some("8"))
    .opt("deletes", "edge removals per batch (uniform over present)", Some("8"))
    .opt("updates", "weight updates per batch (uniform over present)", Some("16"))
    .opt(
        "shards",
        "nnz-balanced row shards (1 = unsharded native + prepared cache)",
        Some("1"),
    )
    .opt("n", "dense width of the per-batch SpMM check", Some("8"))
    .opt("seed", "stream + operand seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let batches = args.parse_positive("batches", 32);
    let shards = args.parse_positive("shards", 1);
    let n = args.parse_positive("n", 8);
    let seed: u64 = args.parse_or("seed", 42);

    let config = ChurnConfig {
        base: RmatConfig::new(args.parse_or("scale", 8), args.parse_or("edge-factor", 8.0)),
        inserts: args.parse_or("inserts", 8),
        deletes: args.parse_or("deletes", 8),
        updates: args.parse_or("updates", 16),
    };
    let mut stream = ChurnStream::new(config, seed);
    let engine = if shards > 1 {
        SpmmEngine::sharded(shards)
    } else {
        SpmmEngine::native().with_prepared_cache(64 << 20)
    };
    let h = engine.register(stream.current().clone())?;
    println!(
        "base: {}x{}, nnz {}  engine: {}{}",
        stream.current().rows,
        stream.current().cols,
        stream.current().nnz(),
        engine.backend_name(),
        if shards > 1 { "" } else { " + prepared cache" }
    );

    let mut rng = Xoshiro256::seeded(seed ^ 0x5bd1e995);
    let (mut patched, mut reprepared, mut drifts) = (0usize, 0usize, 0usize);
    let mut structural_patched = 0usize;
    for b in 0..batches {
        let delta = stream.next_batch();
        let out = engine.apply_delta(h, &delta)?;
        if out.report.touched() > 0 {
            if out.patched {
                patched += 1;
                if out.report.structural {
                    structural_patched += 1;
                }
            } else {
                reprepared += 1;
            }
        }
        if out.drift {
            drifts += 1;
        }
        // verify the patched engine against a from-scratch reference on
        // the stream's ground-truth matrix
        let truth = stream.current();
        let x = DenseMatrix::random(truth.cols, n, 1.0, &mut rng);
        let y = engine.spmm(h, &x)?.y;
        let mut want = DenseMatrix::zeros(truth.rows, n);
        spmm_reference(truth, &x, &mut want);
        let bound = want.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let max_err = y
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        anyhow::ensure!(
            max_err <= 1e-4 * (1.0 + bound),
            "batch {b}: patched SpMM diverged from the rebuilt reference \
             (max |err| = {max_err:.2e})"
        );
    }
    println!(
        "replayed {batches} batches: {patched} patched in place, {reprepared} \
         re-prepared, {drifts} drift-triggered reselections; final nnz {}, epoch {}",
        stream.current().nnz(),
        stream.current().epoch
    );
    if shards > 1 {
        let reused = engine.metrics.shard_operands_reused();
        let redone = engine.metrics.shard_operands_reprepared();
        println!(
            "shard operands across structural batches: {reused} reused \
             (fingerprint match), {redone} re-prepared"
        );
        // The whole point of the fingerprint-gated delta path: a structural
        // batch that was patched in place must not have rebuilt every shard.
        if structural_patched > 0 {
            anyhow::ensure!(
                reused > 0,
                "structural batches were patched in place but every shard \
                 operand was rebuilt every time — partial re-preparation is \
                 not happening"
            );
        }
    }
    if let Some((entries, bytes)) = engine.cache_usage() {
        println!("cache: {entries} prepared matrices resident, {bytes} bytes");
    }
    println!("{}", engine.metrics.summary());
    Ok(())
}

fn cmd_serve(rest: Vec<String>) -> Result<()> {
    use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
    use ge_spmm::sparse::CooMatrix;
    use std::sync::{mpsc, Arc};
    use std::time::{Duration, Instant};

    let cmd = Command::new(
        "serve",
        "drive a synthetic workload through the concurrent serving layer",
    )
    .opt("workers", "server worker threads", Some("4"))
    .opt("producers", "concurrent client threads", Some("4"))
    .opt("requests", "requests per client", Some("64"))
    .opt("matrices", "distinct matrices in the traffic mix", Some("4"))
    .opt("rows", "rows = cols of each synthetic matrix", Some("512"))
    .opt("density", "nnz density of each synthetic matrix", Some("0.02"))
    .opt("n", "dense width per request", Some("8"))
    .opt("max-width", "batcher width bound", Some("128"))
    .opt("max-delay-ms", "partial-batch flush deadline (ms)", Some("2"))
    .opt("max-queue", "admission bound (in-flight requests)", Some("1024"))
    .opt("cache-mb", "prepared-matrix cache budget (MiB)", Some("64"))
    .opt(
        "shard-threshold",
        "nnz at or above which a matrix routes to the sharded backend",
        Some("250000"),
    )
    .opt("shards", "row-shard fan-out for large matrices", Some("4"))
    .opt(
        "profile",
        "hardware-profile JSON with calibrated selector thresholds (default: \
         $GE_SPMM_PROFILE if set; see `calibrate --measured --profile`)",
        None,
    )
    .flag(
        "online",
        "refine selector thresholds online from live request latencies",
    )
    .opt(
        "refit-every",
        "online mode: observations between threshold refits",
        Some("256"),
    )
    .opt(
        "explore-every",
        "online mode: run the sibling kernel every Nth decision (0 = off)",
        Some("16"),
    )
    .opt(
        "stats-file",
        "dump engine metrics to this file (Prometheus text, or a JSON \
         snapshot when the path ends in .json); written once at exit, and \
         periodically with --stats-every",
        None,
    )
    .opt(
        "stats-every",
        "seconds between periodic --stats-file dumps (0 = final dump only)",
        Some("0"),
    )
    .opt(
        "slo",
        "serving objectives to monitor, e.g. p99=2ms,queue=128 \
         (keys: p50/p90/p99 latency, queue depth, window; burn rates land \
         in the stats snapshot and the final health line)",
        None,
    )
    .opt(
        "trace-capacity",
        "flight-recorder ring size (last N request traces retained)",
        Some("64"),
    )
    .opt("seed", "workload seed", Some("42"));
    let args = cmd.parse(&rest)?;

    let producers = args.parse_positive("producers", 4);
    let requests = args.parse_positive("requests", 64);
    let matrices = args.parse_positive("matrices", 4);
    let rows = args.parse_positive("rows", 512);
    let density: f64 = args.parse_or("density", 0.02);
    let n = args.parse_positive("n", 8);
    let seed: u64 = args.parse_or("seed", 42);

    // Selector thresholds: explicit --profile beats $GE_SPMM_PROFILE
    // beats the paper defaults.
    use ge_spmm::selector::{HardwareProfile, OnlineConfig};
    let profile: Option<HardwareProfile> = match args.get("profile") {
        Some(path) => {
            let p = HardwareProfile::load(Path::new(path))?;
            println!("loaded hardware profile {path}: {}", p.summary());
            Some(p)
        }
        None => match HardwareProfile::autoload()? {
            Some((path, p)) => {
                println!(
                    "loaded hardware profile {} (via $GE_SPMM_PROFILE): {}",
                    path.display(),
                    p.summary()
                );
                Some(p)
            }
            None => None,
        },
    };
    let base_selector = profile
        .as_ref()
        .map(|p| p.selector.clone())
        .unwrap_or_default();
    let cache_bytes = args.parse_positive("cache-mb", 64) << 20;
    let threshold = args.parse_positive("shard-threshold", 250_000);
    let shards = args.parse_positive("shards", 4);
    let trace_capacity = args.parse_positive("trace-capacity", 64);
    let engine = Arc::new(if args.flag("online") {
        SpmmEngine::serving_online_traced(
            cache_bytes,
            threshold,
            shards,
            base_selector,
            OnlineConfig {
                explore_every: args.parse_or("explore-every", 16),
                refit_every: args.parse_or("refit-every", 256),
                ..OnlineConfig::default()
            },
            trace_capacity,
        )
    } else {
        SpmmEngine::serving_with_selector_traced(
            cache_bytes,
            threshold,
            shards,
            base_selector,
            trace_capacity,
        )
    });
    if let Some(spec) = args.get("slo") {
        let spec = ge_spmm::obs::SloSpec::parse(spec).map_err(|e| anyhow!("--slo: {e}"))?;
        let monitor = Arc::new(ge_spmm::obs::SloMonitor::new(spec));
        println!("slo objectives: {}", monitor.spec().summary());
        engine.metrics.install_slo(monitor);
    }
    // Tuned variant winners (from `ge-spmm tune --profile`) seed the online
    // selector's per-bucket preferences, so tuned variants are dispatched
    // from the first request rather than rediscovered by exploration.
    if let (Some(online), Some(p)) = (engine.online(), &profile) {
        if !p.variants.is_empty() {
            let installed = online.install_variant_winners(
                p.variants.iter().map(|w| (w.op, w.bucket, w.label.as_str())),
            );
            println!(
                "installed {installed} of {} tuned variant winners from the profile",
                p.variants.len()
            );
        }
    }
    let config = ServerConfig {
        max_width: args.parse_positive("max-width", 128),
        max_delay: Duration::from_millis(args.parse_or("max-delay-ms", 2)),
        workers: args.parse_positive("workers", 4),
        max_queue: args.parse_positive("max-queue", 1024),
    };
    let server = Server::start(engine.clone(), config);
    println!(
        "serving: {} workers, {producers} producers x {requests} requests, \
         {matrices} matrices ({rows}x{rows}, density {density}), n={n}",
        server.workers()
    );

    // Periodic stats exposition: overwrite --stats-file every
    // --stats-every seconds while the workload runs (a scrape target),
    // plus one final dump after shutdown either way.
    let stats_file: Option<String> = args.get("stats-file").map(str::to_string);
    let stats_every: u64 = args.parse_or("stats-every", 0);
    let stop_stats = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stats_thread = match (&stats_file, stats_every) {
        (Some(path), every) if every > 0 => {
            let engine = engine.clone();
            let stop = stop_stats.clone();
            let path = path.clone();
            Some(std::thread::spawn(move || {
                use std::sync::atomic::Ordering;
                let period = Duration::from_secs(every);
                let mut last = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(50));
                    if last.elapsed() >= period {
                        last = Instant::now();
                        if let Err(e) = write_stats(&engine, &path) {
                            eprintln!("stats dump failed: {e:#}");
                        }
                    }
                }
            }))
        }
        _ => None,
    };

    let t0 = Instant::now();
    let (ok, failed) = std::thread::scope(|s| {
        let joins: Vec<_> = (0..producers)
            .map(|p| {
                let engine = engine.clone();
                let server = &server;
                s.spawn(move || {
                    // Every client builds and registers the same matrix
                    // mix: all registrations past the first client's are
                    // prepared-cache hits.
                    let handles: Vec<_> = (0..matrices)
                        .map(|i| {
                            let mut mrng = Xoshiro256::seeded(seed + i as u64);
                            let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(
                                rows, rows, density, &mut mrng,
                            ));
                            engine.register(csr).expect("register")
                        })
                        .collect();
                    let mut rng = Xoshiro256::seeded(seed ^ (0x9e37 + p as u64));
                    let mut replies = Vec::with_capacity(requests);
                    for r in 0..requests {
                        let (rtx, rrx) = mpsc::channel();
                        server.submit(Request::spmm(
                            handles[r % handles.len()],
                            DenseMatrix::random(rows, n, 1.0, &mut rng),
                            (p * requests + r) as u64,
                            rtx,
                        ));
                        replies.push(rrx);
                    }
                    let (mut ok, mut failed) = (0u64, 0u64);
                    for rrx in replies {
                        match rrx.recv_timeout(Duration::from_secs(120)) {
                            Ok(ServerReply::Ok(_)) => ok += 1,
                            _ => failed += 1,
                        }
                    }
                    (ok, failed)
                })
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().expect("producer panicked"))
            .fold((0u64, 0u64), |(a, b), (o, f)| (a + o, b + f))
    });
    let elapsed = t0.elapsed();
    server.shutdown();
    stop_stats.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(t) = stats_thread {
        let _ = t.join();
    }
    if let Some(path) = &stats_file {
        write_stats(&engine, path)?;
        println!("stats written to {path}");
    }

    println!(
        "served {ok} requests ({failed} rejected/failed) in {elapsed:?} \
         ({:.0} req/s)",
        ok as f64 / elapsed.as_secs_f64().max(1e-9)
    );
    println!("{}", engine.metrics.summary());
    if let Some(monitor) = engine.metrics.slo() {
        println!("{}", monitor.report().health_line());
    }
    if let Some(online) = engine.online() {
        println!("{}", online.summary());
    }
    if let Some((entries, bytes)) = engine.cache_usage() {
        println!("cache: {entries} prepared matrices resident, {bytes} bytes");
    }
    Ok(())
}

/// Dump one exposition snapshot of an engine's metrics to `path`:
/// a JSON snapshot when the path ends in `.json`, Prometheus text
/// otherwise.
fn write_stats(engine: &SpmmEngine, path: &str) -> Result<()> {
    use ge_spmm::obs::expo;
    let text = if path.ends_with(".json") {
        let mut t = expo::snapshot(&engine.metrics).to_string_pretty();
        t.push('\n');
        t
    } else {
        expo::prometheus_text(&engine.metrics)
    };
    std::fs::write(path, text).map_err(|e| anyhow!("writing stats file {path}: {e}"))
}

fn cmd_stats(rest: Vec<String>) -> Result<()> {
    use ge_spmm::obs::expo;
    use ge_spmm::sparse::CooMatrix;
    use ge_spmm::util::json::Json;

    let cmd = Command::new(
        "stats",
        "render engine metrics as Prometheus text and JSON (drives a small \
         synthetic workload through the serving engine so every surface has \
         data, or re-renders a dumped JSON snapshot with --file)",
    )
    .opt(
        "file",
        "re-render a previously dumped JSON snapshot (e.g. from `serve \
         --stats-file stats.json`) instead of running a workload",
        None,
    )
    .opt(
        "format",
        "output format: prom | json | both | chrome (chrome emits only the \
         flight recorder as Chrome trace-event JSON, for chrome://tracing \
         or Perfetto)",
        Some("both"),
    )
    .opt("requests", "synthetic requests to drive (workload mode)", Some("32"))
    .opt("rows", "rows = cols of the small synthetic matrix", Some("256"))
    .opt("n", "dense width per request", Some("8"))
    .flag("traces", "also dump the flight recorder's retained traces (JSON)")
    .flag("regret", "also print the selector-regret report (per-bucket table)")
    .flag("explain", "also print the selector decision audit report")
    .opt("seed", "workload seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let format = args.get_or("format", "both");
    anyhow::ensure!(
        matches!(format, "prom" | "json" | "both" | "chrome"),
        "unknown --format '{format}' (expected: prom, json, both, chrome)"
    );

    // File mode: parse the snapshot back and re-render through the same
    // renderers the live path uses — the snapshot is the interchange.
    if let Some(path) = args.get("file") {
        anyhow::ensure!(
            format != "chrome",
            "--format chrome renders the live flight recorder and cannot \
             re-render a snapshot file"
        );
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading stats snapshot {path}: {e}"))?;
        let snap = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        if format != "prom" {
            println!("{}", snap.to_string_pretty());
        }
        if format != "json" {
            print!(
                "{}",
                expo::prometheus_of(&snap).map_err(|e| anyhow!("rendering {path}: {e}"))?
            );
        }
        return Ok(());
    }

    // Workload mode: one small matrix on the unsharded route and one
    // large on the sharded route, mixed SpMM/SDDMM traffic — so request
    // and shard grains, both ops, the audit log and the flight recorder
    // all have data to render.
    let requests = args.parse_positive("requests", 32);
    let rows = args.parse_positive("rows", 256);
    let n = args.parse_positive("n", 8);
    let mut rng = Xoshiro256::seeded(args.parse_or("seed", 42));
    let small = CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, rows, 0.01, &mut rng));
    let large = CsrMatrix::from_coo(&CooMatrix::random_uniform(rows * 2, rows, 0.05, &mut rng));
    let engine = SpmmEngine::serving(16 << 20, small.nnz() + 1, 2);
    let hs = engine.register(small)?;
    let hl = engine.register(large)?;
    for r in 0..requests {
        let h = if r % 2 == 0 { hs } else { hl };
        let f = engine.features(h)?;
        if r % 4 == 3 {
            let u = DenseMatrix::random(f.rows, n, 1.0, &mut rng);
            let v = DenseMatrix::random(f.cols, n, 1.0, &mut rng);
            engine.sddmm(h, &u, &v)?;
        } else {
            let x = DenseMatrix::random(f.cols, n, 1.0, &mut rng);
            engine.spmm(h, &x)?;
        }
    }
    eprintln!(
        "drove {requests} synthetic requests ({} spmm, {} sddmm; {} shard executions)",
        engine.metrics.requests(),
        engine.metrics.sddmm_requests(),
        engine.metrics.shard_executions() + engine.metrics.sddmm_shard_executions(),
    );

    // Chrome mode: stdout is exactly one trace-event JSON document, so it
    // pipes straight into a validator or chrome://tracing.
    if format == "chrome" {
        let json = engine.metrics.recorder().chrome_trace_json();
        println!("{}", json.to_string_pretty());
        return Ok(());
    }

    let snap = expo::snapshot(&engine.metrics);
    if format != "prom" {
        println!("{}", snap.to_string_pretty());
    }
    if format != "json" {
        print!(
            "{}",
            expo::prometheus_of(&snap).map_err(|e| anyhow!("rendering snapshot: {e}"))?
        );
    }
    if format == "both" {
        print_roofline(&engine);
    }
    if args.flag("traces") {
        println!("{}", engine.metrics.recorder().dump_json().to_string_pretty());
    }
    if args.flag("regret") {
        println!("{}", engine.metrics.regret().report().render());
    }
    if args.flag("explain") {
        println!("{}", engine.metrics.audit().explain(None));
    }
    Ok(())
}

/// Print the roofline workload table: achieved GFLOP/s, GB/s and
/// arithmetic intensity per (op, variant) that actually executed, from
/// the analytic flop/byte counters accumulated at dispatch.
fn print_roofline(engine: &SpmmEngine) {
    let mut table =
        ge_spmm::bench::Table::new(&["op", "variant", "execs", "gflop/s", "gb/s", "flops/byte"]);
    let mut rows = 0usize;
    for e in ge_spmm::kernels::registry().entries() {
        let Some(t) = engine.metrics.workload_totals(e.id) else {
            continue;
        };
        rows += 1;
        table.row(vec![
            e.variant.op.label().to_string(),
            e.label.to_string(),
            t.execs.to_string(),
            format!("{:.3}", t.achieved_gflops()),
            format!("{:.3}", t.achieved_gbps()),
            format!("{:.3}", t.arithmetic_intensity()),
        ]);
    }
    println!("roofline workload accounting (analytic flops/bytes over measured ns):");
    if rows == 0 {
        println!("  (no executions recorded)");
    } else {
        table.print();
    }
}

fn cmd_simulate(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("simulate", "GPU cost model for all kernels")
        .opt("n", "dense-matrix width", Some("32"))
        .opt("gpu", "v100 | rtx2080 | rtx3090", Some("rtx3090"));
    let args = cmd.parse(&rest)?;
    let m = load_matrix(&matrix_arg(&args)?)?;
    let n: usize = args.parse_or("n", 32);
    let gpu = GpuConfig::by_name(args.get_or("gpu", "rtx3090"))
        .ok_or_else(|| anyhow!("unknown gpu"))?;
    let sm = SimMatrix::new(m);
    let mut table = ge_spmm::bench::Table::new(&["kernel", "time", "bound", "warps"]);
    for k in [
        SimKernel::SrRs,
        SimKernel::SrWb,
        SimKernel::PrRs,
        SimKernel::PrWb,
        SimKernel::CuSparse,
        SimKernel::Aspt,
    ] {
        let r = simulate(k, &sm, n, &gpu);
        table.row(vec![
            k.label().to_string(),
            ge_spmm::bench::table::secs(r.seconds),
            format!("{:?}", r.bound),
            r.warps.to_string(),
        ]);
    }
    println!("{}x{} n={} on {}", sm.csr.rows, sm.csr.cols, n, gpu.name);
    table.print();
    Ok(())
}

fn cmd_calibrate(rest: Vec<String>) -> Result<()> {
    use ge_spmm::backend::{NativeBackend, SpmmBackend};
    use ge_spmm::selector::measured::{self, MeasureConfig};
    use ge_spmm::selector::HardwareProfile;

    let cmd = Command::new("calibrate", "fit selector thresholds on the collection")
        .opt("gpu", "v100 | rtx2080 | rtx3090 (simulator mode)", Some("rtx3090"))
        .opt("n-values", "dense widths", Some("1,4,32,128"))
        .flag("mini", "use the mini collection (fast)")
        .flag(
            "measured",
            "fit against wallclock timings of the native kernels on this machine \
             instead of the GPU simulator",
        )
        .opt(
            "profile",
            "write the fitted thresholds as a hardware-profile JSON (loaded by \
             `serve --profile` / $GE_SPMM_PROFILE)",
            None,
        )
        .opt(
            "limit",
            "cap the number of suite matrices (0 = all; measured mode smoke-tests \
             with small caps)",
            Some("0"),
        )
        .opt(
            "budget-ms",
            "per-(matrix, N, kernel) measurement budget in measured mode (ms)",
            Some("40"),
        );
    let args = cmd.parse(&rest)?;
    let n_values = args.parse_list("n-values", &[1usize, 4, 32, 128]);
    let mut specs = if args.flag("mini") {
        Collection::mini_suite()
    } else {
        Collection::suite()
    };
    let limit: usize = args.parse_or("limit", 0);
    if limit > 0 && specs.len() > limit {
        specs.truncate(limit);
    }
    eprintln!("building {} matrices …", specs.len());
    let matrices: Vec<CsrMatrix> = specs.iter().map(|s| s.build()).collect();

    let (samples, source, backend_name) = if args.flag("measured") {
        let backend = NativeBackend::default();
        let cfg = MeasureConfig::default().with_budget_ms(args.parse_or("budget-ms", 40));
        eprintln!(
            "profiling {} (matrix × N) cells on the {} backend (wallclock) …",
            matrices.len() * n_values.len(),
            backend.name()
        );
        let samples = measured::collect_samples(&matrices, &n_values, &backend, &cfg)?;
        (samples, "measured", backend.name())
    } else {
        let gpu = GpuConfig::by_name(args.get_or("gpu", "rtx3090"))
            .ok_or_else(|| anyhow!("unknown gpu"))?;
        eprintln!("profiling on the {} simulator …", gpu.name);
        (
            calibrate::collect_samples(&matrices, &n_values, &gpu),
            "simulated",
            "sim",
        )
    };
    if samples.is_empty() {
        bail!("no calibration samples (all suite matrices empty?)");
    }
    let cal = calibrate::calibrate(&samples);
    let default_loss = calibrate::selector_loss(&AdaptiveSelector::default(), &samples);
    println!(
        "calibrated: T_avg={} T_cv={} (geomean loss vs oracle: {:.3}; paper defaults: {:.3})",
        cal.selector.t_avg, cal.selector.t_cv, cal.mean_loss, default_loss
    );
    if let Some(path) = args.get("profile") {
        let profile = HardwareProfile::new(&cal, source, backend_name, samples.len(), &n_values);
        profile.save(Path::new(path))?;
        println!("wrote hardware profile {path}: {}", profile.summary());
    }
    Ok(())
}

/// Budgeted search over the generated variant registry (`DESIGN.md`
/// §Kernel generation). For every (op, feature-bucket, family) cell that
/// the collection populates, the tuner races the family's generated
/// variants by successive halving — everyone gets a slice of the
/// `--budget-ms` budget, the slower half is dropped, the survivors get
/// the rest — and the winner's label is recorded. With `--profile` the
/// winners are written into a v2 hardware profile (together with freshly
/// fitted selector thresholds) that `serve --profile --online` installs
/// as per-bucket variant preferences.
fn cmd_tune(rest: Vec<String>) -> Result<()> {
    use ge_spmm::backend::{NativeBackend, SpmmBackend};
    use ge_spmm::kernels::{registry, SparseOp};
    use ge_spmm::selector::measured::{self, MeasureConfig};
    use ge_spmm::selector::HardwareProfile;

    let cmd = Command::new(
        "tune",
        "budgeted successive-halving search over the generated kernel-variant \
         registry; winners land in a hardware profile",
    )
    .opt("n-values", "SpMM dense widths to tune over", Some("8,32"))
    .opt("d-values", "SDDMM embedding widths to tune over", Some("8,32"))
    .flag("mini", "use the mini collection (fast)")
    .opt("limit", "cap the number of suite matrices (0 = all)", Some("0"))
    .opt(
        "budget-ms",
        "total measurement budget per (matrix, width, family) cell (ms)",
        Some("24"),
    )
    .opt(
        "profile",
        "write the winners (plus fitted selector thresholds) as a \
         hardware-profile JSON for `serve --profile`",
        None,
    )
    .opt("seed", "operand seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let n_values = args.parse_list("n-values", &[8usize, 32]);
    let d_values = args.parse_list("d-values", &[8usize, 32]);
    let mut specs = if args.flag("mini") {
        Collection::mini_suite()
    } else {
        Collection::suite()
    };
    let limit: usize = args.parse_or("limit", 0);
    if limit > 0 && specs.len() > limit {
        specs.truncate(limit);
    }
    eprintln!("building {} matrices …", specs.len());
    let matrices: Vec<CsrMatrix> = specs.iter().map(|s| s.build()).collect();

    let backend = NativeBackend::default();
    let base = MeasureConfig::default().with_budget_ms(args.parse_or("budget-ms", 24));
    let cfg = MeasureConfig {
        seed: args.parse_or("seed", 42),
        ..base
    };
    let reg = registry();
    eprintln!(
        "tuning {} generated variants ({} spmm, {} sddmm) on {} matrices \
         (n={n_values:?}, d={d_values:?}) on the {} backend …",
        reg.len(),
        reg.op_variants(SparseOp::Spmm).len(),
        reg.op_variants(SparseOp::Sddmm).len(),
        matrices.len(),
        backend.name()
    );
    let report = measured::tune_variants(&backend, &matrices, &n_values, &d_values, &cfg)?;
    if report.winners.is_empty() {
        bail!("no variant winners (all suite matrices empty?)");
    }

    let mut table = ge_spmm::bench::Table::new(&["op", "bucket", "family", "winner", "cost/flop"]);
    for w in &report.winners {
        table.row(vec![
            w.op.label().to_string(),
            w.bucket.to_string(),
            w.family.label().to_string(),
            w.label.clone(),
            format!("{:.3e}", w.cost),
        ]);
    }
    table.print();
    println!(
        "tuned {} (op, bucket, family) cells from {} timed candidates; \
         {} non-canonical winners",
        report.winners.len(),
        report.cells_timed,
        report.non_canonical()
    );

    if let Some(path) = args.get("profile") {
        // A profile needs selector thresholds too — fit them on the same
        // suite so one file carries the whole machine-tuned policy.
        let samples = measured::collect_samples(&matrices, &n_values, &backend, &cfg)?;
        anyhow::ensure!(
            !samples.is_empty(),
            "no calibration samples to fit thresholds for the profile"
        );
        let cal = calibrate::calibrate(&samples);
        let profile =
            HardwareProfile::new(&cal, "measured", backend.name(), samples.len(), &n_values)
                .with_variants(report.winners.clone());
        profile.save(Path::new(path))?;
        println!("wrote hardware profile {path}: {}", profile.summary());
    }
    Ok(())
}

/// The CI perf-regression gate (`DESIGN.md` §Vectorization, "Perf gate").
///
/// Measures every variant in the generated registry on a pinned synthetic
/// workload — new variants are gated the moment they are registered, with
/// no case list to update — and normalizes each median by the *same-run*
/// dense-reference median, so the recorded numbers are machine-portable
/// ratios (kernel/reference), not raw
/// wallclock. `--record` writes the ratios as a baseline JSON; with
/// `--baseline` the command re-measures and fails when any kernel's
/// ratio grew by more than `--threshold` (default 1.3×, deliberately
/// generous: shared CI runners are noisy and this gate is after 10×
/// regressions, not 10%). A run that compares nothing — the baseline has
/// an empty `results` object (the checked-in bootstrap from a machine
/// that could not measure), or no measured case matched any baseline
/// entry — prints a `VACUOUS:` status line and exits with code 3 so CI
/// can surface "the gate did not actually gate" instead of a green pass.
fn cmd_perfgate(rest: Vec<String>) -> Result<()> {
    use ge_spmm::bench::harness::{bench_fn_with, BenchConfig};
    use ge_spmm::kernels::{dense, registry, SparseOp};
    use ge_spmm::sparse::{CooMatrix, SegmentedMatrix};
    use ge_spmm::util::json::{num, obj, s, Json};
    use ge_spmm::util::threadpool::ThreadPool;
    use std::collections::HashMap;
    use std::time::Duration;

    let cmd = Command::new(
        "perfgate",
        "perf-regression gate: normalized kernel/reference latency ratios",
    )
    .opt(
        "baseline",
        "baseline JSON to compare against (fail on >threshold regression)",
        None,
    )
    .opt("record", "write this run's ratios as a baseline JSON", None)
    .opt(
        "threshold",
        "max allowed ratio growth vs baseline (1.3 = 30% slower)",
        Some("1.3"),
    )
    .opt("budget-ms", "per-case measurement budget (ms)", Some("40"))
    .opt("n", "dense width for the SpMM cases", Some("32"))
    .opt("seed", "workload seed", Some("42"));
    let args = cmd.parse(&rest)?;
    let threshold: f64 = args.parse_or("threshold", 1.3);
    anyhow::ensure!(
        threshold.is_finite() && threshold > 1.0,
        "--threshold must be a finite value > 1.0"
    );
    let budget_ms: u64 = args.parse_or("budget-ms", 40);
    let n: usize = args.parse_positive("n", 32);
    let cfg = BenchConfig {
        warmup: Duration::from_millis(budget_ms / 4),
        measure: Duration::from_millis(budget_ms),
        ..BenchConfig::default()
    };
    let pool = ThreadPool::default_parallel();
    let mut rng = Xoshiro256::seeded(args.parse_or("seed", 42));

    // Pinned workload: one flat and one heavy-tailed matrix, small enough
    // for a CI smoke yet large enough that per-call overhead is noise.
    let uniform = CsrMatrix::from_coo(&CooMatrix::random_uniform(2048, 2048, 0.004, &mut rng));
    let plaw_cfg = ge_spmm::gen::powerlaw::PowerLawConfig {
        rows: 2048,
        cols: 2048,
        alpha: 1.6,
        min_row: 1,
        max_row: 256,
    };
    let plaw = CsrMatrix::from_coo(&plaw_cfg.generate(&mut rng));

    let reg = registry();
    if reg.entries().is_empty() {
        println!(
            "VACUOUS: the generated variant registry is empty — there is \
             nothing to measure and nothing to gate"
        );
        std::process::exit(3);
    }

    // One segmented layout per distinct segment length, shared across the
    // variants that use it (the layout is the monomorphization axis).
    let layouts_for = |a: &CsrMatrix| -> HashMap<usize, SegmentedMatrix> {
        let mut lens: Vec<usize> = reg.entries().iter().map(|e| e.variant.seg_len).collect();
        lens.sort_unstable();
        lens.dedup();
        lens.into_iter()
            .map(|l| (l, SegmentedMatrix::from_csr(a, l)))
            .collect()
    };

    let mut results: Vec<(String, f64)> = Vec::new();
    for (mname, a) in [("uniform", &uniform), ("plaw", &plaw)] {
        let layouts = layouts_for(a);
        let x = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
        let mut y = DenseMatrix::zeros(a.rows, n);
        let reference = bench_fn_with(&format!("{mname}/reference"), cfg, || {
            dense::spmm_reference(a, &x, &mut y);
            std::hint::black_box(&y);
        });
        let ref_s = reference.median_s().max(1e-12);
        for e in reg.op_variants(SparseOp::Spmm) {
            let seg = &layouts[&e.variant.seg_len];
            // preallocated output, exactly like the reference above — no
            // per-iteration allocation in the timed loop
            let mut out = DenseMatrix::zeros(a.rows, n);
            let name = format!("{mname}/{}", e.label);
            let stats = bench_fn_with(&name, cfg, || {
                e.run_spmm(a, seg, &x, &mut out, &pool)
                    .expect("registry entry rejected its own layout");
                std::hint::black_box(&out);
            });
            results.push((name, stats.median_s() / ref_s));
        }
    }
    // every SDDMM variant on the skewed matrix (reduction axis d = n)
    {
        let a = &plaw;
        let layouts = layouts_for(a);
        let u = DenseMatrix::random(a.rows, n, 1.0, &mut rng);
        let v = DenseMatrix::random(a.cols, n, 1.0, &mut rng);
        let mut out = vec![0f32; a.nnz()];
        let reference = bench_fn_with("sddmm/reference", cfg, || {
            dense::sddmm_reference(a, &u, &v, &mut out);
            std::hint::black_box(&out);
        });
        let ref_s = reference.median_s().max(1e-12);
        for e in reg.op_variants(SparseOp::Sddmm) {
            let seg = &layouts[&e.variant.seg_len];
            let mut vals = vec![0f32; a.nnz()];
            let name = format!("sddmm/{}", e.label);
            let stats = bench_fn_with(&name, cfg, || {
                e.run_sddmm(a, seg, &u, &v, &mut vals, &pool)
                    .expect("registry entry rejected its own layout");
                std::hint::black_box(&vals);
            });
            results.push((name, stats.median_s() / ref_s));
        }
    }

    let mut table = ge_spmm::bench::Table::new(&["case", "kernel/reference"]);
    for (name, ratio) in &results {
        table.row(vec![name.clone(), format!("{ratio:.3}")]);
    }
    table.print();

    if let Some(path) = args.get("record") {
        let json = obj(vec![
            ("version", num(1.0)),
            ("bench", s("perfgate")),
            ("host", s(&ge_spmm::bench::record::hostname())),
            (
                "note",
                s("normalized medians: kernel latency / same-run dense-reference latency"),
            ),
            (
                "results",
                Json::Obj(
                    results
                        .iter()
                        .map(|(k, v)| (k.clone(), num(*v)))
                        .collect(),
                ),
            ),
        ]);
        let mut text = json.to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| anyhow!("writing perfgate record {path}: {e}"))?;
        println!("recorded {} ratios to {path}", results.len());
    }

    if let Some(path) = args.get("baseline") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading perfgate baseline {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let base = json
            .get("results")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("baseline {path} has no 'results' object"))?;
        if base.is_empty() {
            println!(
                "VACUOUS: baseline {path} has no recorded results (bootstrap from a \
                 machine without measurement) — nothing was compared; regenerate with \
                 `ge-spmm perfgate --record {path}` on a machine that can measure"
            );
            std::process::exit(3);
        }
        let mut regressions = Vec::new();
        let mut compared = 0usize;
        for (name, now) in &results {
            let Some(was) = base.get(name).and_then(Json::as_f64) else {
                println!("  (no baseline entry for {name}; skipped)");
                continue;
            };
            compared += 1;
            let growth = now / was.max(1e-12);
            if growth > threshold {
                regressions.push(format!(
                    "{name}: ratio {was:.3} -> {now:.3} ({growth:.2}x growth)"
                ));
            }
        }
        if !regressions.is_empty() {
            bail!(
                "perf gate failed ({} of {compared} cases regressed past {threshold}x):\n  {}",
                regressions.len(),
                regressions.join("\n  ")
            );
        }
        if compared == 0 {
            println!(
                "VACUOUS: no measured case matched any entry in {path} — the gate \
                 compared nothing; re-record the baseline with \
                 `ge-spmm perfgate --record {path}`"
            );
            std::process::exit(3);
        }
        println!("perf gate passed: {compared} cases within {threshold}x of {path}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_gcn(_rest: Vec<String>) -> Result<()> {
    bail!(
        "`train-gcn` drives the AOT `gcn_step` artifact and needs the `pjrt` \
         feature — rebuild with `cargo build --features pjrt`"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train_gcn(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("train-gcn", "end-to-end GCN training (E2E driver)")
        .opt("steps", "training steps", Some("200"))
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("seed", "graph + init seed", Some("7"))
        .opt("log-every", "loss log interval", Some("20"));
    let args = cmd.parse(&rest)?;
    let engine = Engine::new(Path::new(args.get_or("artifacts", "artifacts")))?;
    let graph = SyntheticGraph::generate(GraphConfig::default(), args.parse_or("seed", 7));
    let mut trainer = GcnTrainer::new(&engine, &graph, args.parse_or("seed", 7) + 1)?;
    let report = trainer.train(args.parse_or("steps", 200), args.parse_or("log-every", 20))?;
    println!(
        "trained {} steps in {:.1}s  loss {:.4} → {:.4}  train-acc {:.3}",
        report.steps,
        report.seconds,
        report.losses.first().unwrap_or(&f32::NAN),
        report.losses.last().unwrap_or(&f32::NAN),
        report.train_accuracy
    );
    Ok(())
}

fn cmd_suite(rest: Vec<String>) -> Result<()> {
    let cmd = Command::new("suite", "list the synthetic benchmark collection")
        .flag("features", "also print per-matrix features (slow)");
    let args = cmd.parse(&rest)?;
    let specs = Collection::suite();
    println!("{} matrices:", specs.len());
    for s in &specs {
        if args.flag("features") {
            let f = MatrixFeatures::of(&s.build());
            println!("  {:<24} [{}] {}", s.name, s.family.label(), f.summary());
        } else {
            println!("  {:<24} [{}]", s.name, s.family.label());
        }
    }
    Ok(())
}
