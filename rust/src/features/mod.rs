//! Sparse-matrix feature extraction — the inputs to the adaptive selector.
//!
//! The paper's selection strategy (§2.2) uses *low-cost* statistics of the
//! row-length distribution: the mean `avg_row`, the standard deviation
//! `stdv_row`, and their ratio (coefficient of variation). All are O(rows)
//! given CSR `indptr`, i.e. essentially free next to the SpMM itself.

use crate::sparse::CsrMatrix;
use crate::util::stats;

/// Row-length statistics of a sparse matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatrixFeatures {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// mean row length (`avg_row` in the paper)
    pub avg_row: f64,
    /// population stddev of row lengths (`stdv_row` in the paper)
    pub stdv_row: f64,
    /// `stdv_row / avg_row` — the paper's balancing metric
    pub cv_row: f64,
    /// maximum row length (bottleneck row)
    pub max_row: usize,
    /// fraction of empty rows
    pub empty_frac: f64,
    /// Gini coefficient of row lengths (auxiliary imbalance measure)
    pub gini_row: f64,
}

impl MatrixFeatures {
    /// Extract features from CSR (O(rows)).
    pub fn of(csr: &CsrMatrix) -> Self {
        Self::of_row_range(csr, 0..csr.rows)
    }

    /// Features of a contiguous row range, read off the parent CSR without
    /// materializing the slice — what `crate::shard` feeds the per-shard
    /// selector. O(range length); `of(csr)` is the `0..rows` case.
    pub fn of_row_range(csr: &CsrMatrix, rows: std::ops::Range<usize>) -> Self {
        assert!(
            rows.start <= rows.end && rows.end <= csr.rows,
            "row range {}..{} out of bounds for {} rows",
            rows.start,
            rows.end,
            csr.rows
        );
        let nrows = rows.end - rows.start;
        let nnz = (csr.indptr[rows.end] - csr.indptr[rows.start]) as usize;
        let lens: Vec<f64> = rows.map(|r| csr.row_nnz(r) as f64).collect();
        let avg = stats::mean(&lens);
        let stdv = stats::stddev(&lens);
        let max_row = lens.iter().cloned().fold(0.0f64, f64::max) as usize;
        let empty = lens.iter().filter(|&&l| l == 0.0).count();
        Self {
            rows: nrows,
            cols: csr.cols,
            nnz,
            avg_row: avg,
            stdv_row: stdv,
            cv_row: if avg == 0.0 { 0.0 } else { stdv / avg },
            max_row,
            empty_frac: if nrows == 0 {
                0.0
            } else {
                empty as f64 / nrows as f64
            },
            gini_row: stats::gini(&lens),
        }
    }

    /// Total floating-point work of `A × X` with dense width `n`:
    /// 2·nnz·n flops (multiply + add).
    pub fn flops(&self, n: usize) -> f64 {
        2.0 * self.nnz as f64 * n as f64
    }

    /// One-line summary for logs/bench output.
    pub fn summary(&self) -> String {
        format!(
            "{}x{} nnz={} avg_row={:.2} stdv_row={:.2} cv={:.2} max_row={} empty={:.1}%",
            self.rows,
            self.cols,
            self.nnz,
            self.avg_row,
            self.stdv_row,
            self.cv_row,
            self.max_row,
            self.empty_frac * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn features_of_known_matrix() {
        // rows of length 2, 0, 4
        let mut coo = CooMatrix::new(3, 8);
        for c in 0..2 {
            coo.push(0, c, 1.0);
        }
        for c in 0..4 {
            coo.push(2, c, 1.0);
        }
        let f = MatrixFeatures::of(&CsrMatrix::from_coo(&coo));
        assert_eq!(f.nnz, 6);
        assert!((f.avg_row - 2.0).abs() < 1e-12);
        let expected_stdv = ((4.0 + 4.0 + 0.0) / 3.0f64).sqrt(); // lens 2,0,4 mean 2
        assert!((f.stdv_row - expected_stdv).abs() < 1e-12);
        assert_eq!(f.max_row, 4);
        assert!((f.empty_frac - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(f.flops(16) as u64, 2 * 6 * 16);
    }

    #[test]
    fn balanced_matrix_has_low_cv() {
        let mut rng = Xoshiro256::seeded(71);
        let m = crate::gen::banded::banded(200, &[-1, 0, 1], &mut rng);
        let f = MatrixFeatures::of(&CsrMatrix::from_coo(&m));
        assert!(f.cv_row < 0.1, "cv {}", f.cv_row);
        assert!(f.gini_row < 0.05, "gini {}", f.gini_row);
    }

    #[test]
    fn skewed_matrix_has_high_cv() {
        let mut rng = Xoshiro256::seeded(72);
        let cfg = crate::gen::powerlaw::PowerLawConfig {
            rows: 1000,
            cols: 2000,
            alpha: 1.6,
            min_row: 1,
            max_row: 800,
        };
        let f = MatrixFeatures::of(&CsrMatrix::from_coo(&cfg.generate(&mut rng)));
        assert!(f.cv_row > 1.0, "cv {}", f.cv_row);
        assert!(f.gini_row > 0.3, "gini {}", f.gini_row);
    }

    #[test]
    fn row_range_features_match_slice_extraction() {
        let mut rng = Xoshiro256::seeded(73);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 80, 0.07, &mut rng));
        for range in [0..csr.rows, 0..40, 40..115, 115..csr.rows, 7..7] {
            let direct = MatrixFeatures::of_row_range(&csr, range.clone());
            let via_slice = MatrixFeatures::of(&csr.row_slice(range));
            assert_eq!(direct, via_slice);
        }
    }

    #[test]
    fn summary_contains_key_fields() {
        let coo = CooMatrix::new(4, 4);
        let f = MatrixFeatures::of(&CsrMatrix::from_coo(&coo));
        let s = f.summary();
        assert!(s.contains("4x4"));
        assert!(s.contains("nnz=0"));
    }
}
